"""Pallas kernels (L1) and their pure-jnp oracles.

``attention.verify_attention`` and ``argmax.vocab_argmax`` are the two
kernels on the serving hot path; ``ref`` holds the ground-truth
implementations used by pytest and by the training forward pass.
"""

from . import argmax, attention, ref  # noqa: F401
