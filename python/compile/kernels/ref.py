"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
has a reference implementation here, and ``python/tests/test_kernels.py``
sweeps shapes/dtypes (hypothesis) asserting allclose between the two.

The references are also used directly by the training forward pass (which
does not need a KV cache) so serving and training numerics share one
definition of masked attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite "minus infinity": keeps softmax NaN-free on fully
                 # masked rows (padding rows have len == 0 and query 0 still
                 # attends to itself, but tests exercise degenerate cases)


def verify_attention_ref(
    q: jax.Array,      # [B, H, T, Dh] queries for the T in-flight tokens
    k: jax.Array,      # [B, H, S_max, Dh] full key cache (stale tail incl.)
    v: jax.Array,      # [B, H, S_max, Dh]
    lens: jax.Array,   # [B] i32: committed KV entries per row
) -> jax.Array:
    """Masked verify-attention: query i (absolute position lens+i) attends
    cache positions p <= lens + i.

    This single rule covers prefill (lens=0, plain causal), plain decode
    (T=1) and speculative verification (T=s+1): the intra-query causal mask
    and the committed-prefix mask are the same inequality.
    """
    b, h, t, dh = q.shape
    s_max = k.shape[2]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    pos = jnp.arange(s_max, dtype=jnp.int32)[None, None, None, :]
    qi = jnp.arange(t, dtype=jnp.int32)[None, None, :, None]
    mask = pos <= lens[:, None, None, None] + qi
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)


def vocab_argmax_ref(logits: jax.Array) -> jax.Array:
    """Row-wise argmax over the vocabulary, first-max-wins tie breaking.

    logits: [..., V] -> i32 [...].  ``jnp.argmax`` already picks the first
    maximum, which the Pallas kernel must match exactly (greedy decoding is
    the acceptance rule of Algorithm 1, so ties must break identically
    between draft and verify paths).
    """
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
