"""L1 Pallas kernel: tiled row-wise argmax over the vocabulary.

Greedy decoding (Algorithm 1 in the paper uses argmax acceptance) needs the
predicted token id for every in-flight position.  Shipping full logits
``[B, T, V]`` back to the Rust coordinator would waste host<->device
bandwidth; instead the model emits ``i32[B, T]`` token ids computed by this
kernel, fused into the same HLO module.

TPU mapping: the vocabulary axis is streamed through VMEM in ``V_BLK``
tiles while running (max, argmax) statistics live in scratch; tie-breaking
is *first maximum wins* (strict ``>`` on the update) to match
``jnp.argmax`` exactly — draft and verify paths must agree on ties or the
acceptance rule would mis-count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

DEFAULT_V_BLOCK = 256


def _argmax_kernel(
    x_ref,      # [R_BLK, V_BLK] logits tile
    o_ref,      # [R_BLK] i32 output block
    m_scr,      # [R_BLK, 1] running max
    i_scr,      # [R_BLK, 1] running argmax
    *,
    v_block: int,
    n_v_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        i_scr[...] = jnp.zeros_like(i_scr)

    x = x_ref[...]
    r = x.shape[0]
    tile_max = x.max(axis=1, keepdims=True)                       # [R,1]
    col = jax.lax.broadcasted_iota(jnp.int32, (r, v_block), 1)
    # first maximum within the tile: smallest column index achieving tile_max
    hit = jnp.where(x == tile_max, col, v_block)
    tile_arg = hit.min(axis=1, keepdims=True) + j * v_block       # [R,1]

    better = tile_max > m_scr[...]          # strict: earlier tiles win ties
    m_scr[...] = jnp.where(better, tile_max, m_scr[...])
    i_scr[...] = jnp.where(better, tile_arg, i_scr[...])

    @pl.when(j == n_v_blocks - 1)
    def _finalize():
        o_ref[...] = i_scr[..., 0]


def vocab_argmax(logits: jax.Array, *, v_block: int = DEFAULT_V_BLOCK) -> jax.Array:
    """Pallas row argmax.  ``logits [..., V] -> i32 [...]``.

    Semantics == ref.vocab_argmax_ref (first-max tie-breaking).
    """
    *lead, v = logits.shape
    rows = 1
    for d in lead:
        rows *= d
    x = logits.reshape(rows, v)
    if v % v_block != 0:
        v_block = next(
            blk for blk in range(min(v_block, v), 0, -1) if v % blk == 0
        )
    n_v = v // v_block

    kernel = functools.partial(_argmax_kernel, v_block=v_block, n_v_blocks=n_v)
    out = pl.pallas_call(
        kernel,
        grid=(1, n_v),
        in_specs=[pl.BlockSpec((rows, v_block), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((rows,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x.astype(jnp.float32))
    return out.reshape(tuple(lead))
