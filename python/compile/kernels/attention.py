"""L1 Pallas kernel: masked verify-attention with online softmax.

This is the compute hot-spot of batched speculative decoding: for every
(batch row, head) the `T = s+1` in-flight tokens (last committed token plus
the s speculated tokens) attend over a KV cache of up to `S_max` entries,
with a per-row valid-length mask fused with the intra-query causal mask.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's CUDA
prototype expressed this as a threadblock-per-(b,h) masked attention with
the score matrix staged through shared memory.  The TPU rethink:

* grid ``(S_max / S_BLK,)`` — the KV cache streams HBM→VMEM in tiles while
  the *whole* ``[B, H, T, Dh]`` query block stays VMEM-resident: at
  serving shapes (B ≤ 16, H ≤ 8, T ≤ 9) queries are tiny, so the batched
  block keeps the MXU fed with one big ``dot_general`` per tile instead of
  B·H skinny matmuls.  The ``BlockSpec`` index map is the HBM↔VMEM
  schedule CUDA did with threadblocks.
* flash-attention style **online softmax** across KV tiles so VMEM holds
  only the running ``(m, l, acc)`` statistics — never a ``[T, S_max]``
  score matrix.
* masking is positional arithmetic on ``broadcasted_iota`` (VPU-friendly,
  no gathers); both contractions use f32 accumulation on the MXU.

§Perf note: the first version used a ``(B, H, n_kv)`` grid (a literal port
of the CUDA threadblock layout).  Under ``interpret=True`` each grid step
pays overhead proportional to the operand count, so the per-(b,h) grid
cost O(B²) on CPU — 200 ms/call at B=16 vs 7.9 ms for this batched grid
(EXPERIMENTS.md §Perf).  On real TPU both layouts fit VMEM comfortably;
the batched layout also halves grid-dispatch overhead there.

The kernel runs under ``interpret=True`` — the CPU PJRT client cannot run
Mosaic custom calls — so it lowers into plain HLO and executes anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

# KV tile (second-minor axis of the VMEM block).  The block-shape sweep
# (EXPERIMENTS.md §Perf: 28/56/112/224 at b ∈ {4,8,16}) picked the single
# full-cache tile: 224 is 3-7x faster than 112 under interpret mode and
# still fits VMEM at the largest serving bucket (b=16, h=6: k+v tiles
# ≈ 5.5 MiB of the ~16 MiB/core budget).  The online-softmax structure is
# kept so larger S_max configurations can tile down without code changes.
DEFAULT_S_BLOCK = 224


def _attention_kernel(
    lens_ref,   # [B] i32 committed length per batch row
    q_ref,      # [B, H, T, Dh]
    k_ref,      # [B, H, S_BLK, Dh]
    v_ref,      # [B, H, S_BLK, Dh]
    o_ref,      # [B, H, T, Dh]
    m_scr,      # [B, H, T, 1] running max
    l_scr,      # [B, H, T, 1] running sum
    acc_scr,    # [B, H, T, Dh] running weighted-value accumulator
    *,
    s_block: int,
    n_kv_blocks: int,
    scale: float,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    lens = lens_ref[...]

    # scores for this KV tile: one batched MXU contraction [B,H,T,S_BLK]
    s = (
        jax.lax.dot_general(
            q, k, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )
        * scale
    )

    # fused mask: cache position p visible to query i iff p <= len + i
    pos = j * s_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos <= lens[:, None, None, None] + qi, s, NEG_INF)

    # online softmax update
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=3, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=3, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


def verify_attention(
    q: jax.Array,      # [B, H, T, Dh]
    k: jax.Array,      # [B, H, S_max, Dh]
    v: jax.Array,      # [B, H, S_max, Dh]
    lens: jax.Array,   # [B] i32
    *,
    s_block: int = DEFAULT_S_BLOCK,
) -> jax.Array:
    """Pallas masked verify-attention.  Semantics == ref.verify_attention_ref."""
    b, h, t, dh = q.shape
    s_max = k.shape[2]
    if s_max % s_block != 0:
        # fall back to the largest divisor <= requested block
        s_block = next(
            blk for blk in range(min(s_block, s_max), 0, -1) if s_max % blk == 0
        )
    n_kv = s_max // s_block
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _attention_kernel,
        s_block=s_block,
        n_kv_blocks=n_kv,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b, h, t, dh), lambda j: (0, 0, 0, 0)),
            pl.BlockSpec((b, h, s_block, dh), lambda j: (0, 0, j, 0)),
            pl.BlockSpec((b, h, s_block, dh), lambda j: (0, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((b, h, t, dh), lambda j: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, h, t, 1), jnp.float32),
            pltpu.VMEM((b, h, t, 1), jnp.float32),
            pltpu.VMEM((b, h, t, dh), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(lens, q, k, v)


def vmem_bytes(b: int, h: int, t: int, dh: int, s_block: int) -> int:
    """Estimated VMEM residency of one grid step (f32).

    q block + k/v tiles + scratch (m, l, acc) + output block.  Used by the
    §Perf analysis to pick ``s_block`` under the ~16 MiB/core VMEM budget
    (largest bucket b=16, h=6, t=9: ≈ 5.8 MiB at s_block=224).
    """
    floats = (
        b * h * t * dh            # q
        + 2 * b * h * s_block * dh  # k, v tiles
        + b * h * t * (dh + 2)    # acc, m, l scratch
        + b * h * t * dh          # o
    )
    return 4 * floats
