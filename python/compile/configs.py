"""Model and artifact-matrix configuration for the specbatch compile path.

Two OPT-style decoder-only transformers are built at artifact time:

* ``LLM_CONFIG``  — the "large" target model that verifies speculations.
* ``SSM_CONFIG``  — the small speculative model (draft model).

Dimensions are laptop-scale stand-ins for the paper's OPT-6.7B / OPT-125M
pair (see DESIGN.md §Substitutions): the acceptance behaviour l(s) emerges
from a *real* draft/target pair trained on the same corpus, which is the
mechanism the paper relies on, at a size the CPU PJRT client can serve.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters of one decoder-only transformer."""

    name: str
    vocab: int          # vocabulary size (shared between LLM and SSM)
    d_model: int        # residual width
    n_layers: int
    n_heads: int
    d_head: int         # per-head width; n_heads * d_head == d_model
    d_ff: int           # MLP hidden width
    max_seq: int        # KV-cache capacity (prompt + generation + slack)
    max_prompt: int     # prefill pad width

    def __post_init__(self) -> None:
        if self.n_heads * self.d_head != self.d_model:
            raise ValueError(
                f"{self.name}: n_heads*d_head ({self.n_heads}*{self.d_head}) "
                f"!= d_model ({self.d_model})"
            )
        if self.max_prompt >= self.max_seq:
            raise ValueError(f"{self.name}: max_prompt must be < max_seq")

    @property
    def kv_shape_per_batch(self):
        """KV-cache shape [L, 2, B, H, S_max, d_head] without the batch dim."""
        return (self.n_layers, 2, self.n_heads, self.max_seq, self.d_head)

    def kv_shape(self, batch: int):
        l, two, h, s, d = self.kv_shape_per_batch
        return (l, two, batch, h, s, d)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + stacked blocks)."""
        d, f, l, v = self.d_model, self.d_ff, self.n_layers, self.vocab
        per_layer = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d
        return v * d + self.max_seq * d + l * per_layer + 2 * d

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# Shared vocabulary between LLM and SSM (speculative decoding requires it).
VOCAB_SIZE = 512
MAX_SEQ = 224       # prompt (<=64) + 128 generated + speculation slack
MAX_PROMPT = 64

LLM_CONFIG = ModelConfig(
    name="llm",
    vocab=VOCAB_SIZE,
    d_model=192,
    n_layers=4,
    n_heads=6,
    d_head=32,
    d_ff=768,
    max_seq=MAX_SEQ,
    max_prompt=MAX_PROMPT,
)

SSM_CONFIG = ModelConfig(
    name="ssm",
    vocab=VOCAB_SIZE,
    d_model=96,
    n_layers=2,
    n_heads=3,
    d_head=32,
    d_ff=384,
    max_seq=MAX_SEQ,
    max_prompt=MAX_PROMPT,
)


@dataclass(frozen=True)
class ArtifactProfile:
    """Which (batch, speculation-length) executables to lower.

    ``batch_buckets`` are the power-of-two buckets of the paper's adaptive
    scheme (Sec. 4); arriving batches are padded up to the nearest bucket.
    ``spec_lengths`` covers the paper's sweep (1..8 in Fig. 1; the serving
    evaluation uses <=6).  s = 0 verify executables are the no-speculation
    decode baseline.
    """

    name: str
    batch_buckets: tuple
    verify_lengths: tuple       # for llm_verify (0 == plain decode)
    speculate_lengths: tuple    # for ssm_speculate
    # extra (bucket, s) pairs used by the Fig.2 acceptance study
    extra_verify: tuple = ()
    extra_speculate: tuple = ()
    train_steps_llm: int = 700
    train_steps_ssm: int = 500
    train_batch: int = 16
    train_seq: int = 64


FULL_PROFILE = ArtifactProfile(
    name="full",
    batch_buckets=(1, 2, 4, 8, 16),
    verify_lengths=(0, 1, 2, 3, 4, 5, 6),
    speculate_lengths=(1, 2, 3, 4, 5, 6),
    extra_verify=((1, 8), (4, 8)),
    extra_speculate=((1, 8), (4, 8)),
)

QUICK_PROFILE = ArtifactProfile(
    name="quick",
    batch_buckets=(1, 2, 4),
    verify_lengths=(0, 1, 2, 3),
    speculate_lengths=(1, 2, 3),
    train_steps_llm=60,
    train_steps_ssm=60,
)

PROFILES = {"full": FULL_PROFILE, "quick": QUICK_PROFILE}


def active_profile() -> ArtifactProfile:
    """Profile selected by the SPECBATCH_PROFILE env var (default: full)."""
    return PROFILES[os.environ.get("SPECBATCH_PROFILE", "full")]


def config_fingerprint(profile: ArtifactProfile) -> str:
    """Stable hash of everything that invalidates the artifact bundle
    (bump format_version on calling-convention or lowering changes)."""
    payload = {
        "llm": LLM_CONFIG.to_json(),
        "ssm": SSM_CONFIG.to_json(),
        "profile": dataclasses.asdict(profile),
        "format_version": 6,  # v6: full-cache KV tile s_block=224 (§Perf)
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def weights_fingerprint(profile: ArtifactProfile) -> str:
    """Hash of only what the *trained weights* depend on (model dims,
    corpus seed, training recipe) — lowering-only changes keep the
    multi-minute training cache warm."""
    payload = {
        "llm": LLM_CONFIG.to_json(),
        "ssm": SSM_CONFIG.to_json(),
        "train": {
            "steps_llm": profile.train_steps_llm,
            "steps_ssm": profile.train_steps_ssm,
            "batch": profile.train_batch,
            "seq": profile.train_seq,
        },
        "weights_version": 1,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]
