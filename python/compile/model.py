"""L2: OPT-style decoder-only transformer with a functional KV cache.

One forward definition covers every serving entry point (the paper's
Algorithm 1 maps onto exactly three executables):

* ``prefill``       — ingest the (padded) prompt, emit the first token.
* ``verify(s)``     — LLM side: ingest ``[last_committed, d_1..d_s]`` and
                      emit the argmax prediction at every position (the
                      ``o_i`` of Algorithm 1, reduced to token ids by the
                      Pallas argmax kernel).  ``s = 0`` is the plain
                      no-speculation decode baseline.
* ``speculate(s)``  — SSM side: ingest the <=2 newly committed tokens it
                      has not seen (delta), then autoregressively draft
                      ``s`` tokens with a ``lax.scan``.

State contract with the Rust coordinator (see DESIGN.md):

* the KV cache is an explicit parameter/result ``f32[L, 2, B, H, S_max, Dh]``
  that stays resident on device between calls (``execute_b``);
* ``lens[b]`` is the number of *ingested* cache entries of row ``b``; the
  forward writes the T in-flight tokens at positions ``lens..lens+T-1`` and
  masks attention with ``pos <= lens + i``.  Rejected speculations leave
  stale entries above the committed length, which are (a) never attended
  and (b) overwritten by the next call — no rollback pass is needed.

Weights are *runtime parameters* (stacked per-layer tensors, ~20 arrays),
so the HLO text stays small and one executable serves any checkpoint.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.argmax import vocab_argmax
from .kernels.attention import verify_attention
from .kernels.ref import verify_attention_ref, vocab_argmax_ref

Weights = Dict[str, jax.Array]

# Deterministic parameter order of the AOT calling convention.  The Rust
# manifest replicates this list; never reorder without bumping the
# format_version in configs.config_fingerprint.
WEIGHT_ORDER = (
    "embed",        # [V, D]
    "pos_embed",    # [S_max, D]
    "ln1_scale",    # [L, D]
    "ln1_bias",     # [L, D]
    "wq", "bq",     # [L, D, D], [L, D]
    "wk", "bk",
    "wv", "bv",
    "wo", "bo",
    "ln2_scale",    # [L, D]
    "ln2_bias",
    "w_up", "b_up",     # [L, D, F], [L, F]
    "w_down", "b_down",  # [L, F, D], [L, D]
    "lnf_scale",    # [D]
    "lnf_bias",     # [D]
)


def weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Shape table of the stacked weight tensors, in WEIGHT_ORDER."""
    v, d, l, f, s = cfg.vocab, cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.max_seq
    return {
        "embed": (v, d),
        "pos_embed": (s, d),
        "ln1_scale": (l, d),
        "ln1_bias": (l, d),
        "wq": (l, d, d), "bq": (l, d),
        "wk": (l, d, d), "bk": (l, d),
        "wv": (l, d, d), "bv": (l, d),
        "wo": (l, d, d), "bo": (l, d),
        "ln2_scale": (l, d),
        "ln2_bias": (l, d),
        "w_up": (l, d, f), "b_up": (l, f),
        "w_down": (l, f, d), "b_down": (l, d),
        "lnf_scale": (d,),
        "lnf_bias": (d,),
    }


def init_weights(cfg: ModelConfig, key: jax.Array) -> Weights:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    shapes = weight_shapes(cfg)
    w: Weights = {}
    n_resid = 2 * cfg.n_layers  # residual-write matrices: wo, w_down
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(shapes.items(), keys):
        if name.startswith(("b", "ln1_bias", "ln2_bias", "lnf_bias")):
            w[name] = jnp.zeros(shape, jnp.float32)
        elif "scale" in name:
            w[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if name in ("wo", "w_down"):
                std = 0.02 / (n_resid ** 0.5)
            w[name] = std * jax.random.normal(k, shape, jnp.float32)
    return w


def _layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _split_heads(x: jax.Array, n_heads: int, d_head: int) -> jax.Array:
    """[B, T, D] -> [B, H, T, Dh]"""
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    """[B, H, T, Dh] -> [B, T, D]"""
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _write_kv(
    cache: jax.Array,   # [B, H, S_max, Dh] one layer, one of k/v
    new: jax.Array,     # [B, H, T, Dh]
    lens: jax.Array,    # [B] i32
) -> jax.Array:
    """Write the T new entries of each row at positions lens..lens+T-1.

    Windowed write: a vmapped ``dynamic_update_slice`` touches only the T
    slots per row.  (The original masked-gather formulation rewrote the
    whole cache — ~4 full passes over [B,H,S_max,Dh] per layer side — and
    dominated the verify step at large batch; see EXPERIMENTS.md §Perf,
    ~6x end-to-end.)  DUS clamps the start index into range; the engine's
    capacity check guarantees lens + T <= S_max so clamping never fires in
    practice.
    """

    def row_update(c, n, start):
        # c [H, S_max, Dh], n [H, T, Dh]
        return jax.lax.dynamic_update_slice(c, n, (0, start, 0))

    return jax.vmap(row_update)(cache, new, lens)


def forward_tokens(
    w: Weights,
    cfg: ModelConfig,
    tokens: jax.Array,   # i32 [B, T] the T in-flight tokens per row
    lens: jax.Array,     # i32 [B]   ingested cache entries per row
    kv: jax.Array,       # f32 [L, 2, B, H, S_max, Dh]
    *,
    use_kernels: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """One decoder pass over T in-flight tokens with cache update.

    Returns ``(pred i32[B, T], kv')`` where ``pred[b, i]`` is the argmax
    next-token prediction at absolute position ``lens[b] + i``.
    """
    b, t = tokens.shape
    attn = verify_attention if use_kernels else verify_attention_ref
    amax = vocab_argmax if use_kernels else vocab_argmax_ref

    positions = lens[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    positions = jnp.clip(positions, 0, cfg.max_seq - 1)
    x = w["embed"][tokens] + w["pos_embed"][positions]          # [B, T, D]

    for layer in range(cfg.n_layers):
        h = _layernorm(x, w["ln1_scale"][layer], w["ln1_bias"][layer])
        q = _split_heads(h @ w["wq"][layer] + w["bq"][layer], cfg.n_heads, cfg.d_head)
        k_new = _split_heads(h @ w["wk"][layer] + w["bk"][layer], cfg.n_heads, cfg.d_head)
        v_new = _split_heads(h @ w["wv"][layer] + w["bv"][layer], cfg.n_heads, cfg.d_head)

        k_cache = _write_kv(kv[layer, 0], k_new, lens)
        v_cache = _write_kv(kv[layer, 1], v_new, lens)
        kv = kv.at[layer, 0].set(k_cache).at[layer, 1].set(v_cache)

        ctx = attn(q, k_cache, v_cache, lens)                   # [B, H, T, Dh]
        x = x + _merge_heads(ctx) @ w["wo"][layer] + w["bo"][layer]

        h = _layernorm(x, w["ln2_scale"][layer], w["ln2_bias"][layer])
        h = jax.nn.gelu(h @ w["w_up"][layer] + w["b_up"][layer])
        x = x + h @ w["w_down"][layer] + w["b_down"][layer]

    x = _layernorm(x, w["lnf_scale"], w["lnf_bias"])
    logits = x @ w["embed"].T                                   # tied head
    pred = amax(logits)                                         # i32 [B, T]
    return pred, kv


def forward_train(w: Weights, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Training forward: full causal attention, no cache, returns logits.

    Uses the jnp reference kernels (training never runs on the request
    path); numerics match forward_tokens on the same committed prefix,
    which test_model.py asserts.
    """
    b, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    x = w["embed"][tokens] + w["pos_embed"][positions]
    zero = jnp.zeros((b,), jnp.int32)

    for layer in range(cfg.n_layers):
        h = _layernorm(x, w["ln1_scale"][layer], w["ln1_bias"][layer])
        q = _split_heads(h @ w["wq"][layer] + w["bq"][layer], cfg.n_heads, cfg.d_head)
        k = _split_heads(h @ w["wk"][layer] + w["bk"][layer], cfg.n_heads, cfg.d_head)
        v = _split_heads(h @ w["wv"][layer] + w["bv"][layer], cfg.n_heads, cfg.d_head)
        # lens = 0 and S_max = T turns the verify mask into plain causal
        ctx = verify_attention_ref(q, k, v, zero)
        x = x + _merge_heads(ctx) @ w["wo"][layer] + w["bo"][layer]
        h = _layernorm(x, w["ln2_scale"][layer], w["ln2_bias"][layer])
        h = jax.nn.gelu(h @ w["w_up"][layer] + w["b_up"][layer])
        x = x + h @ w["w_down"][layer] + w["b_down"][layer]

    x = _layernorm(x, w["lnf_scale"], w["lnf_bias"])
    return x @ w["embed"].T


# ---------------------------------------------------------------------------
# AOT entry points (the executable matrix)
# ---------------------------------------------------------------------------

def _weights_from_args(wlist) -> Weights:
    return dict(zip(WEIGHT_ORDER, wlist))


def make_prefill(cfg: ModelConfig, batch: int, *, use_kernels: bool = True):
    """prefill: (tokens i32[B,P], plens i32[B], kv, *W) -> (last i32[B], kv').

    ``tokens`` is the prompt padded to P = max_prompt; ``plens`` the true
    prompt lengths.  Writes KV for all P positions (stale tail above plens
    is overwritten by generation) and gathers the prediction at each row's
    last real prompt token.
    """

    def prefill(tokens, plens, kv, *wlist):
        w = _weights_from_args(wlist)
        zero = jnp.zeros((batch,), jnp.int32)
        pred, kv = forward_tokens(w, cfg, tokens, zero, kv, use_kernels=use_kernels)
        last = jnp.take_along_axis(
            pred, jnp.clip(plens[:, None] - 1, 0, cfg.max_prompt - 1), axis=1
        )[:, 0]
        return last, kv

    return prefill


def make_verify(cfg: ModelConfig, batch: int, s: int, *, use_kernels: bool = True):
    """verify(s): (tokens i32[B,s+1], lens i32[B], kv, *W) -> (pred, kv').

    ``tokens[:, 0]`` is the last committed-but-not-ingested token, the rest
    are the s draft tokens.  ``pred[:, i]`` is argmax(o_i): the model's
    next-token choice after position i.  s = 0 is the plain decode step.
    """

    def verify(tokens, lens, kv, *wlist):
        w = _weights_from_args(wlist)
        return forward_tokens(w, cfg, tokens, lens, kv, use_kernels=use_kernels)

    return verify


def make_speculate(cfg: ModelConfig, batch: int, s: int, *, use_kernels: bool = True):
    """speculate(s): (delta i32[B,2], dlens i32[B], lens i32[B], kv, *W)
    -> (draft i32[B,s], kv').

    Ingests the ``dlens`` (1 or 2) newly committed tokens the SSM has not
    seen, whose first prediction is draft token d_1, then drafts the
    remaining s-1 tokens autoregressively under a ``lax.scan``.
    """

    def speculate(delta, dlens, lens, kv, *wlist):
        w = _weights_from_args(wlist)
        # ingest the delta (T=2 padded; rows with dlens==1 write one stale
        # slot above their new length, overwritten by the scan below)
        pred, kv = forward_tokens(w, cfg, delta, lens, kv, use_kernels=use_kernels)
        d1 = jnp.take_along_axis(
            pred, jnp.clip(dlens[:, None] - 1, 0, 1), axis=1
        )[:, 0]                                                # [B]
        cur_len = lens + dlens

        def step(carry, _):
            tok, cur_len, kv = carry
            pred, kv = forward_tokens(
                w, cfg, tok[:, None], cur_len, kv, use_kernels=use_kernels
            )
            nxt = pred[:, 0]
            return (nxt, cur_len + 1, kv), nxt

        if s > 1:
            (_, _, kv), rest = jax.lax.scan(
                step, (d1, cur_len, kv), None, length=s - 1
            )
            draft = jnp.concatenate([d1[:, None], rest.T], axis=1)  # [B, s]
        else:
            draft = d1[:, None]
        return draft, kv

    return speculate
