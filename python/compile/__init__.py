"""specbatch compile path (build-time only, never on the request path).

Layers:
  * ``kernels``  — L1 Pallas kernels + jnp oracles
  * ``model``    — L2 OPT-style decoder with functional KV cache
  * ``corpus``   — synthetic Markov instruction corpus + vocab + dataset
  * ``train``    — brief Adam training of the LLM/SSM pair
  * ``aot``      — lowers the executable matrix to HLO text + weights
"""
