"""Synthetic Markov "chatbot instruction" corpus, vocabulary and dataset.

Substitute for the paper's *Chatbot Instruction Prompts* HuggingFace
dataset (no network in this environment; DESIGN.md §Substitutions).  The
dataset's role in the paper is to provide (i) realistic prompt lengths and
(ii) text whose predictability lets the SSM track the LLM — both are
reproduced here by a first-order Markov chain over a 512-word vocabulary:

* **easy states** (peaky next-token distribution) — both models learn the
  argmax transition and agree, like boilerplate natural language;
* **hard states** (near-uniform over many successors) — the models'
  argmaxes diverge, like content words.

The easy/hard mix controls the per-token acceptance probability and hence
the shape of l(s); the measured curve stays sublinear-power (Fig. 2).

Everything is deterministic given SEED so `make artifacts` is reproducible
and the profiling/eval splits are stable across Python and Rust.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import List

import numpy as np

from .configs import VOCAB_SIZE

SEED = 20231003  # arXiv submission date of the paper, for flavour

PAD, BOS, EOS, UNK = 0, 1, 2, 3
N_SPECIAL = 4

# a small English word list for readable prompts; the rest of the vocab is
# synthetic "tok###" words.
_BASE_WORDS = """
write a short story about the history of machine learning and explain how
it works in simple terms please describe what makes large language models
fast when serving many users at once summarize this article for me list
three ways to improve inference latency on modern hardware tell us why
speculative decoding helps small batch sizes compare batching strategies
for transformer models give an example of adaptive scheduling policies
draft an email to my team about the new deployment plan translate the
following sentence into french outline the main ideas behind attention
caches what is the best way to learn systems research today
""".split()

HARD_FRACTION = 0.25     # fraction of states with near-uniform successors
EASY_TOPK = 6            # successor fan-out of easy states
HARD_TOPK = 48           # successor fan-out of hard states
EASY_PROBS = np.array([0.62, 0.16, 0.09, 0.06, 0.04, 0.03])

N_OPENERS = 24           # states that can start a prompt


@dataclass
class Corpus:
    vocab: List[str]            # id -> text
    trans_next: np.ndarray      # [V, HARD_TOPK] successor ids (padded)
    trans_prob: np.ndarray      # [V, HARD_TOPK] successor probabilities
    openers: np.ndarray         # [N_OPENERS] opener state ids
    hard_mask: np.ndarray       # [V] bool: True for hard states

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)


def build_vocab() -> List[str]:
    vocab = ["<pad>", "<bos>", "<eos>", "<unk>"]
    seen = set(vocab)
    for wrd in _BASE_WORDS:
        if wrd not in seen:
            vocab.append(wrd)
            seen.add(wrd)
    i = 0
    while len(vocab) < VOCAB_SIZE:
        vocab.append(f"tok{i:03d}")
        i += 1
    return vocab[:VOCAB_SIZE]


def build_corpus(seed: int = SEED) -> Corpus:
    rng = np.random.default_rng(seed)
    vocab = build_vocab()
    v = len(vocab)

    trans_next = np.zeros((v, HARD_TOPK), dtype=np.int32)
    trans_prob = np.zeros((v, HARD_TOPK), dtype=np.float64)
    hard_mask = np.zeros(v, dtype=bool)

    content = np.arange(N_SPECIAL, v, dtype=np.int32)
    for state in range(v):
        hard = rng.random() < HARD_FRACTION
        hard_mask[state] = hard
        k = HARD_TOPK if hard else EASY_TOPK
        succ = rng.choice(content, size=k, replace=False)
        if hard:
            # near-uniform with mild random tilt
            p = rng.random(k) * 0.2 + 1.0
            p /= p.sum()
        else:
            p = EASY_PROBS.copy()
        trans_next[state, :k] = succ
        trans_prob[state, :k] = p

    openers = rng.choice(content, size=N_OPENERS, replace=False)
    return Corpus(vocab, trans_next, trans_prob, openers, hard_mask)


def sample_walk(corpus: Corpus, rng: np.random.Generator, length: int,
                start: int | None = None) -> np.ndarray:
    """Sample a Markov walk of `length` tokens (the start token included)."""
    if start is None:
        start = int(rng.choice(corpus.openers))
    out = np.empty(length, dtype=np.int32)
    state = start
    out[0] = state
    for i in range(1, length):
        nxt = corpus.trans_next[state]
        p = corpus.trans_prob[state]
        state = int(rng.choice(nxt, p=p))
        out[i] = state
    return out


def sample_training_batch(corpus: Corpus, rng: np.random.Generator,
                          batch: int, seq: int) -> np.ndarray:
    """[batch, seq] i32 token matrix of independent walks (BOS-prefixed)."""
    rows = np.empty((batch, seq), dtype=np.int32)
    for b in range(batch):
        rows[b, 0] = BOS
        rows[b, 1:] = sample_walk(corpus, rng, seq - 1)
    return rows


@dataclass
class Prompt:
    ids: List[int]
    text: str
    split: str  # "profile" | "eval"


def build_dataset(corpus: Corpus, *, n_profile: int = 500, n_eval: int = 1500,
                  min_len: int = 4, max_len: int = 24,
                  seed: int = SEED + 1) -> List[Prompt]:
    """Prompt set with disjoint profiling/eval splits (paper Sec. 5.3 keeps
    the adaptive scheme's profiling prompts disjoint from evaluation)."""
    rng = np.random.default_rng(seed)
    prompts: List[Prompt] = []
    total = n_profile + n_eval
    for i in range(total):
        ln = int(rng.integers(min_len, max_len + 1))
        ids = [BOS] + sample_walk(corpus, rng, ln).tolist()
        text = " ".join(corpus.vocab[t] for t in ids[1:])
        split = "profile" if i < n_profile else "eval"
        prompts.append(Prompt(ids=ids, text=text, split=split))
    return prompts


def write_dataset(path: str, corpus: Corpus, prompts: List[Prompt]) -> None:
    """Emit the vocab + prompt dataset consumed by the Rust coordinator."""
    payload = {
        "seed": SEED,
        "vocab": corpus.vocab,
        "special": {"pad": PAD, "bos": BOS, "eos": EOS, "unk": UNK},
        "prompts": [
            {"ids": p.ids, "text": p.text, "split": p.split} for p in prompts
        ],
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


def oracle_argmax_walk(corpus: Corpus, start: int, length: int) -> np.ndarray:
    """The deterministic argmax continuation of the chain itself — handy in
    tests as an upper bound on what a perfectly trained model would emit."""
    out = np.empty(length, dtype=np.int32)
    state = start
    for i in range(length):
        state = int(corpus.trans_next[state][np.argmax(corpus.trans_prob[state])])
        out[i] = state
    return out
