"""AOT compile driver: corpus -> training -> HLO text + weights + manifest.

Runs once at build time (`make artifacts`); the Rust coordinator is fully
self-contained afterwards.  Outputs under ``artifacts/``:

* ``manifest.json``        — models, weight tables, executable matrix,
                             calling convention; written last (atomicity
                             marker: its presence means the build is whole)
* ``weights_{llm,ssm}.bin`` — flat little-endian f32 in WEIGHT_ORDER
* ``<exe>.hlo.txt``        — one HLO-text module per (model, kind, b, s)
* ``dataset.json``         — vocab + prompt set (profile/eval splits)
* ``goldens.json``         — greedy continuations for Rust integration tests
* ``cache/``               — trained-weight cache keyed by config fingerprint

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from . import corpus as corpus_mod
from . import engine_ref, train
from .configs import (
    LLM_CONFIG,
    SSM_CONFIG,
    ArtifactProfile,
    ModelConfig,
    active_profile,
    config_fingerprint,
    weights_fingerprint,
)
from .model import (
    WEIGHT_ORDER,
    Weights,
    make_prefill,
    make_speculate,
    make_verify,
    weight_shapes,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _weight_sds(cfg: ModelConfig):
    shapes = weight_shapes(cfg)
    return [_sds(shapes[name], np.float32) for name in WEIGHT_ORDER]


def lower_executable(kind: str, cfg: ModelConfig, batch: int, s: int) -> str:
    """Lower one executable to HLO text.  Param order is the calling
    convention recorded in the manifest."""
    i32, f32 = np.int32, np.float32
    kv = _sds(cfg.kv_shape(batch), f32)
    w = _weight_sds(cfg)
    if kind == "prefill":
        fn = make_prefill(cfg, batch)
        args = (_sds((batch, cfg.max_prompt), i32), _sds((batch,), i32), kv)
    elif kind == "verify":
        fn = make_verify(cfg, batch, s)
        args = (_sds((batch, s + 1), i32), _sds((batch,), i32), kv)
    elif kind == "speculate":
        fn = make_speculate(cfg, batch, s)
        args = (_sds((batch, 2), i32), _sds((batch,), i32), _sds((batch,), i32), kv)
    else:
        raise ValueError(kind)
    lowered = jax.jit(fn).lower(*args, *w)
    return to_hlo_text(lowered)


def executable_matrix(profile: ArtifactProfile):
    """Yield (name, kind, cfg, batch, s) for every executable to lower."""
    for b in profile.batch_buckets:
        yield f"llm_prefill_b{b}", "prefill", LLM_CONFIG, b, 0
        yield f"ssm_prefill_b{b}", "prefill", SSM_CONFIG, b, 0
        for s in profile.verify_lengths:
            yield f"llm_verify_b{b}_s{s}", "verify", LLM_CONFIG, b, s
        for s in profile.speculate_lengths:
            yield f"ssm_speculate_b{b}_s{s}", "speculate", SSM_CONFIG, b, s
    for b, s in profile.extra_verify:
        yield f"llm_verify_b{b}_s{s}", "verify", LLM_CONFIG, b, s
    for b, s in profile.extra_speculate:
        yield f"ssm_speculate_b{b}_s{s}", "speculate", SSM_CONFIG, b, s


def export_weights(path: str, w: Weights, cfg: ModelConfig):
    """Flat little-endian f32 blob in WEIGHT_ORDER; returns the table."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for name in WEIGHT_ORDER:
            arr = np.asarray(w[name], dtype="<f4")
            expect = weight_shapes(cfg)[name]
            if tuple(arr.shape) != tuple(expect):
                raise AssertionError(f"{name}: {arr.shape} != {expect}")
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            offset += arr.size * 4
    return table, offset


def _get_weights(cfg: ModelConfig, corpus, profile: ArtifactProfile,
                 cache_dir: str, fingerprint: str, log=print) -> Weights:
    # fingerprint here is the weights-only fingerprint: lowering changes
    # do not invalidate the training cache
    steps = (
        profile.train_steps_llm if cfg.name == "llm" else profile.train_steps_ssm
    )
    cache = os.path.join(cache_dir, f"{cfg.name}_{fingerprint}.npz")  # noqa: F841 (kept name)
    if os.path.exists(cache):
        log(f"[aot] cached weights: {cache}")
        return train.load_weights_npz(cache)
    w = train.train_model(
        cfg, corpus, steps,
        batch=profile.train_batch, seq=profile.train_seq,
        seed=0 if cfg.name == "llm" else 1, log=log,
    )
    os.makedirs(cache_dir, exist_ok=True)
    train.save_weights_npz(cache, w)
    return w


def write_goldens(path: str, w_llm, w_ssm, prompts, *, n_new=24, log=print):
    """Greedy continuations + a spec-equals-greedy cross-check, consumed by
    the Rust integration tests."""
    ids = [p.ids for p in prompts]
    greedy = engine_ref.greedy_generate(w_llm, LLM_CONFIG, ids, n_new)
    spec = engine_ref.spec_generate(
        w_llm, LLM_CONFIG, w_ssm, SSM_CONFIG, ids, n_new, s=3
    )
    if spec != greedy:
        raise AssertionError("speculative decode diverged from greedy decode")
    payload = {
        "n_new": n_new,
        "cases": [
            {"prompt": p, "greedy": g} for p, g in zip(ids, greedy)
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    log(f"[aot] goldens: {len(ids)} prompts x {n_new} tokens (spec == greedy)")


def build(out_dir: str, profile: ArtifactProfile, log=print) -> None:
    t_start = time.time()
    os.makedirs(out_dir, exist_ok=True)
    fingerprint = config_fingerprint(profile)
    manifest_path = os.path.join(out_dir, "manifest.json")

    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            if json.load(f).get("fingerprint") == fingerprint:
                log(f"[aot] artifacts up to date (fingerprint {fingerprint})")
                return

    log(f"[aot] profile={profile.name} fingerprint={fingerprint}")
    corpus = corpus_mod.build_corpus()
    prompts = corpus_mod.build_dataset(corpus)
    corpus_mod.write_dataset(os.path.join(out_dir, "dataset.json"), corpus, prompts)
    log(f"[aot] dataset: {len(prompts)} prompts")

    cache_dir = os.path.join(out_dir, "cache")
    w_fp = weights_fingerprint(profile)
    w_llm = _get_weights(LLM_CONFIG, corpus, profile, cache_dir, w_fp, log)
    w_ssm = _get_weights(SSM_CONFIG, corpus, profile, cache_dir, w_fp, log)
    agree = train.agreement_rate(w_llm, LLM_CONFIG, w_ssm, SSM_CONFIG, corpus)
    log(f"[aot] SSM/LLM argmax agreement on held-out text: {agree:.3f}")

    models = {}
    for cfg, w in ((LLM_CONFIG, w_llm), (SSM_CONFIG, w_ssm)):
        fname = f"weights_{cfg.name}.bin"
        table, nbytes = export_weights(os.path.join(out_dir, fname), w, cfg)
        models[cfg.name] = {
            "config": cfg.to_json(),
            "weights_file": fname,
            "weights_bytes": nbytes,
            "weights": table,
            "n_params": cfg.n_params(),
        }
        log(f"[aot] {fname}: {nbytes / 1e6:.1f} MB")

    write_goldens(
        os.path.join(out_dir, "goldens.json"), w_llm, w_ssm,
        [p for p in prompts if p.split == "eval"][:4], log=log,
    )

    exes = []
    matrix = list(executable_matrix(profile))
    for i, (name, kind, cfg, b, s) in enumerate(matrix):
        t0 = time.time()
        text = lower_executable(kind, cfg, b, s)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        exes.append(
            {
                "name": name,
                "file": fname,
                "model": cfg.name,
                "kind": kind,
                "batch": b,
                "s": s,
            }
        )
        log(
            f"[aot] [{i + 1}/{len(matrix)}] {fname} "
            f"({len(text) / 1e3:.0f} kB, {time.time() - t0:.1f}s)"
        )

    manifest = {
        "fingerprint": fingerprint,
        "profile": profile.name,
        "format_version": 3,
        "weight_order": list(WEIGHT_ORDER),
        "calling_convention": {
            "prefill": ["tokens[B,P]i32", "plens[B]i32", "kv f32", "*weights"],
            "verify": ["tokens[B,s+1]i32", "lens[B]i32", "kv f32", "*weights"],
            "speculate": [
                "delta[B,2]i32", "dlens[B]i32", "lens[B]i32", "kv f32", "*weights",
            ],
            "outputs": "(pred i32, kv' f32) as a 2-tuple",
        },
        "models": models,
        "executables": exes,
        "batch_buckets": list(profile.batch_buckets),
        "verify_lengths": list(profile.verify_lengths),
        "speculate_lengths": list(profile.speculate_lengths),
        "dataset": "dataset.json",
        "goldens": "goldens.json",
        "agreement_rate": agree,
    }
    tmp = manifest_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, manifest_path)
    log(f"[aot] done: {len(exes)} executables in {time.time() - t_start:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--profile",
        default=None,
        choices=["full", "quick"],
        help="artifact profile (default: $SPECBATCH_PROFILE or full)",
    )
    args = ap.parse_args()
    profile = (
        active_profile()
        if args.profile is None
        else __import__(
            "compile.configs", fromlist=["PROFILES"]
        ).PROFILES[args.profile]
    )
    build(os.path.abspath(args.out), profile)


if __name__ == "__main__":
    sys.exit(main())
