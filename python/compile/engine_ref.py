"""Python reference implementation of the batched speculative engine.

This mirrors, at jnp level, exactly the state machine the Rust coordinator
runs against the AOT executables (same lens accounting, same acceptance
rule).  It serves three purposes:

1. **Correctness oracle** — greedy speculative decoding is *lossless*: its
   output must equal plain greedy decoding token-for-token (Algorithm 1).
   pytest asserts this across batch sizes and speculation lengths.
2. **Golden traces** — aot.py dumps `goldens.json` (prompt -> greedy
   continuation) that the Rust integration tests compare against, proving
   the HLO executables + Rust engine reproduce the Python semantics.
3. **Acceptance measurement** — the Eq. 4 estimator of l(s) used to
   pre-validate the Fig. 2 shape at build time.

State contract (shared with Rust, see model.py docstring): per row,
``committed`` is the list of known tokens; ``ingested = len(committed)-1``
KV entries are valid; each forward ingests the in-flight tokens starting at
``ingested``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .model import Weights, make_prefill, make_speculate, make_verify

PAD = 0


# jit-compiled entry points, cached per (cfg, batch, s, kernels) so the
# reference engine's inner loop does not re-trace on every call
@lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig, batch: int, use_kernels: bool):
    return jax.jit(make_prefill(cfg, batch, use_kernels=use_kernels))


@lru_cache(maxsize=None)
def _jit_verify(cfg: ModelConfig, batch: int, s: int, use_kernels: bool):
    return jax.jit(make_verify(cfg, batch, s, use_kernels=use_kernels))


@lru_cache(maxsize=None)
def _jit_speculate(cfg: ModelConfig, batch: int, s: int, use_kernels: bool):
    return jax.jit(make_speculate(cfg, batch, s, use_kernels=use_kernels))


def _pad_prompts(prompts: List[List[int]], batch: int, width: int):
    toks = np.full((batch, width), PAD, dtype=np.int32)
    lens = np.zeros(batch, dtype=np.int32)
    for i, p in enumerate(prompts):
        if len(p) > width:
            raise ValueError(f"prompt {i} longer than max_prompt ({len(p)} > {width})")
        toks[i, : len(p)] = p
        lens[i] = len(p)
    return jnp.asarray(toks), jnp.asarray(lens)


@dataclass
class ModelState:
    """One model's device state for a batch (KV cache + ingest counters)."""

    cfg: ModelConfig
    weights: Weights
    kv: jnp.ndarray
    ingested: np.ndarray  # [B] i64 valid KV entries per row

    @classmethod
    def fresh(cls, cfg: ModelConfig, weights: Weights, batch: int) -> "ModelState":
        kv = jnp.zeros(cfg.kv_shape(batch), jnp.float32)
        return cls(cfg, weights, kv, np.zeros(batch, dtype=np.int64))


@dataclass
class BatchSession:
    """Committed tokens of each row (prompt + generated)."""

    prompts: List[List[int]]
    committed: List[List[int]] = field(default_factory=list)

    def __post_init__(self):
        if not self.committed:
            self.committed = [list(p) for p in self.prompts]

    def generated(self, row: int) -> List[int]:
        return self.committed[row][len(self.prompts[row]):]


def _wlist(w: Weights):
    from .model import WEIGHT_ORDER

    return [w[k] for k in WEIGHT_ORDER]


def prefill(state: ModelState, session: BatchSession, *, use_kernels=False):
    """Run prefill; commits the first generated token on every row."""
    batch = len(session.prompts)
    fn = _jit_prefill(state.cfg, batch, use_kernels)
    toks, plens = _pad_prompts(session.prompts, batch, state.cfg.max_prompt)
    last, state.kv = fn(toks, plens, state.kv, *_wlist(state.weights))
    last = np.asarray(last)
    for i in range(batch):
        session.committed[i].append(int(last[i]))
        state.ingested[i] = len(session.committed[i]) - 1
    return last


def ssm_sync_prefill(state: ModelState, session: BatchSession, *, use_kernels=False):
    """Prefill the SSM on the prompt only (its prediction is discarded —
    the LLM already committed the first token; the SSM just needs KV)."""
    batch = len(session.prompts)
    fn = _jit_prefill(state.cfg, batch, use_kernels)
    toks, plens = _pad_prompts(session.prompts, batch, state.cfg.max_prompt)
    _, state.kv = fn(toks, plens, state.kv, *_wlist(state.weights))
    for i in range(batch):
        state.ingested[i] = len(session.prompts[i])


def verify_step(state: ModelState, session: BatchSession,
                drafts: np.ndarray, *, use_kernels=False) -> np.ndarray:
    """LLM verification of `s` draft tokens per row; returns accepted counts.

    Feeds [last_committed, d_1..d_s]; pred[i] is the model's choice after
    position i.  Acceptance: first index where draft != pred truncates; the
    prediction at the truncation point is the bonus/correction token.
    """
    batch, s = drafts.shape
    fn = _jit_verify(state.cfg, batch, s, use_kernels)
    feed = np.empty((batch, s + 1), dtype=np.int32)
    lens = np.empty(batch, dtype=np.int32)
    for i in range(batch):
        feed[i, 0] = session.committed[i][-1]
        feed[i, 1:] = drafts[i]
        lens[i] = state.ingested[i]
    pred, state.kv = fn(jnp.asarray(feed), jnp.asarray(lens), state.kv,
                        *_wlist(state.weights))
    pred = np.asarray(pred)

    accepted = np.zeros(batch, dtype=np.int64)
    for i in range(batch):
        a = 0
        while a < s and drafts[i, a] == pred[i, a]:
            a += 1
        accepted[i] = a
        new = [int(t) for t in drafts[i, :a]] + [int(pred[i, a])]
        session.committed[i].extend(new)
        state.ingested[i] = len(session.committed[i]) - 1
    return accepted


def speculate_step(state: ModelState, session: BatchSession, s: int,
                   *, use_kernels=False) -> np.ndarray:
    """SSM drafts `s` tokens per row after ingesting its committed delta."""
    batch = len(session.prompts)
    fn = _jit_speculate(state.cfg, batch, s, use_kernels)
    delta = np.full((batch, 2), PAD, dtype=np.int32)
    dlens = np.empty(batch, dtype=np.int32)
    lens = np.empty(batch, dtype=np.int32)
    for i in range(batch):
        missing = session.committed[i][state.ingested[i]:]
        if not 1 <= len(missing) <= 2:
            raise AssertionError(
                f"SSM delta invariant violated: row {i} missing {len(missing)}"
            )
        delta[i, : len(missing)] = missing
        dlens[i] = len(missing)
        lens[i] = state.ingested[i]
    draft, state.kv = fn(jnp.asarray(delta), jnp.asarray(dlens),
                         jnp.asarray(lens), state.kv, *_wlist(state.weights))
    for i in range(batch):
        # delta rows fully ingested; drafts d_1..d_{s-1} ingested by the scan
        state.ingested[i] = int(lens[i]) + int(dlens[i]) + max(0, s - 1)
    return np.asarray(draft)


def ssm_rollback(state: ModelState, session: BatchSession) -> None:
    """Clamp SSM ingest counters after verification rejected some drafts.

    Stale KV entries above the clamped length are never attended and are
    overwritten by the next ingest — mirror of the Rust engine."""
    for i in range(len(session.prompts)):
        state.ingested[i] = min(state.ingested[i], len(session.committed[i]) - 1)


def greedy_generate(w: Weights, cfg: ModelConfig, prompts: List[List[int]],
                    n_new: int, *, use_kernels=False) -> List[List[int]]:
    """Plain autoregressive greedy decoding — the ground truth that
    speculative decoding must reproduce exactly."""
    batch = len(prompts)
    session = BatchSession(prompts)
    state = ModelState.fresh(cfg, w, batch)
    prefill(state, session, use_kernels=use_kernels)
    for _ in range(n_new - 1):
        drafts = np.zeros((batch, 0), dtype=np.int32)
        # s=0 verify == plain decode: feed only the last committed token
        verify_step(state, session, drafts, use_kernels=use_kernels)
    return [session.generated(i)[:n_new] for i in range(batch)]


def spec_generate(
    w_llm: Weights, cfg_llm: ModelConfig,
    w_ssm: Weights, cfg_ssm: ModelConfig,
    prompts: List[List[int]], n_new: int, s: int,
    *, use_kernels=False, record_accepts: list | None = None,
) -> List[List[int]]:
    """Batched speculative decoding (Algorithm 1, batched, greedy)."""
    batch = len(prompts)
    session = BatchSession(prompts)
    llm = ModelState.fresh(cfg_llm, w_llm, batch)
    ssm = ModelState.fresh(cfg_ssm, w_ssm, batch)
    prefill(llm, session, use_kernels=use_kernels)
    ssm_sync_prefill(ssm, session, use_kernels=use_kernels)

    while min(len(session.generated(i)) for i in range(batch)) < n_new:
        drafts = speculate_step(ssm, session, s, use_kernels=use_kernels)
        acc = verify_step(llm, session, drafts, use_kernels=use_kernels)
        ssm_rollback(ssm, session)
        if record_accepts is not None:
            record_accepts.append(acc.copy())
    return [session.generated(i)[:n_new] for i in range(batch)]


def measure_acceptance(
    w_llm: Weights, cfg_llm: ModelConfig,
    w_ssm: Weights, cfg_ssm: ModelConfig,
    prompts: List[List[int]], *, s: int = 8, rounds: int = 12,
) -> np.ndarray:
    """Per-round accepted counts for the Eq. 4 estimator of l(s)."""
    accepts: list = []
    spec_generate(
        w_llm, cfg_llm, w_ssm, cfg_ssm, prompts,
        n_new=rounds * (s + 1), s=s, record_accepts=accepts,
    )
    return np.concatenate([a for a in accepts]) if accepts else np.zeros(0)


def l_of_s(accepted_samples: np.ndarray, s_max: int) -> np.ndarray:
    """Eq. 4: l(s) ~= mean(min(l_i, s)) for s = 1..s_max."""
    return np.array(
        [np.minimum(accepted_samples, s).mean() for s in range(1, s_max + 1)]
    )
