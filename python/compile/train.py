"""Brief build-time training of the LLM/SSM pair on the Markov corpus.

Speculative decoding only exhibits the paper's acceptance behaviour when
the draft model genuinely mimics the target model.  Random weights would
give l(s) ~= 0; instead `make artifacts` trains both models for a few
hundred Adam steps on the synthetic corpus (~1-2 minutes on CPU), after
which the SSM reproduces the LLM's argmax on "easy" states and diverges on
"hard" ones — the same mechanism as OPT-125M drafting for OPT-6.7B.

Adam is hand-rolled (no optax in this environment).  Everything is jitted
once and runs at build time only.
"""

from __future__ import annotations

import time
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from .configs import ModelConfig
from .model import Weights, forward_train, init_weights

AdamState = Tuple[Weights, Weights, jax.Array]  # (m, v, step)


def adam_init(w: Weights) -> AdamState:
    zeros = {k: jnp.zeros_like(x) for k, x in w.items()}
    return zeros, {k: jnp.zeros_like(x) for k, x in w.items()}, jnp.zeros((), jnp.int32)


def adam_update(
    w: Weights, grads: Weights, state: AdamState,
    lr: float = 3e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
) -> Tuple[Weights, AdamState]:
    m, v, step = state
    step = step + 1
    t = step.astype(jnp.float32)
    new_w, new_m, new_v = {}, {}, {}
    for k in w:
        new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
        new_v[k] = b2 * v[k] + (1 - b2) * grads[k] ** 2
        m_hat = new_m[k] / (1 - b1 ** t)
        v_hat = new_v[k] / (1 - b2 ** t)
        new_w[k] = w[k] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return new_w, (new_m, new_v, step)


def loss_fn(w: Weights, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy over the batch (no padding in training
    batches, so no masking needed)."""
    logits = forward_train(w, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


@partial(jax.jit, static_argnums=(3,))
def _train_step(w: Weights, state: AdamState, tokens: jax.Array,
                cfg: ModelConfig, lr: jax.Array) -> Tuple[Weights, AdamState, jax.Array]:
    loss, grads = jax.value_and_grad(loss_fn)(w, cfg, tokens)
    w, state = adam_update(w, grads, state, lr=lr)
    return w, state, loss


def lr_schedule(step: int, steps: int, peak: float = 1e-2, warmup: int = 20) -> float:
    """Linear warmup to `peak`, then cosine decay to ~0."""
    scale = min(1.0, (step + 1) / warmup)
    if step > warmup:
        scale *= 0.5 * (1.0 + np.cos(np.pi * step / steps))
    return peak * scale


def train_model(
    cfg: ModelConfig,
    corpus: "corpus_mod.Corpus",
    steps: int,
    *,
    batch: int = 16,
    seq: int = 64,
    seed: int = 0,
    log_every: int = 50,
    log=print,
) -> Weights:
    """Train one model; returns the final weights (host numpy-backed)."""
    rng = np.random.default_rng(corpus_mod.SEED + 17 + seed)
    w = init_weights(cfg, jax.random.PRNGKey(seed))
    state = adam_init(w)
    t0 = time.time()
    loss = None
    for step in range(steps):
        tokens = jnp.asarray(
            corpus_mod.sample_training_batch(corpus, rng, batch, seq)
        )
        lr = jnp.asarray(lr_schedule(step, steps), jnp.float32)
        w, state, loss = _train_step(w, state, tokens, cfg, lr)
        if log_every and (step % log_every == 0 or step == steps - 1):
            log(
                f"[train {cfg.name}] step {step:4d}/{steps} "
                f"loss {float(loss):.4f} ({time.time() - t0:.1f}s)"
            )
    return {k: jnp.asarray(v) for k, v in w.items()}


def agreement_rate(
    w_llm: Weights, cfg_llm: ModelConfig,
    w_ssm: Weights, cfg_ssm: ModelConfig,
    corpus: "corpus_mod.Corpus",
    *,
    batch: int = 16,
    seq: int = 64,
    seed: int = 123,
) -> float:
    """Fraction of held-out positions where SSM argmax == LLM argmax.

    This is (roughly) the per-token acceptance probability p that shapes
    l(s); printed by aot.py as a build sanity check (expect 0.5-0.9)."""
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(corpus_mod.sample_training_batch(corpus, rng, batch, seq))
    pred_l = jnp.argmax(forward_train(w_llm, cfg_llm, tokens[:, :-1]), axis=-1)
    pred_s = jnp.argmax(forward_train(w_ssm, cfg_ssm, tokens[:, :-1]), axis=-1)
    return float((pred_l == pred_s).mean())


def save_weights_npz(path: str, w: Weights) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in w.items()})


def load_weights_npz(path: str) -> Dict[str, jnp.ndarray]:
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}
