"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/length patterns; exact agreement is
required for argmax (greedy acceptance depends on it) and tight allclose
for attention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.argmax import vocab_argmax
from compile.kernels.attention import verify_attention, vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


class TestVerifyAttention:
    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 4),
        h=st.integers(1, 3),
        t=st.integers(1, 9),
        dh=st.sampled_from([8, 16, 32]),
        s_max=st.sampled_from([32, 48, 224]),
        data=st.data(),
    )
    def test_matches_reference(self, b, h, t, dh, s_max, data):
        lens = jnp.asarray(
            data.draw(
                st.lists(
                    st.integers(0, s_max - t), min_size=b, max_size=b
                )
            ),
            jnp.int32,
        )
        q = rand(1, (b, h, t, dh))
        k = rand(2, (b, h, s_max, dh))
        v = rand(3, (b, h, s_max, dh))
        out = verify_attention(q, k, v, lens)
        expect = ref.verify_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5
        )

    def test_zero_length_rows_attend_only_self(self):
        # lens = 0: query 0 attends only position 0 (itself, just written)
        b, h, t, dh, s_max = 1, 1, 1, 8, 32
        q = rand(4, (b, h, t, dh))
        k = rand(5, (b, h, s_max, dh))
        v = rand(6, (b, h, s_max, dh))
        lens = jnp.zeros((b,), jnp.int32)
        out = verify_attention(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-5, atol=1e-5
        )

    def test_stale_tail_is_never_attended(self):
        # corrupting cache entries above lens+t must not change the output
        b, h, t, dh, s_max = 2, 2, 3, 16, 48
        q = rand(7, (b, h, t, dh))
        k = rand(8, (b, h, s_max, dh))
        v = rand(9, (b, h, s_max, dh))
        lens = jnp.asarray([5, 11], jnp.int32)
        base = np.asarray(verify_attention(q, k, v, lens))
        k2 = k.at[:, :, 20:].set(1e4)
        v2 = v.at[:, :, 20:].set(-1e4)
        out = np.asarray(verify_attention(q, k2, v2, lens))
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)

    def test_block_size_fallback_on_non_divisor(self):
        # s_block that does not divide s_max falls back to a divisor
        b, h, t, dh, s_max = 1, 1, 2, 8, 36
        q = rand(10, (b, h, t, dh))
        k = rand(11, (b, h, s_max, dh))
        v = rand(12, (b, h, s_max, dh))
        lens = jnp.asarray([7], jnp.int32)
        out = verify_attention(q, k, v, lens, s_block=32)
        expect = ref.verify_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5
        )

    def test_jit_compatible(self):
        b, h, t, dh, s_max = 2, 2, 4, 16, 64
        fn = jax.jit(verify_attention)
        q = rand(13, (b, h, t, dh))
        k = rand(14, (b, h, s_max, dh))
        v = rand(15, (b, h, s_max, dh))
        lens = jnp.asarray([3, 9], jnp.int32)
        np.testing.assert_allclose(
            np.asarray(fn(q, k, v, lens)),
            np.asarray(ref.verify_attention_ref(q, k, v, lens)),
            rtol=3e-5,
            atol=3e-5,
        )

    def test_vmem_estimate_is_positive_and_monotone(self):
        assert vmem_bytes(4, 6, 4, 32, 112) > 0
        assert vmem_bytes(4, 6, 4, 32, 224) > vmem_bytes(4, 6, 4, 32, 112)
        # the largest serving bucket stays well under the 16 MiB VMEM budget
        assert vmem_bytes(16, 6, 9, 32, 112) < 16 * 1024 * 1024


class TestVocabArgmax:
    @settings(**SETTINGS)
    @given(
        rows=st.integers(1, 24),
        v=st.sampled_from([64, 512, 1000]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, rows, v, seed):
        x = rand(seed, (rows, v), scale=3.0)
        np.testing.assert_array_equal(
            np.asarray(vocab_argmax(x)), np.asarray(ref.vocab_argmax_ref(x))
        )

    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 4),
        t=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_leading_dims_preserved(self, b, t, seed):
        x = rand(seed, (b, t, 128))
        out = vocab_argmax(x)
        assert out.shape == (b, t)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.vocab_argmax_ref(x))
        )

    def test_ties_break_to_first_across_tiles(self):
        # identical maxima in different V-tiles: earliest index must win,
        # matching jnp.argmax (greedy acceptance depends on this)
        x = jnp.zeros((3, 512))
        x = x.at[0, 10].set(7.0).at[0, 300].set(7.0)
        x = x.at[1, 255].set(1.0).at[1, 256].set(1.0)  # tile boundary
        x = x.at[2, 511].set(2.0)
        out = np.asarray(vocab_argmax(x, v_block=256))
        np.testing.assert_array_equal(out, [10, 255, 511])

    def test_negative_logits(self):
        x = -jnp.abs(rand(99, (5, 512))) - 1.0
        np.testing.assert_array_equal(
            np.asarray(vocab_argmax(x)), np.asarray(ref.vocab_argmax_ref(x))
        )

    def test_nondivisor_vocab_falls_back(self):
        x = rand(100, (4, 300))
        np.testing.assert_array_equal(
            np.asarray(vocab_argmax(x, v_block=256)),
            np.asarray(ref.vocab_argmax_ref(x)),
        )
