"""Shared fixtures: tiny model configs (much smaller than the artifact
models) so the pytest suite stays fast while exercising every code path,
plus session-cached weights."""

import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.configs import ModelConfig  # noqa: E402


TINY_LLM = ModelConfig(
    name="llm",
    vocab=64,
    d_model=32,
    n_layers=2,
    n_heads=2,
    d_head=16,
    d_ff=64,
    max_seq=48,
    max_prompt=12,
)

TINY_SSM = ModelConfig(
    name="ssm",
    vocab=64,
    d_model=16,
    n_layers=1,
    n_heads=1,
    d_head=16,
    d_ff=32,
    max_seq=48,
    max_prompt=12,
)


@pytest.fixture(scope="session")
def tiny_llm_cfg():
    return TINY_LLM


@pytest.fixture(scope="session")
def tiny_ssm_cfg():
    return TINY_SSM


@pytest.fixture(scope="session")
def tiny_llm_weights():
    from compile.model import init_weights

    return init_weights(TINY_LLM, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def tiny_ssm_weights():
    from compile.model import init_weights

    return init_weights(TINY_SSM, jax.random.PRNGKey(1))
