"""Corpus/dataset generation invariants (the substrate for acceptance
behaviour and the shared Rust/Python dataset contract)."""

import json

import numpy as np
import pytest

from compile import corpus as cm
from compile.configs import VOCAB_SIZE


@pytest.fixture(scope="module")
def corpus():
    return cm.build_corpus()


class TestVocab:
    def test_size_and_specials(self, corpus):
        assert corpus.vocab_size == VOCAB_SIZE
        assert corpus.vocab[cm.PAD] == "<pad>"
        assert corpus.vocab[cm.BOS] == "<bos>"
        assert corpus.vocab[cm.EOS] == "<eos>"
        assert corpus.vocab[cm.UNK] == "<unk>"
        assert len(set(corpus.vocab)) == VOCAB_SIZE  # no duplicates


class TestMarkovChain:
    def test_transitions_are_valid_distributions(self, corpus):
        sums = corpus.trans_prob.sum(axis=1)
        np.testing.assert_allclose(sums, 1.0, rtol=1e-9)
        # successors never point at special tokens
        used = corpus.trans_next[corpus.trans_prob > 0]
        assert (used >= cm.N_SPECIAL).all()

    def test_hard_fraction_near_target(self, corpus):
        frac = corpus.hard_mask.mean()
        assert abs(frac - cm.HARD_FRACTION) < 0.08

    def test_deterministic_given_seed(self):
        a = cm.build_corpus(123)
        b = cm.build_corpus(123)
        c = cm.build_corpus(124)
        np.testing.assert_array_equal(a.trans_next, b.trans_next)
        assert not np.array_equal(a.trans_next, c.trans_next)

    def test_walks_stay_in_content_vocab(self, corpus):
        rng = np.random.default_rng(0)
        w = cm.sample_walk(corpus, rng, 200)
        assert (w >= cm.N_SPECIAL).all()
        assert (w < VOCAB_SIZE).all()

    def test_oracle_argmax_walk_is_deterministic(self, corpus):
        start = int(corpus.openers[0])
        a = cm.oracle_argmax_walk(corpus, start, 20)
        b = cm.oracle_argmax_walk(corpus, start, 20)
        np.testing.assert_array_equal(a, b)


class TestDataset:
    def test_split_sizes_and_disjoint_generation(self, corpus):
        prompts = cm.build_dataset(corpus, n_profile=20, n_eval=30)
        assert sum(p.split == "profile" for p in prompts) == 20
        assert sum(p.split == "eval" for p in prompts) == 30
        for p in prompts:
            assert p.ids[0] == cm.BOS
            assert 4 + 1 <= len(p.ids) <= 24 + 1
            # text round-trips through the vocab
            assert p.text == " ".join(corpus.vocab[t] for t in p.ids[1:])

    def test_write_dataset_schema(self, corpus, tmp_path):
        prompts = cm.build_dataset(corpus, n_profile=3, n_eval=4)
        path = tmp_path / "dataset.json"
        cm.write_dataset(str(path), corpus, prompts)
        data = json.loads(path.read_text())
        assert len(data["vocab"]) == VOCAB_SIZE
        assert data["special"] == {"pad": 0, "bos": 1, "eos": 2, "unk": 3}
        assert len(data["prompts"]) == 7
        assert {p["split"] for p in data["prompts"]} == {"profile", "eval"}

    def test_training_batch_shape(self, corpus):
        rng = np.random.default_rng(1)
        batch = cm.sample_training_batch(corpus, rng, 4, 16)
        assert batch.shape == (4, 16)
        assert (batch[:, 0] == cm.BOS).all()
