"""The reference engine's core guarantees (shared contract with Rust).

The heart of the suite: greedy speculative decoding is LOSSLESS — its
output must be byte-identical to plain greedy decoding for any draft
model, any speculation length, any batch composition.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import engine_ref


@pytest.fixture(scope="module")
def prompts():
    return [[1, 5, 9, 13], [1, 7, 8], [1, 20, 21, 22, 23, 24], [1, 2]]


@pytest.fixture(scope="module")
def greedy(tiny_llm_weights, tiny_llm_cfg, prompts):
    return engine_ref.greedy_generate(
        tiny_llm_weights, tiny_llm_cfg, prompts, 14
    )


class TestLosslessness:
    @pytest.mark.parametrize("s", [1, 2, 3, 5, 7])
    def test_spec_equals_greedy(
        self, tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg,
        prompts, greedy, s,
    ):
        out = engine_ref.spec_generate(
            tiny_llm_weights, tiny_llm_cfg,
            tiny_ssm_weights, tiny_ssm_cfg,
            prompts, 14, s,
        )
        assert out == greedy, f"s={s} diverged"

    def test_spec_equals_greedy_when_draft_is_target(
        self, tiny_llm_weights, tiny_llm_cfg, prompts, greedy
    ):
        """Perfect draft model: everything accepted, output unchanged."""
        accepts = []
        out = engine_ref.spec_generate(
            tiny_llm_weights, tiny_llm_cfg,
            tiny_llm_weights, tiny_llm_cfg,
            prompts, 14, 4, record_accepts=accepts,
        )
        assert out == greedy
        # self-drafting must accept (nearly) everything
        acc = np.concatenate(accepts)
        assert acc.mean() > 3.9

    def test_single_prompt_batch(self, tiny_llm_weights, tiny_llm_cfg,
                                 tiny_ssm_weights, tiny_ssm_cfg):
        p = [[1, 3, 5]]
        g = engine_ref.greedy_generate(tiny_llm_weights, tiny_llm_cfg, p, 10)
        s = engine_ref.spec_generate(
            tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg,
            p, 10, 2,
        )
        assert s == g


class TestStateInvariants:
    def test_prefill_establishes_ingested_invariant(
        self, tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg
    ):
        prompts = [[1, 4], [1, 6, 7]]
        session = engine_ref.BatchSession(prompts)
        llm = engine_ref.ModelState.fresh(tiny_llm_cfg, tiny_llm_weights, 2)
        ssm = engine_ref.ModelState.fresh(tiny_ssm_cfg, tiny_ssm_weights, 2)
        engine_ref.prefill(llm, session)
        engine_ref.ssm_sync_prefill(ssm, session)
        for i in range(2):
            assert llm.ingested[i] == len(session.committed[i]) - 1
            assert ssm.ingested[i] == len(prompts[i])

    def test_round_loop_invariants(
        self, tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg
    ):
        prompts = [[1, 4], [1, 6, 7]]
        session = engine_ref.BatchSession(prompts)
        llm = engine_ref.ModelState.fresh(tiny_llm_cfg, tiny_llm_weights, 2)
        ssm = engine_ref.ModelState.fresh(tiny_ssm_cfg, tiny_ssm_weights, 2)
        engine_ref.prefill(llm, session)
        engine_ref.ssm_sync_prefill(ssm, session)
        for _ in range(5):
            drafts = engine_ref.speculate_step(ssm, session, 3)
            assert drafts.shape == (2, 3)
            acc = engine_ref.verify_step(llm, session, drafts)
            assert all(0 <= a <= 3 for a in acc)
            engine_ref.ssm_rollback(ssm, session)
            for i in range(2):
                # both models: ingested == committed - 1 after each round
                assert llm.ingested[i] == len(session.committed[i]) - 1
                assert ssm.ingested[i] <= len(session.committed[i]) - 1
                # committed grows by accepted + 1
            # ssm delta for next round is 1..=2 tokens
            for i in range(2):
                missing = len(session.committed[i]) - ssm.ingested[i]
                assert 1 <= missing <= 2

    def test_acceptance_measurement_shapes(
        self, tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg
    ):
        samples = engine_ref.measure_acceptance(
            tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg,
            [[1, 5, 9]], s=4, rounds=3,
        )
        assert samples.ndim == 1
        assert (samples >= 0).all() and (samples <= 4).all()

    def test_l_of_s_estimator_monotone(self):
        samples = np.asarray([0, 1, 1, 2, 4, 4, 6])
        l = engine_ref.l_of_s(samples, 6)
        assert (np.diff(l) >= -1e-12).all()
        assert l[0] == np.minimum(samples, 1).mean()


class TestValidation:
    def test_rejects_oversized_prompt(self, tiny_llm_weights, tiny_llm_cfg):
        too_long = [[1] * (tiny_llm_cfg.max_prompt + 1)]
        with pytest.raises(ValueError):
            engine_ref.greedy_generate(tiny_llm_weights, tiny_llm_cfg, too_long, 4)

    def test_delta_invariant_is_enforced(
        self, tiny_llm_weights, tiny_llm_cfg, tiny_ssm_weights, tiny_ssm_cfg
    ):
        prompts = [[1, 4]]
        session = engine_ref.BatchSession(prompts)
        ssm = engine_ref.ModelState.fresh(tiny_ssm_cfg, tiny_ssm_weights, 1)
        # ssm never prefilled: missing == full prompt > 2
        session.committed[0].extend([5, 6, 7])
        with pytest.raises(AssertionError):
            engine_ref.speculate_step(ssm, session, 2)
