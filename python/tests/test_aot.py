"""AOT pipeline: lowering produces loadable HLO text; the shipped artifact
manifest (when present) is internally consistent with the weight blobs
and the calling convention the Rust runtime assumes."""

import json
import os

import numpy as np
import pytest

from compile.aot import executable_matrix, lower_executable, to_hlo_text
from compile.configs import (
    FULL_PROFILE,
    LLM_CONFIG,
    QUICK_PROFILE,
    SSM_CONFIG,
    config_fingerprint,
)
from compile.model import WEIGHT_ORDER, weight_shapes

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_hlo_text_is_emitted(self, tiny_llm_cfg):
        text = lower_executable("verify", tiny_llm_cfg, 1, 1)
        assert "ENTRY" in text
        assert "f32" in text
        # weights are parameters, not constants: the text stays small
        assert len(text) < 2_000_000

    def test_all_three_kinds_lower(self, tiny_llm_cfg, tiny_ssm_cfg):
        for kind, cfg, s in [
            ("prefill", tiny_llm_cfg, 0),
            ("verify", tiny_llm_cfg, 2),
            ("speculate", tiny_ssm_cfg, 2),
        ]:
            text = lower_executable(kind, cfg, 2, s)
            assert "ENTRY" in text

    def test_to_hlo_text_roundtrip_simple(self):
        import jax
        import jax.numpy as jnp

        def fn(x):
            return (x * 2.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
        assert "ENTRY" in to_hlo_text(lowered)


class TestExecutableMatrix:
    def test_full_profile_covers_serving_needs(self):
        entries = list(executable_matrix(FULL_PROFILE))
        names = {e[0] for e in entries}
        # prefill for every bucket and both models
        for b in FULL_PROFILE.batch_buckets:
            assert f"llm_prefill_b{b}" in names
            assert f"ssm_prefill_b{b}" in names
            assert f"llm_verify_b{b}_s0" in names  # the no-spec baseline
        # the Fig. 2 probes
        assert "llm_verify_b4_s8" in names
        assert "ssm_speculate_b4_s8" in names

    def test_fingerprint_distinguishes_profiles(self):
        assert config_fingerprint(FULL_PROFILE) != config_fingerprint(QUICK_PROFILE)
        assert config_fingerprint(FULL_PROFILE) == config_fingerprint(FULL_PROFILE)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
class TestShippedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_weight_blobs_match_tables(self, manifest):
        for name, m in manifest["models"].items():
            path = os.path.join(ARTIFACTS, m["weights_file"])
            assert os.path.getsize(path) == m["weights_bytes"], name
            assert [w["name"] for w in m["weights"]] == list(WEIGHT_ORDER)
            cfg = LLM_CONFIG if name == "llm" else SSM_CONFIG
            shapes = weight_shapes(cfg)
            for w in m["weights"]:
                assert tuple(w["shape"]) == tuple(shapes[w["name"]]), w["name"]

    def test_every_declared_hlo_file_exists(self, manifest):
        for e in manifest["executables"]:
            path = os.path.join(ARTIFACTS, e["file"])
            assert os.path.exists(path), e["file"]
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head or "ENTRY" in head

    def test_goldens_are_consistent(self, manifest):
        with open(os.path.join(ARTIFACTS, manifest["goldens"])) as f:
            goldens = json.load(f)
        assert goldens["cases"], "no golden cases"
        for case in goldens["cases"]:
            assert len(case["greedy"]) == goldens["n_new"]
            assert all(0 <= t < LLM_CONFIG.vocab for t in case["greedy"])

    def test_weights_are_finite(self, manifest):
        m = manifest["models"]["llm"]
        blob = np.fromfile(
            os.path.join(ARTIFACTS, m["weights_file"]), dtype="<f4"
        )
        assert np.isfinite(blob).all()
        # trained weights, not zeros
        assert np.abs(blob).mean() > 1e-3
