"""L2 invariants of the decoder + functional KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    WEIGHT_ORDER,
    forward_tokens,
    forward_train,
    init_weights,
    make_prefill,
    make_speculate,
    make_verify,
    weight_shapes,
)


def zeros_kv(cfg, batch):
    return jnp.zeros(cfg.kv_shape(batch), jnp.float32)


def wlist(w):
    return [w[k] for k in WEIGHT_ORDER]


class TestWeights:
    def test_shapes_cover_weight_order(self, tiny_llm_cfg):
        shapes = weight_shapes(tiny_llm_cfg)
        assert list(shapes.keys()) == list(WEIGHT_ORDER)

    def test_init_matches_declared_shapes(self, tiny_llm_cfg, tiny_llm_weights):
        shapes = weight_shapes(tiny_llm_cfg)
        for name, arr in tiny_llm_weights.items():
            assert tuple(arr.shape) == tuple(shapes[name]), name
            assert arr.dtype == jnp.float32

    def test_param_count_close_to_estimate(self, tiny_llm_cfg, tiny_llm_weights):
        actual = sum(int(np.prod(a.shape)) for a in tiny_llm_weights.values())
        est = tiny_llm_cfg.n_params()
        assert abs(actual - est) / actual < 0.05


class TestForwardTokens:
    def test_kernels_and_jnp_paths_agree(self, tiny_llm_cfg, tiny_llm_weights):
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        toks = jnp.asarray([[4, 5, 6], [7, 8, 9]], jnp.int32)
        lens = jnp.asarray([3, 10], jnp.int32)
        kv = 0.1 * jax.random.normal(jax.random.PRNGKey(2), cfg.kv_shape(2))
        p1, kv1 = forward_tokens(w, cfg, toks, lens, kv, use_kernels=True)
        p2, kv2 = forward_tokens(w, cfg, toks, lens, kv, use_kernels=False)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        np.testing.assert_allclose(
            np.asarray(kv1), np.asarray(kv2), rtol=1e-5, atol=1e-5
        )

    def test_kv_written_exactly_at_lens_offsets(self, tiny_llm_cfg, tiny_llm_weights):
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        toks = jnp.asarray([[4, 5], [6, 7]], jnp.int32)
        lens = jnp.asarray([0, 5], jnp.int32)
        kv = jnp.full(cfg.kv_shape(2), 7.0)
        _, kv2 = forward_tokens(w, cfg, toks, lens, kv, use_kernels=False)
        kv2 = np.asarray(kv2)
        # row 0: positions 0..1 written, rest untouched
        assert not np.allclose(kv2[:, :, 0, :, 0:2], 7.0)
        assert np.allclose(kv2[:, :, 0, :, 2:], 7.0)
        # row 1: positions 5..6 written, outside untouched
        assert np.allclose(kv2[:, :, 1, :, :5], 7.0)
        assert not np.allclose(kv2[:, :, 1, :, 5:7], 7.0)
        assert np.allclose(kv2[:, :, 1, :, 7:], 7.0)

    def test_incremental_equals_full_forward(self, tiny_llm_cfg, tiny_llm_weights):
        """Token-by-token decoding with the cache must equal the training
        forward (full causal attention) on the same sequence."""
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        seq = jnp.asarray([[4, 9, 13, 21, 33, 7]], jnp.int32)
        full_logits = forward_train(w, cfg, seq)
        full_pred = np.asarray(jnp.argmax(full_logits, -1))[0]

        kv = zeros_kv(cfg, 1)
        inc_pred = []
        for i in range(seq.shape[1]):
            pred, kv = forward_tokens(
                w, cfg, seq[:, i : i + 1], jnp.asarray([i], jnp.int32), kv,
                use_kernels=False,
            )
            inc_pred.append(int(pred[0, 0]))
        np.testing.assert_array_equal(inc_pred, full_pred)

    def test_batched_rows_are_independent(self, tiny_llm_cfg, tiny_llm_weights):
        """A row's output must not depend on what other rows contain."""
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        kv2 = zeros_kv(cfg, 2)
        toks2 = jnp.asarray([[4, 5, 6], [40, 50, 60]], jnp.int32)
        lens2 = jnp.asarray([0, 0], jnp.int32)
        p2, _ = forward_tokens(w, cfg, toks2, lens2, kv2, use_kernels=False)

        kv1 = zeros_kv(cfg, 1)
        p1, _ = forward_tokens(
            w, cfg, toks2[:1], lens2[:1], kv1, use_kernels=False
        )
        np.testing.assert_array_equal(np.asarray(p2)[0], np.asarray(p1)[0])


class TestEntryPoints:
    def test_prefill_gathers_last_real_token(self, tiny_llm_cfg, tiny_llm_weights):
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        batch = 2
        fn = make_prefill(cfg, batch, use_kernels=False)
        toks = jnp.zeros((batch, cfg.max_prompt), jnp.int32)
        toks = toks.at[0, :3].set(jnp.asarray([1, 4, 9]))
        toks = toks.at[1, :5].set(jnp.asarray([1, 7, 8, 2, 3]))
        plens = jnp.asarray([3, 5], jnp.int32)
        last, kv = fn(toks, plens, zeros_kv(cfg, batch), *wlist(w))
        # cross-check: pred at position plens-1 of a raw forward
        pred, _ = forward_tokens(
            w, cfg, toks, jnp.zeros((batch,), jnp.int32),
            zeros_kv(cfg, batch), use_kernels=False,
        )
        np.testing.assert_array_equal(
            np.asarray(last), [np.asarray(pred)[0, 2], np.asarray(pred)[1, 4]]
        )

    def test_verify_s0_is_plain_decode(self, tiny_llm_cfg, tiny_llm_weights):
        cfg, w = tiny_llm_cfg, tiny_llm_weights
        fn = make_verify(cfg, 1, 0, use_kernels=False)
        kv = zeros_kv(cfg, 1)
        pred, kv = fn(
            jnp.asarray([[4]], jnp.int32), jnp.asarray([0], jnp.int32), kv, *wlist(w)
        )
        assert pred.shape == (1, 1)

    def test_speculate_draft_shape_and_dlens(self, tiny_ssm_cfg, tiny_ssm_weights):
        cfg, w = tiny_ssm_cfg, tiny_ssm_weights
        batch, s = 2, 3
        fn = make_speculate(cfg, batch, s, use_kernels=False)
        delta = jnp.asarray([[4, 0], [5, 6]], jnp.int32)
        dlens = jnp.asarray([1, 2], jnp.int32)
        lens = jnp.asarray([3, 7], jnp.int32)
        draft, kv = fn(delta, dlens, lens, zeros_kv(cfg, batch), *wlist(w))
        assert draft.shape == (batch, s)
        assert kv.shape == tuple(cfg.kv_shape(batch))

    def test_speculate_is_autoregressive_chain(self, tiny_ssm_cfg, tiny_ssm_weights):
        """The s drafts must equal s sequential single-token decodes."""
        cfg, w = tiny_ssm_cfg, tiny_ssm_weights
        s = 4
        fn = make_speculate(cfg, 1, s, use_kernels=False)
        delta = jnp.asarray([[9, 0]], jnp.int32)
        dlens = jnp.asarray([1], jnp.int32)
        lens = jnp.asarray([0], jnp.int32)
        draft, _ = fn(delta, dlens, lens, zeros_kv(cfg, 1), *wlist(w))
        draft = np.asarray(draft)[0]

        # manual chain with forward_tokens
        kv = zeros_kv(cfg, 1)
        pred, kv = forward_tokens(
            w, cfg, delta[:, :1], lens, kv, use_kernels=False
        )
        chain = [int(pred[0, 0])]
        cur = 1
        for _ in range(s - 1):
            tok = jnp.asarray([[chain[-1]]], jnp.int32)
            pred, kv = forward_tokens(
                w, cfg, tok, jnp.asarray([cur], jnp.int32), kv, use_kernels=False
            )
            chain.append(int(pred[0, 0]))
            cur += 1
        np.testing.assert_array_equal(draft, chain)
