//! SLO-aware admission, artifact-free: the three admission controllers
//! replay the SAME bursty deadlined trace (the Fig. 6 intense/sparse
//! pattern, time-compressed into overload) through the continuous DES,
//! each driven by a warm model-based speculation policy.  Watch:
//!
//! * **fifo** serve in arrival order — during the intense phase every
//!   request queues behind already-doomed ones, and attainment collapses;
//! * **edf** reorder by deadline — urgent requests jump the queue, but
//!   capacity is still burned on requests that can no longer make it;
//! * **slo** (SloAware) shed the hopeless ones — they were going to miss
//!   either way, and the rounds they would have burned now serve requests
//!   that still can meet their deadlines.
//!
//! ```bash
//! cargo run --release --example slo_admission   # no artifacts needed
//! ```

use anyhow::Result;

use specbatch::admission::build_controller;
use specbatch::config::AdmissionSpec;
use specbatch::simulator::simulate_trace_continuous_admission;
use specbatch::testkit::harness::{
    const_prompt_pool, paper_sim_config, slo_fig6_trace, warm_model_based,
};

const REQUESTS: usize = 400;
const SEED: u64 = 3;

fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let mut cfg = paper_sim_config(SEED);
    cfg.max_new_tokens = 32;

    // Fig. 6 traffic compressed 10x into overload; every request carries
    // a deadline sampled log-uniformly around a 1.5 s median budget
    let trace = slo_fig6_trace(&const_prompt_pool(12), REQUESTS, SEED, 0.1, 1.5, 2.0);
    println!(
        "trace: {} requests over {:.1}s, p50 budget 1.5s (spread 2x)\n",
        trace.len(),
        trace.span()
    );
    println!(
        "{:<10} {:>10} {:>6} {:>7} {:>6} {:>8} {:>12} {:>12}",
        "admission", "attainment", "met", "missed", "shed", "defers", "mean lat", "p99 lat"
    );

    for spec in AdmissionSpec::all() {
        let mut policy = warm_model_based(&cfg, 30);
        let mut ctrl = build_controller(spec);
        let (rec, _rounds) =
            simulate_trace_continuous_admission(&cfg, &mut policy, ctrl.as_mut(), &trace);
        let slo = rec.slo_attainment();
        let defers: usize = rec.records().iter().map(|r| r.deferred_rounds).sum();
        let (_, _, p99) = rec.percentiles();
        println!(
            "{:<10} {:>9.1}% {:>6} {:>7} {:>6} {:>8} {:>10.3}s {:>10.3}s",
            ctrl.label(),
            slo.attainment() * 100.0,
            slo.met,
            slo.missed,
            slo.shed,
            defers,
            rec.summary().mean,
            p99
        );
    }

    println!(
        "\nThe same comparison runs on the real threaded server:\n  \
         specbatch serve --mode continuous --admission slo --slo-p50 2 \\\n      \
         --policy model-based --requests 200 --interval 0.01"
    );
    Ok(())
}
