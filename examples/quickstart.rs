//! Quickstart: load the AOT artifacts, generate text for a few dataset
//! prompts with batched speculative decoding, and print acceptance stats.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example quickstart
//! ```
#![cfg_attr(not(feature = "pjrt"), allow(unused_imports, dead_code))]

use anyhow::Result;

use specbatch::engine::{Engine, EngineConfig};
use specbatch::policy::{Fixed, NoSpec};
#[cfg(feature = "pjrt")]
use specbatch::runtime::Runtime;
use specbatch::util::prng::Pcg64;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "quickstart drives the real PJRT runtime — rebuild with --features pjrt \
         and run `make artifacts` (try `--example continuous_batching` for an \
         artifact-free demo)"
    );
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let rt = Runtime::load("artifacts")?;
    let dataset = rt.dataset()?;
    let mut engine = Engine::new(&rt, EngineConfig::default())?;

    // a small batch of real dataset prompts
    let mut rng = Pcg64::new(7);
    let prompts = dataset.sample_eval(&mut rng, 4);
    let ids: Vec<Vec<i32>> = prompts.iter().map(|p| p.ids.clone()).collect();

    // generate with speculation length 3, then compare against no-spec
    let spec = engine.generate_batch(&ids, 32, &mut Fixed(3))?;
    let plain = engine.generate_batch(&ids, 32, &mut NoSpec)?;

    println!("== generations ==");
    for (p, toks) in prompts.iter().zip(&spec.tokens) {
        println!("prompt: {}", p.text);
        println!("  ->    {}\n", dataset.detokenize(toks));
    }

    // losslessness: speculative greedy decoding == plain greedy decoding
    assert_eq!(spec.tokens, plain.tokens, "speculation must be lossless");
    println!("lossless ✓  (speculative output == plain greedy output)");

    println!(
        "\nspeculative: {:.2} ms/token over {} rounds, {:.2} drafts accepted/round",
        spec.stats.per_token_latency() * 1e3,
        spec.stats.rounds,
        spec.stats.mean_accepted(),
    );
    println!(
        "no-spec:     {:.2} ms/token over {} rounds",
        plain.stats.per_token_latency() * 1e3,
        plain.stats.rounds,
    );
    println!(
        "speedup:     {:.2}x",
        plain.stats.per_token_latency() / spec.stats.per_token_latency()
    );
    Ok(())
}
