//! End-to-end serving driver (the repo's E2E validation run, recorded in
//! EXPERIMENTS.md): a real server thread owning the PJRT runtime serves
//! batched speculative requests from a real client thread generating
//! Gamma-distributed traffic over message queues — the paper's Sec. 5.3
//! setting, scaled to the tiny trained model pair.
//!
//! Runs the same trace under all four comparison points (no-spec,
//! fixed-2, fixed-4, adaptive-with-profiling) and reports end-to-end
//! request latency (queueing included) and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example serve_dynamic
//! # knobs: SPECBATCH_REQUESTS=48 SPECBATCH_INTERVAL=0.4 SPECBATCH_CV=2
//! #        SPECBATCH_MODE=continuous for round-granular batching
//! ```
#![cfg_attr(not(feature = "pjrt"), allow(unused_imports, dead_code))]

use std::path::PathBuf;

use anyhow::Result;

use specbatch::config::PolicySpec;
#[cfg(feature = "pjrt")]
use specbatch::dataset::Dataset;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::traffic::{Trace, TrafficPattern};
use specbatch::util::csv::{f, Csv};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "serve_dynamic drives the real PJRT runtime — rebuild with \
         --features pjrt and run `make artifacts` (the stub-backend server \
         is exercised by `specbatch serve` and tests/batcher_stub.rs)"
    );
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let artifacts = PathBuf::from("artifacts");
    let dataset = Dataset::load(artifacts.join("dataset.json"))?;

    let n_requests = env_f64("SPECBATCH_REQUESTS", 40.0) as usize;
    let interval = env_f64("SPECBATCH_INTERVAL", 0.25);
    let cv = env_f64("SPECBATCH_CV", 2.0);
    let tokens = env_f64("SPECBATCH_TOKENS", 24.0) as usize;
    let mode = match std::env::var("SPECBATCH_MODE").as_deref() {
        Ok("continuous") => SchedulingMode::Continuous,
        _ => SchedulingMode::Static,
    };
    println!("scheduling mode: {mode:?}");

    // ONE trace shared by all comparison points (paper methodology)
    let pattern = TrafficPattern::Stationary { interval, cv };
    let trace = Trace::generate(&pattern, &dataset.eval, n_requests, 11);
    println!(
        "trace: {n_requests} requests over {:.1}s ({}), {tokens} tokens each",
        trace.span(),
        pattern.label()
    );

    let policies = [
        PolicySpec::None,
        PolicySpec::Fixed(2),
        PolicySpec::Fixed(4),
        PolicySpec::Adaptive,
        PolicySpec::ModelBased,
    ];
    let mut csv = Csv::new(&[
        "policy",
        "mean_latency_s",
        "p50_s",
        "p90_s",
        "p99_s",
        "throughput_tok_s",
    ]);
    let mut means = Vec::new();
    for policy in policies {
        let label = policy.label();
        let cfg = ServerConfig {
            max_batch: 8,
            max_new_tokens: tokens,
            mode,
            ..ServerConfig::default()
        };
        let out =
            run_experiment(Backend::Artifacts(artifacts.clone()), cfg, policy, None, &trace)?;
        if let Some(lut) = &out.lut {
            println!("[{label}] profiled LUT: {}", lut.to_json().compact());
        }
        if let Some(snapshot) = &out.policy_snapshot {
            println!("[{label}] fitted model: {}", snapshot.compact());
        }
        let rec = &out.recorder;
        let s = rec.summary();
        let (p50, p90, p99) = rec.percentiles();
        let tput = rec.throughput_tokens_per_s();
        println!(
            "[{label:>8}] latency mean {:.3}s p50 {p50:.3}s p90 {p90:.3}s p99 {p99:.3}s | {tput:.1} tok/s",
            s.mean
        );
        csv.row(&[
            label.clone(),
            f(s.mean),
            f(p50),
            f(p90),
            f(p99),
            f(tput),
        ]);
        means.push((label, s.mean));
        rec.to_csv()
            .write_file(format!("results/serve_dynamic_{}.csv", means.last().unwrap().0))?;
    }
    csv.write_file("results/serve_dynamic_summary.csv")?;
    println!("-> results/serve_dynamic_summary.csv (+ per-policy request CSVs)");

    let get = |n: &str| means.iter().find(|(m, _)| m == n).map(|(_, v)| *v).unwrap();
    println!(
        "\nadaptive vs no-spec: {:.2}x  | vs fixed-2: {:.2}x | vs fixed-4: {:.2}x",
        get("no-spec") / get("adaptive"),
        get("fixed-2") / get("adaptive"),
        get("fixed-4") / get("adaptive"),
    );
    Ok(())
}
