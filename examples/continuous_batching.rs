//! Continuous vs static batching, end to end and artifact-free.
//!
//! Part 1 drives the **real serving stack** (worker thread, message
//! queues, continuous batcher) on the deterministic stub model pair and
//! prints the per-round `(live, s)` timeline — watch the live batch grow
//! as requests arrive mid-epoch and the adaptive policy shrink `s`.
//!
//! Part 2 replays the paper's Fig. 5 stationary point (interval 0.2 s,
//! CV 1) at **paper scale on the calibrated simulator** (OPT-6.7B +
//! OPT-125M on RTX 3090) for all four comparison policies under both
//! scheduling modes.
//!
//! ```bash
//! cargo run --release --example continuous_batching   # no artifacts needed
//! ```

use anyhow::Result;

use specbatch::config::PolicySpec;
use specbatch::dataset::Prompt;
use specbatch::server::{run_experiment, Backend, SchedulingMode, ServerConfig};
use specbatch::simulator::{
    comparison_policies, simulate_trace, simulate_trace_continuous, simulated_lut,
    AcceptanceProcess, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::testkit::stub::StubSpec;
use specbatch::traffic::{Trace, TrafficPattern};

fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    stub_server_demo()?;
    simulator_comparison();
    Ok(())
}

/// Part 1: the real server loop on the stub backend.
fn stub_server_demo() -> Result<()> {
    println!("== continuous batching on the stub server (no artifacts) ==");
    let pool: Vec<Prompt> = (4..=10usize)
        .map(|n| Prompt {
            ids: (0..n).map(|k| 4 + ((k * 7 + n) % 50) as i32).collect(),
            text: format!("stub prompt of {n} tokens"),
        })
        .collect();
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.003,
            cv: 1.0,
        },
        &pool,
        24,
        42,
    );
    let cfg = ServerConfig {
        max_batch: 8,
        max_new_tokens: 32,
        mode: SchedulingMode::Continuous,
        ..ServerConfig::default()
    };
    let out = run_experiment(
        Backend::Stub(StubSpec::default()),
        cfg,
        PolicySpec::Adaptive,
        None,
        &trace,
    )?;
    if let Some(lut) = &out.lut {
        println!("adaptive LUT: {}", lut.to_json().compact());
    }
    let rounds = &out.timeline;
    let s = out.recorder.summary();
    println!(
        "{} requests | mean latency {:.4}s | {} decode rounds recorded",
        s.n,
        s.mean,
        rounds.len()
    );
    println!("first rounds of the timeline (live batch vs chosen s):");
    for e in rounds.iter().take(12) {
        println!(
            "  t={:.4}s epoch={} live={:2} queued={:2} s={}",
            e.t, e.epoch, e.live, e.queued, e.s
        );
    }
    let lives: Vec<usize> = rounds.iter().map(|e| e.live).collect();
    println!(
        "live batch range within the run: {}..{}\n",
        lives.iter().min().unwrap_or(&0),
        lives.iter().max().unwrap_or(&0)
    );
    Ok(())
}

/// Part 2: paper-scale static vs continuous across the four policies.
fn simulator_comparison() {
    println!("== Fig. 5 point (interval 0.2s, CV 1) at paper scale, both modes ==");
    let cfg = SimConfig {
        llm: CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        ssm: CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        acceptance: AcceptanceProcess::paper(),
        drift: None,
        max_batch: 16,
        max_new_tokens: 128,
        host_overhead: 0.2e-3,
        kv_layout: specbatch::kvcache::KvLayout::Paged,
        kv_block: specbatch::kvcache::DEFAULT_BLOCK_SIZE,
        seed: 5,
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("simulated LUT: {}", lut.to_json().compact());
    let pool: Vec<Prompt> = (4..=24)
        .map(|n| Prompt {
            ids: vec![1; n],
            text: String::new(),
        })
        .collect();
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.2,
            cv: 1.0,
        },
        &pool,
        400,
        5,
    );

    println!(
        "{:>10} {:>14} {:>17} {:>9}",
        "policy", "static mean", "continuous mean", "gain"
    );
    for (name, mut policy) in comparison_policies(lut) {
        let m_static = simulate_trace(&cfg, policy.as_mut(), &trace).summary().mean;
        let (rec, _) = simulate_trace_continuous(&cfg, policy.as_mut(), &trace);
        let m_cont = rec.summary().mean;
        println!(
            "{name:>10} {m_static:>13.3}s {m_cont:>16.3}s {:>8.2}x",
            m_static / m_cont
        );
    }
    println!("\n(continuous admits at round boundaries instead of batch boundaries;");
    println!(" the adaptive policy re-reads the LUT with the live batch every round)");
}
