//! The paper's analytical model (Sec. 3.3) fitted from *real
//! measurements* of the tiny model pair, end to end:
//!
//! 1. measure per-round accepted counts -> Eq. 4 estimator -> fit
//!    l(s) = c·s^γ (Fig. 2);
//! 2. measure t_L(b, s) per bucket -> fit α_b·s + β (Fig. 3);
//! 3. combine into the Eq. 7 total-time model, solve Eq. 12 for s_opt,
//!    and compare the predicted s_opt(b) against the grid-searched
//!    optimum from actual execution.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example analytic_model
//! ```
#![cfg_attr(not(feature = "pjrt"), allow(unused_imports, dead_code))]

use std::time::Instant;

use anyhow::Result;

use specbatch::analytic::{AcceptanceModel, StepCostModel, TotalTimeModel};
use specbatch::engine::{Engine, EngineConfig};
#[cfg(feature = "pjrt")]
use specbatch::model::Model;
use specbatch::policy::Fixed;
#[cfg(feature = "pjrt")]
use specbatch::runtime::Runtime;
use specbatch::util::prng::Pcg64;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "analytic_model drives the real PJRT runtime — rebuild with \
         --features pjrt and run `make artifacts`"
    );
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let rt = Runtime::load("artifacts")?;
    let dataset = rt.dataset()?;

    // --- 1. acceptance curve from real speculative runs ---
    let mut engine = Engine::new(
        &rt,
        EngineConfig {
            record_acceptance: true,
            stop_at_eos: false,
            ..EngineConfig::default()
        },
    )?;
    let s_probe = 6.min(rt.manifest.max_spec_len(4));
    let mut rng = Pcg64::new(0xACC);
    let mut samples = Vec::new();
    for _ in 0..6 {
        let prompts: Vec<Vec<i32>> = dataset
            .sample_eval(&mut rng, 4)
            .into_iter()
            .map(|p| p.ids)
            .collect();
        let out = engine.generate_batch(&prompts, 32, &mut Fixed(s_probe))?;
        samples.extend(out.stats.accept_samples);
    }
    let acceptance = AcceptanceModel::fit_samples(&samples, s_probe)?;
    println!(
        "l(s) ≈ {:.3}·s^{:.3} from {} samples (r² {:.3}; paper: 0.9·s^0.548)",
        acceptance.c,
        acceptance.gamma,
        samples.len(),
        acceptance.r2
    );

    // --- 2. step costs per bucket + 3. predicted vs measured s_opt ---
    let llm = Model::new(&rt, "llm")?;
    let ssm = Model::new(&rt, "ssm")?;
    println!("\n{:>6} {:>12} {:>12} {:>14} {:>13}", "batch", "alpha(ms)", "beta(ms)", "predicted s*", "measured s*");
    for &b in &rt.manifest.batch_buckets {
        let max_s = rt.manifest.max_spec_len(b);
        // measure t_L(b, s)
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for s in 0..=max_s {
            let mut kv = llm.new_kv(b)?;
            let tokens = vec![5i32; b * llm.spec.max_prompt];
            let plens = vec![8i32; b];
            llm.prefill(&tokens, &plens, b, &mut kv)?;
            let feed = vec![7i32; b * (s + 1)];
            let clamp = vec![9u32; b];
            llm.verify(&feed, s, b, &mut kv)?; // warmup
            kv.clamp_to(&clamp);
            let reps = 10;
            let t0 = Instant::now();
            for _ in 0..reps {
                llm.verify(&feed, s, b, &mut kv)?;
                kv.clamp_to(&clamp);
            }
            xs.push(s as f64);
            ys.push(t0.elapsed().as_secs_f64() / reps as f64);
        }
        // measure t_S(b, 1): a speculate(s=1) call is ingest+1 draft
        let t_ssm = {
            let mut kv = ssm.new_kv(b)?;
            let tokens = vec![5i32; b * ssm.spec.max_prompt];
            let plens = vec![8i32; b];
            ssm.prefill(&tokens, &plens, b, &mut kv)?;
            let delta = vec![7i32; b * 2];
            let dlens = vec![1i32; b];
            let clamp = vec![9u32; b];
            ssm.speculate(&delta, &dlens, 1, b, &mut kv)?;
            kv.clamp_to(&clamp);
            let reps = 10;
            let t0 = Instant::now();
            for _ in 0..reps {
                ssm.speculate(&delta, &dlens, 1, b, &mut kv)?;
                kv.clamp_to(&clamp);
            }
            t0.elapsed().as_secs_f64() / reps as f64
        };
        let cost = StepCostModel::fit(b, &xs, &ys, t_ssm)?;
        let model = TotalTimeModel { acceptance, cost };
        let predicted = model.s_opt(max_s);

        // measured optimum by grid search on real generation
        let mut best = (0usize, f64::INFINITY);
        for s in 0..=max_s {
            let prompts: Vec<Vec<i32>> = dataset
                .sample_eval(&mut rng, b)
                .into_iter()
                .map(|p| p.ids)
                .collect();
            let mut policy: Box<dyn specbatch::policy::SpeculationPolicy> = if s == 0 {
                Box::new(specbatch::policy::NoSpec)
            } else {
                Box::new(Fixed(s))
            };
            let out = engine.generate_batch(&prompts, 16, policy.as_mut())?;
            let lat = out.stats.per_token_latency();
            if lat < best.1 {
                best = (s, lat);
            }
        }
        println!(
            "{b:>6} {:>12.3} {:>12.3} {predicted:>14} {:>13}",
            cost.alpha * 1e3,
            cost.beta * 1e3,
            best.0
        );
    }
    println!("\n(Eq. 12 predicts s_opt from the fitted model; the measured column is");
    println!(" the grid-searched optimum on real execution — shapes should agree)");
    Ok(())
}
