//! Online model-based speculation adapting to acceptance drift, end to
//! end and artifact-free: a continuous-batching serve run (virtual time,
//! paper-scale cost model) whose draft acceptance collapses mid-trace.
//! A reporting wrapper around [`ModelBased`] prints the fitted `c`, `γ`
//! and the chosen `s` every few hundred rounds — watch the fit track the
//! pre-drift curve, break when the workload shifts, and re-converge.
//!
//! ```bash
//! cargo run --release --example online_adaptation   # no artifacts needed
//! ```

use anyhow::Result;

use specbatch::dataset::Prompt;
use specbatch::policy::{ModelBased, RoundFeedback, SpeculationPolicy};
use specbatch::simulator::{
    oracle_s_opt, simulate_trace_continuous, simulated_lut, AcceptanceDrift, AcceptanceProcess,
    CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};

/// Wraps the online policy and narrates its fits as feedback arrives —
/// a tiny demonstration of composing [`SpeculationPolicy`] objects.
struct Narrated {
    inner: ModelBased,
    rounds: usize,
    every: usize,
}

impl SpeculationPolicy for Narrated {
    fn choose(&self, live: usize, max_s: usize) -> usize {
        self.inner.choose(live, max_s)
    }

    fn observe(&mut self, fb: &RoundFeedback) {
        self.inner.observe(fb);
        self.rounds += 1;
        if self.rounds % self.every == 0 {
            match self.inner.fitted_acceptance() {
                Some(a) => println!(
                    "  round {:>5}: l(s) ≈ {:.3}·s^{:.3}  (r² {:.3})  live {:>2} -> s = {}",
                    self.rounds,
                    a.c,
                    a.gamma,
                    a.r2,
                    fb.live,
                    self.inner.choose(fb.live, 8),
                ),
                None => println!("  round {:>5}: cold start (LUT fallback)", self.rounds),
            }
        }
    }

    fn label(&self) -> String {
        format!("narrated({})", self.inner.label())
    }
}

fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let drift_at = 60.0;
    let before = AcceptanceProcess::PowerLaw { c: 0.9, gamma: 0.8 };
    let after = AcceptanceProcess::PowerLaw {
        c: 0.6,
        gamma: 0.05,
    };

    let mut cfg = SimConfig::paper_default(
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
    );
    cfg.acceptance = before.clone();
    cfg.drift = Some(AcceptanceDrift {
        at: drift_at,
        after: after.clone(),
    });

    // the offline LUT, profiled before the drift — about to go stale
    let lut = {
        let mut pre = cfg.clone();
        pre.drift = None;
        simulated_lut(&pre, &[1, 2, 4, 8, 16], 8, 80)
    };
    println!("offline (soon-stale) LUT: {}", lut.to_json().compact());
    println!(
        "acceptance drifts at t = {drift_at}s: 0.9·s^0.8 -> 0.6·s^0.05 \
         (drafts stop being accepted)\n"
    );

    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    let trace = Trace::generate(
        &TrafficPattern::Stationary {
            interval: 0.2,
            cv: 1.0,
        },
        &pool,
        600,
        42,
    );

    let mut policy = Narrated {
        inner: ModelBased::new(lut),
        rounds: 0,
        every: 300,
    };
    println!("== online fit converging over rounds ==");
    let (rec, rounds) = simulate_trace_continuous(&cfg, &mut policy, &trace);

    println!("\n== outcome ==");
    println!(
        "{} requests | mean latency {:.3}s over {} rounds",
        rec.len(),
        rec.summary().mean,
        rounds.len()
    );
    if let Some(snap) = policy.inner.snapshot() {
        println!("final fitted model: {}", snap.compact());
    }

    // chosen s vs the oracle, before and after the drift
    let mode_s = |lo: f64, hi: f64| -> Option<usize> {
        let mut counts = std::collections::BTreeMap::new();
        for e in rounds.iter().filter(|e| e.t >= lo && e.t < hi) {
            *counts.entry(e.s).or_insert(0usize) += 1;
        }
        counts.into_iter().max_by_key(|&(_, n)| n).map(|(s, _)| s)
    };
    let live_late = rounds.last().map(|e| e.live).unwrap_or(8);
    println!(
        "pre-drift modal s = {:?} (oracle at live=2: {})",
        mode_s(5.0, drift_at),
        oracle_s_opt(&cfg, &before, 2, 8, 80)
    );
    println!(
        "post-drift modal s = {:?} (oracle at live={live_late}: {})",
        mode_s(drift_at + 20.0, f64::INFINITY),
        oracle_s_opt(&cfg, &after, live_late, 8, 80)
    );
    Ok(())
}
