//! Routing × speculation synergy on a sharded cluster, artifact-free:
//! four routing strategies replay the SAME bursty trace against N
//! simulated worker shards (paper-scale cost model, virtual time), each
//! shard running its own online model-based speculation policy.  Watch
//! per-shard live batches diverge and each shard's chosen `s` follow its
//! own batch — the paper's batch↔s_opt curve acting at cluster scale —
//! and the cost-aware router beat the oblivious ones on per-token
//! latency.
//!
//! ```bash
//! cargo run --release --example cluster_routing   # no artifacts needed
//! ```

use anyhow::Result;

use specbatch::cluster::sim::simulate_trace_cluster;
use specbatch::cluster::{build_router, replicate_policies};
use specbatch::config::{PolicySpec, RouterSpec};
use specbatch::dataset::Prompt;
use specbatch::simulator::{
    simulated_lut, CostModel, GpuProfile, ModelProfile, SimConfig,
};
use specbatch::traffic::{Trace, TrafficPattern};

const WORKERS: usize = 4;
const REQUESTS: usize = 800;

fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let cfg = SimConfig {
        seed: 5,
        ..SimConfig::paper_default(
            CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
            CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        )
    };
    let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
    println!("offline LUT (cold-start fallback): {}", lut.to_json().compact());

    // one shared bursty trace: the Fig. 6 intense/sparse pattern,
    // time-compressed ~6.7x so four shards run at moderate-heavy load and
    // shard batches swing through the whole batch <-> s_opt curve
    let pool = vec![Prompt {
        ids: vec![1; 16],
        text: String::new(),
    }];
    let trace =
        Trace::generate(&TrafficPattern::fig6(), &pool, REQUESTS, 5).time_scaled(0.15);
    println!(
        "trace: {} requests over {:.0}s across {WORKERS} shards\n",
        trace.len(),
        trace.span()
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    for spec in RouterSpec::all() {
        let mut policies =
            replicate_policies(&PolicySpec::ModelBased, Some(&lut), WORKERS)?;
        let mut router = build_router(spec, cfg.seed);
        let report = simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
        assert_eq!(report.recorder.len(), REQUESTS);
        let counts = report.shard_requests();
        let spread = counts.iter().max().unwrap() - counts.iter().min().unwrap();
        rows.push(vec![
            report.router.clone(),
            format!("{:.2}", report.recorder.summary().mean),
            format!("{:.2}", report.recorder.mean_per_token_latency() * 1e3),
            format!("{:?}", counts),
            spread.to_string(),
        ]);

        // per-shard live/s timeline: mean live and mean s per window, one
        // row per shard (the divergence the router creates)
        if spec == RouterSpec::CostAware {
            let span = trace.span();
            let win = (span / 6.0).max(1.0);
            println!(
                "per-shard timeline under {} ({win:.0}s windows, live/s):",
                report.router
            );
            let mut header = vec!["shard".to_string()];
            let mut t0 = 0.0;
            while t0 < span {
                header.push(format!("[{:.0}-{:.0}s)", t0, t0 + win));
                t0 += win;
            }
            let mut table: Vec<Vec<String>> = Vec::new();
            for (k, rounds) in report.shard_rounds.iter().enumerate() {
                let mut row = vec![k.to_string()];
                let mut t0 = 0.0;
                while t0 < span {
                    let window: Vec<_> = rounds
                        .iter()
                        .filter(|e| e.t >= t0 && e.t < t0 + win)
                        .collect();
                    if window.is_empty() {
                        row.push("idle".into());
                    } else {
                        let live = window.iter().map(|e| e.live as f64).sum::<f64>()
                            / window.len() as f64;
                        let s = window.iter().map(|e| e.s as f64).sum::<f64>()
                            / window.len() as f64;
                        row.push(format!("{live:.1}/{s:.1}"));
                    }
                    t0 += win;
                }
                table.push(row);
            }
            print_table(&header, &table);
            println!();
        }
    }

    println!("router comparison on the shared trace:");
    print_table(
        &[
            "router".into(),
            "mean latency (s)".into(),
            "ms/token".into(),
            "requests/shard".into(),
            "spread".into(),
        ],
        &rows,
    );
    println!(
        "\ncost-aware keeps shard batches in the sweet spot of the paper's \
         batch <-> s_opt curve; round-robin lets bursts pile onto busy shards."
    );
    Ok(())
}

/// Render a small ASCII table (rows of equal length).
fn print_table(header: &[String], rows: &[Vec<String>]) {
    let ncol = header.len();
    let mut width = vec![0usize; ncol];
    for (i, h) in header.iter().enumerate() {
        width[i] = h.len();
    }
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            width[i] = width[i].max(c.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = width[i]));
        }
        s
    };
    println!("{}", line(header));
    println!("{}", "-".repeat(width.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", line(row));
    }
}
