//! Adaptive vs fixed speculation on the real engine (Sec. 4 end to end):
//! profile the LUT on the *profile* split, then compare per-token latency
//! across batch sizes against fixed speculation lengths on the *eval*
//! split — the real-execution miniature of Fig. 4.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example adaptive_vs_fixed
//! ```
#![cfg_attr(not(feature = "pjrt"), allow(unused_imports, dead_code))]

use anyhow::Result;

use specbatch::engine::{Engine, EngineConfig};
use specbatch::policy::{Fixed, LutAdaptive, NoSpec, SpeculationPolicy};
#[cfg(feature = "pjrt")]
use specbatch::runtime::Runtime;
use specbatch::scheduler::profiler::{profile, ProfilerConfig};
use specbatch::util::prng::Pcg64;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "adaptive_vs_fixed drives the real PJRT runtime — rebuild with \
         --features pjrt and run `make artifacts`"
    );
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    specbatch::util::logging::init_from_env();
    let rt = Runtime::load("artifacts")?;
    let dataset = rt.dataset()?;
    let mut engine = Engine::new(&rt, EngineConfig::default())?;

    // --- offline profiling stage (the paper's Sec. 4) ---
    let mut rng = Pcg64::new(0xADA);
    let profile_prompts = dataset.sample_profile(&mut rng, 24);
    let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
    pcfg.tokens_per_run = 16;
    pcfg.repeats = 1;
    let result = profile(&mut engine, &profile_prompts, &pcfg)?;
    println!("profiled LUT: {}\n", result.lut.to_json().compact());

    // --- execution stage on the disjoint eval split ---
    let tokens = 24;
    let mut policies: Vec<(String, Box<dyn SpeculationPolicy>)> = vec![
        ("no-spec".into(), Box::new(NoSpec) as Box<dyn SpeculationPolicy>),
        ("fixed-2".into(), Box::new(Fixed(2))),
        ("fixed-4".into(), Box::new(Fixed(4))),
        ("adaptive".into(), Box::new(LutAdaptive(result.lut.clone()))),
    ];
    println!(
        "{:>6}  {:>9} {:>9} {:>9} {:>9}   (ms/token)",
        "batch", "no-spec", "fixed-2", "fixed-4", "adaptive"
    );
    for &b in &rt.manifest.batch_buckets {
        let prompts: Vec<Vec<i32>> = dataset
            .sample_eval(&mut rng, b)
            .into_iter()
            .map(|p| p.ids)
            .collect();
        let mut cells = Vec::new();
        let mut best = (String::new(), f64::INFINITY);
        for (name, policy) in policies.iter_mut() {
            let out = engine.generate_batch(&prompts, tokens, policy.as_mut())?;
            let ms = out.stats.per_token_latency() * 1e3;
            if ms < best.1 {
                best = (name.clone(), ms);
            }
            cells.push(ms);
        }
        println!(
            "{b:>6}  {:>9.2} {:>9.2} {:>9.2} {:>9.2}   best: {}",
            cells[0], cells[1], cells[2], cells[3], best.0
        );
    }
    println!("\n(adaptive uses s = LUT[b] per batch; the paper's claim is that it");
    println!(" matches or beats the best fixed length at every batch size)");
    Ok(())
}
