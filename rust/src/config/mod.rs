//! Typed configuration for the serving coordinator.
//!
//! Configs load from JSON files (see `configs/*.json` at the repo root for
//! examples) and/or CLI flags; every field has a sensible default so the
//! quickstart works with zero configuration.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::kvcache::KvLayout;
use crate::telemetry::TelemetryMode;
use crate::util::json::Json;

/// Top-level serving configuration (paper Sec. 5 methodology).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Directory produced by `make artifacts`.
    pub artifacts_dir: PathBuf,
    /// Maximum requests merged into one batch (paper: 16, memory-bound).
    pub max_batch: usize,
    /// New tokens generated per request (paper: 128).
    pub max_new_tokens: usize,
    /// Stop early when the model emits `<eos>`.
    pub stop_at_eos: bool,
    /// Speculation policy: "none", "fixed:<s>", "adaptive", or
    /// "model-based" (online, feedback-fitted).
    pub policy: PolicySpec,
    /// Worker shards serving in parallel (1 = the single-worker paths).
    pub workers: usize,
    /// How arrivals are routed across shards when `workers > 1`.
    pub router: RouterSpec,
    /// KV layout: "dense" (per-slot buffers, reshape re-ingests) or
    /// "paged" (block tables, O(1) reshape remap; stub backend only).
    pub kv_layout: KvLayout,
    /// Admission control: "fifo" (arrival order), "edf"
    /// (deadline-ordered), or "slo" (model-predicted defer/shed).
    pub admission: AdmissionSpec,
    /// Median per-request latency budget in seconds (0 = requests carry
    /// no deadlines and every controller behaves like FIFO).
    pub slo_p50: f64,
    /// Log-uniform spread of the sampled budgets: each request's budget
    /// lands in `[slo_p50 / slo_scale, slo_p50 * slo_scale]` (1 = all
    /// requests share the same budget).
    pub slo_scale: f64,
    /// Observability: "off" (zero-overhead default), "summary" (metric
    /// registry only), or "trace" (metrics + structured event sink).
    /// Defaults to the `SPECBATCH_TELEMETRY` env override, else off.
    pub telemetry: TelemetryMode,
    /// Seed for everything stochastic on the serving side.
    pub seed: u64,
}

/// Parsed policy choice (resolved into a live `policy::SpeculationPolicy`
/// object once the profiler has run / the LUT is loaded).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PolicySpec {
    None,
    Fixed(usize),
    /// offline LUT (the paper's scheme)
    Adaptive,
    /// online model-based speculation, LUT-seeded cold start
    ModelBased,
}

impl PolicySpec {
    pub fn parse(s: &str) -> Result<PolicySpec> {
        if s == "none" || s == "no-spec" {
            Ok(PolicySpec::None)
        } else if s == "adaptive" {
            Ok(PolicySpec::Adaptive)
        } else if s == "model-based" || s == "model" || s == "online" {
            Ok(PolicySpec::ModelBased)
        } else if let Some(v) = s.strip_prefix("fixed:").or_else(|| s.strip_prefix("fixed-")) {
            Ok(PolicySpec::Fixed(v.parse()?))
        } else {
            bail!("bad policy {s:?}: expected none | fixed:<s> | adaptive | model-based")
        }
    }

    pub fn label(&self) -> String {
        match self {
            PolicySpec::None => "no-spec".into(),
            PolicySpec::Fixed(s) => format!("fixed-{s}"),
            PolicySpec::Adaptive => "adaptive".into(),
            PolicySpec::ModelBased => "model-based".into(),
        }
    }
}

/// Parsed admission-control choice (resolved into a live
/// `admission::AdmissionController` by `admission::build_controller`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionSpec {
    /// arrival order, admit everything (the pre-subsystem behaviour)
    Fifo,
    /// earliest-deadline-first queue ordering, never defer or shed
    Edf,
    /// EDF plus model-predicted feasibility: defer predicted SLO misses,
    /// shed hopeless requests, degrade to EDF while the fits are cold
    SloAware,
}

impl AdmissionSpec {
    pub fn parse(s: &str) -> Result<AdmissionSpec> {
        match s {
            "fifo" => Ok(AdmissionSpec::Fifo),
            "edf" | "deadline" => Ok(AdmissionSpec::Edf),
            "slo" | "slo-aware" => Ok(AdmissionSpec::SloAware),
            other => bail!("bad admission {other:?}: expected fifo | edf | slo"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionSpec::Fifo => "fifo",
            AdmissionSpec::Edf => "edf",
            AdmissionSpec::SloAware => "slo-aware",
        }
    }

    /// All three controllers (the comparison set of the SLO benches).
    pub fn all() -> [AdmissionSpec; 3] {
        [
            AdmissionSpec::Fifo,
            AdmissionSpec::Edf,
            AdmissionSpec::SloAware,
        ]
    }

    /// The `SPECBATCH_ADMISSION` environment override, if set.  CI runs
    /// the stub suite under both `fifo` and `slo`; with no deadlines in
    /// a trace every controller is behaviourally FIFO, so the axis
    /// checks exactly that invariant across the whole suite.
    pub fn env_override() -> Option<AdmissionSpec> {
        let v = std::env::var("SPECBATCH_ADMISSION").ok()?;
        Some(AdmissionSpec::parse(&v).unwrap_or_else(|e| panic!("SPECBATCH_ADMISSION: {e}")))
    }

    /// Default controller: the env override, else FIFO.
    pub fn default_spec() -> AdmissionSpec {
        AdmissionSpec::env_override().unwrap_or(AdmissionSpec::Fifo)
    }
}

/// Parsed request-routing choice for multi-worker serving (resolved into
/// a live `cluster::Router` object by `cluster::build_router`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterSpec {
    /// cycle through the shards in arrival order
    RoundRobin,
    /// always pick the shard with the fewest live + queued requests
    JoinShortestQueue,
    /// probe two random shards, pick the lighter (power-of-two-choices)
    PowerOfTwo,
    /// pick the shard whose fitted round-cost model predicts the
    /// smallest marginal per-token latency increase (JSQ while cold)
    CostAware,
    /// cost-aware with the marginal penalized by each shard's predicted
    /// SLO misses (deadline-pressure-weighted placement)
    Deadline,
}

impl RouterSpec {
    pub fn parse(s: &str) -> Result<RouterSpec> {
        match s {
            "round-robin" | "rr" => Ok(RouterSpec::RoundRobin),
            "jsq" | "join-shortest-queue" | "shortest" => {
                Ok(RouterSpec::JoinShortestQueue)
            }
            "power-of-two" | "p2" | "po2" => Ok(RouterSpec::PowerOfTwo),
            "cost-aware" | "cost" => Ok(RouterSpec::CostAware),
            "deadline" | "deadline-aware" => Ok(RouterSpec::Deadline),
            other => bail!(
                "bad router {other:?}: expected round-robin | jsq | \
                 power-of-two | cost-aware | deadline"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RouterSpec::RoundRobin => "round-robin",
            RouterSpec::JoinShortestQueue => "jsq",
            RouterSpec::PowerOfTwo => "power-of-two",
            RouterSpec::CostAware => "cost-aware",
            RouterSpec::Deadline => "deadline",
        }
    }

    /// All five routing strategies (the comparison set of the cluster
    /// benches and examples).
    pub fn all() -> [RouterSpec; 5] {
        [
            RouterSpec::RoundRobin,
            RouterSpec::JoinShortestQueue,
            RouterSpec::PowerOfTwo,
            RouterSpec::CostAware,
            RouterSpec::Deadline,
        ]
    }
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            max_batch: 16,
            max_new_tokens: 128,
            stop_at_eos: true,
            policy: PolicySpec::Adaptive,
            workers: 1,
            router: RouterSpec::RoundRobin,
            kv_layout: KvLayout::Dense,
            admission: AdmissionSpec::Fifo,
            slo_p50: 0.0,
            slo_scale: 1.0,
            telemetry: TelemetryMode::default_mode(),
            seed: 0,
        }
    }
}

impl ServingConfig {
    /// Load from a JSON file; missing keys keep their defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let json = Json::parse_file(path)?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let mut cfg = ServingConfig::default();
        if let Some(v) = json.get_opt("artifacts_dir")? {
            cfg.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = json.get_opt("max_batch")? {
            cfg.max_batch = v.as_usize()?;
        }
        if let Some(v) = json.get_opt("max_new_tokens")? {
            cfg.max_new_tokens = v.as_usize()?;
        }
        if let Some(v) = json.get_opt("stop_at_eos")? {
            cfg.stop_at_eos = v.as_bool()?;
        }
        if let Some(v) = json.get_opt("policy")? {
            cfg.policy = PolicySpec::parse(v.as_str()?)?;
        }
        if let Some(v) = json.get_opt("workers")? {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = json.get_opt("router")? {
            cfg.router = RouterSpec::parse(v.as_str()?)?;
        }
        if let Some(v) = json.get_opt("kv_layout")? {
            cfg.kv_layout = KvLayout::parse(v.as_str()?)?;
        }
        if let Some(v) = json.get_opt("admission")? {
            cfg.admission = AdmissionSpec::parse(v.as_str()?)?;
        }
        if let Some(v) = json.get_opt("slo_p50")? {
            cfg.slo_p50 = v.as_f64()?;
        }
        if let Some(v) = json.get_opt("slo_scale")? {
            cfg.slo_scale = v.as_f64()?;
        }
        if let Some(v) = json.get_opt("telemetry")? {
            cfg.telemetry = TelemetryMode::parse(v.as_str()?)?;
        }
        if let Some(v) = json.get_opt("seed")? {
            cfg.seed = v.as_i64()? as u64;
        }
        if cfg.max_batch == 0 || cfg.max_new_tokens == 0 {
            bail!("max_batch and max_new_tokens must be positive");
        }
        if cfg.workers == 0 {
            bail!("workers must be positive (1 = single-worker serving)");
        }
        if cfg.slo_p50 < 0.0 || cfg.slo_scale < 1.0 {
            bail!("slo_p50 must be >= 0 and slo_scale >= 1");
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "artifacts_dir",
                Json::Str(self.artifacts_dir.display().to_string()),
            ),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("max_new_tokens", Json::Num(self.max_new_tokens as f64)),
            ("stop_at_eos", Json::Bool(self.stop_at_eos)),
            ("policy", Json::Str(self.policy.label())),
            ("workers", Json::Num(self.workers as f64)),
            ("router", Json::Str(self.router.label().into())),
            ("kv_layout", Json::Str(self.kv_layout.label().into())),
            ("admission", Json::Str(self.admission.label().into())),
            ("slo_p50", Json::Num(self.slo_p50)),
            ("slo_scale", Json::Num(self.slo_scale)),
            ("telemetry", Json::Str(self.telemetry.label().into())),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_methodology() {
        let c = ServingConfig::default();
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.max_new_tokens, 128);
        assert_eq!(c.policy, PolicySpec::Adaptive);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(PolicySpec::parse("none").unwrap(), PolicySpec::None);
        assert_eq!(PolicySpec::parse("fixed:4").unwrap(), PolicySpec::Fixed(4));
        assert_eq!(PolicySpec::parse("adaptive").unwrap(), PolicySpec::Adaptive);
        assert_eq!(
            PolicySpec::parse("model-based").unwrap(),
            PolicySpec::ModelBased
        );
        assert_eq!(PolicySpec::parse("online").unwrap(), PolicySpec::ModelBased);
        assert!(PolicySpec::parse("bogus").is_err());
        assert!(PolicySpec::parse("fixed:x").is_err());
    }

    #[test]
    fn model_based_roundtrips_through_json() {
        let c = ServingConfig {
            policy: PolicySpec::ModelBased,
            ..ServingConfig::default()
        };
        let c2 = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.policy, PolicySpec::ModelBased);
    }

    #[test]
    fn json_roundtrip() {
        let c = ServingConfig {
            max_batch: 8,
            policy: PolicySpec::Fixed(2),
            seed: 42,
            ..ServingConfig::default()
        };
        let j = c.to_json();
        let c2 = ServingConfig::from_json(&j).unwrap();
        assert_eq!(c2.max_batch, 8);
        assert_eq!(c2.policy, PolicySpec::Fixed(2));
        assert_eq!(c2.seed, 42);
    }

    #[test]
    fn from_json_partial_keeps_defaults() {
        let j = Json::parse(r#"{"max_batch": 4}"#).unwrap();
        let c = ServingConfig::from_json(&j).unwrap();
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.max_new_tokens, 128);
    }

    #[test]
    fn rejects_zero_batch() {
        let j = Json::parse(r#"{"max_batch": 0}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
    }

    #[test]
    fn router_parse_and_labels() {
        assert_eq!(
            RouterSpec::parse("round-robin").unwrap(),
            RouterSpec::RoundRobin
        );
        assert_eq!(RouterSpec::parse("rr").unwrap(), RouterSpec::RoundRobin);
        assert_eq!(
            RouterSpec::parse("jsq").unwrap(),
            RouterSpec::JoinShortestQueue
        );
        assert_eq!(RouterSpec::parse("p2").unwrap(), RouterSpec::PowerOfTwo);
        assert_eq!(
            RouterSpec::parse("cost-aware").unwrap(),
            RouterSpec::CostAware
        );
        assert!(RouterSpec::parse("bogus").is_err());
        for spec in RouterSpec::all() {
            assert_eq!(RouterSpec::parse(spec.label()).unwrap(), spec);
        }
    }

    #[test]
    fn admission_parse_labels_and_roundtrip() {
        assert_eq!(AdmissionSpec::parse("fifo").unwrap(), AdmissionSpec::Fifo);
        assert_eq!(AdmissionSpec::parse("edf").unwrap(), AdmissionSpec::Edf);
        assert_eq!(
            AdmissionSpec::parse("deadline").unwrap(),
            AdmissionSpec::Edf
        );
        assert_eq!(AdmissionSpec::parse("slo").unwrap(), AdmissionSpec::SloAware);
        assert_eq!(
            AdmissionSpec::parse("slo-aware").unwrap(),
            AdmissionSpec::SloAware
        );
        assert!(AdmissionSpec::parse("bogus").is_err());
        for spec in AdmissionSpec::all() {
            assert_eq!(AdmissionSpec::parse(spec.label()).unwrap(), spec);
        }
        let c = ServingConfig {
            admission: AdmissionSpec::SloAware,
            slo_p50: 2.5,
            slo_scale: 3.0,
            ..ServingConfig::default()
        };
        let c2 = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.admission, AdmissionSpec::SloAware);
        assert_eq!(c2.slo_p50, 2.5);
        assert_eq!(c2.slo_scale, 3.0);
        // defaults: FIFO, no deadlines
        let d = ServingConfig::default();
        assert_eq!(d.admission, AdmissionSpec::Fifo);
        assert_eq!(d.slo_p50, 0.0);
        // invalid SLO shapes rejected
        let j = Json::parse(r#"{"slo_scale": 0.5}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"slo_p50": -1.0}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
    }

    #[test]
    fn kv_layout_roundtrips_and_defaults_dense() {
        assert_eq!(ServingConfig::default().kv_layout, KvLayout::Dense);
        let c = ServingConfig {
            kv_layout: KvLayout::Paged,
            ..ServingConfig::default()
        };
        let c2 = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.kv_layout, KvLayout::Paged);
        let j = Json::parse(r#"{"kv_layout": "ragged"}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
    }

    #[test]
    fn telemetry_mode_roundtrips_and_rejects_garbage() {
        let c = ServingConfig {
            telemetry: TelemetryMode::Trace,
            ..ServingConfig::default()
        };
        let c2 = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.telemetry, TelemetryMode::Trace);
        let j = Json::parse(r#"{"telemetry": "summary"}"#).unwrap();
        assert_eq!(
            ServingConfig::from_json(&j).unwrap().telemetry,
            TelemetryMode::Summary
        );
        let j = Json::parse(r#"{"telemetry": "verbose"}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
    }

    #[test]
    fn cluster_fields_roundtrip_and_validate() {
        let c = ServingConfig {
            workers: 4,
            router: RouterSpec::CostAware,
            ..ServingConfig::default()
        };
        let c2 = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.workers, 4);
        assert_eq!(c2.router, RouterSpec::CostAware);
        // defaults stay single-worker round-robin
        let d = ServingConfig::default();
        assert_eq!(d.workers, 1);
        assert_eq!(d.router, RouterSpec::RoundRobin);
        let j = Json::parse(r#"{"workers": 0}"#).unwrap();
        assert!(ServingConfig::from_json(&j).is_err());
    }
}
