//! Feedback-driven speculation policies — the open policy subsystem that
//! replaced the closed `scheduler::SpecPolicy` enum.
//!
//! Every serving round the driver (engine, continuous batcher, DES
//! simulator) asks the policy for a speculation length via
//! [`SpeculationPolicy::choose`] and, once the round completes, feeds the
//! outcome back through [`SpeculationPolicy::observe`]: the live batch
//! size, the `s` actually used, per-row accepted counts, and the measured
//! round latency (wall time on the engine, virtual time in the DES).
//! Static policies ignore the feedback; [`ModelBased`] uses it to keep
//! *online* fits of the paper's quantitative model (Sec. 3.3) and
//! re-solve `s_opt` as the workload drifts:
//!
//! * **acceptance** — windowed Eq. 4 estimator + Eq. 5 power-law fit
//!   (`l(s) ≈ c·s^γ`) over recent accepted-count samples, each paired
//!   with the `s` it was observed under so clipped rounds never bias the
//!   tail of the curve;
//! * **step cost** — per power-of-two batch bucket, a linear fit of
//!   measured round latency against `s` (Fig. 3's `α_b·s + β`, with the
//!   SSM's per-draft cost folded into the slope — the paper's `α'_b`
//!   of Eq. 11);
//! * **decision** — Eq. 7 total-time argmin per bucket with
//!   **hysteresis** (switching requires a relative predicted improvement
//!   of at least [`ModelBasedConfig::hysteresis`]) and a **cold-start
//!   fallback** to an offline [`Lut`] until both fits are warm.  A
//!   deterministic probe round every [`ModelBasedConfig::explore_every`]
//!   rounds tries `s + 1` so `l(s)` stays identifiable above the
//!   committed choice.
//!
//! Implementations: [`NoSpec`], [`Fixed`], [`LutAdaptive`] (the paper's
//! offline scheme, smaller-of-neighbours interpolation preserved), and
//! the online [`ModelBased`].

use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, VecDeque};

use crate::analytic::{AcceptanceModel, StepCostModel, TotalTimeModel};
use crate::scheduler::Lut;
use crate::util::json::Json;
use crate::util::stats::linear_fit;

/// Largest speculation length the online solver considers; the driver's
/// `max_s` cap is applied afterwards in [`SpeculationPolicy::choose`].
const MAX_SOLVE_S: usize = 12;

/// Once the acceptance fit is warm, the O(window·s) curve rebuild is
/// amortized to every Nth observation.
const ACCEPT_REFIT_EVERY: usize = 4;

/// EWMA rate of the CUSUM detector's residual-variance estimate: slow
/// enough that a changepoint raises the statistic long before the
/// yardstick absorbs it (~50 rounds to adapt).
const CUSUM_VAR_EWMA: f64 = 0.02;

/// Probe cadence while re-identifying after a CUSUM flush: at one sample
/// per round (live = 1) the flushed window needs `s >= 2` samples before
/// the Eq. 5 curve has two points again, and the normal 1-in-16 probes
/// would starve the refit long enough for the stale fit to re-alarm.
const FLUSH_REPROBE_EVERY: usize = 4;

/// Everything a policy may learn from one completed decode round.
#[derive(Debug, Clone, Default)]
pub struct RoundFeedback {
    /// live batch size the policy was queried with
    pub live: usize,
    /// batch width the round actually executed at (the padded bucket on
    /// the engine; equals `live` when nothing is padded) — round cost
    /// scales with this, not with `live`
    pub width: usize,
    /// widest speculation length actually used (0 = plain round); on a
    /// ragged round this is `max(s_rows)`, the length execution padded to
    pub s: usize,
    /// drafts accepted per live real row (empty when `s == 0`)
    pub accepted: Vec<u32>,
    /// per-row speculation lengths actually drafted, parallel to
    /// `accepted`.  Empty means the round was uniform: every row drafted
    /// exactly `s` (today's scalar path, bit-for-bit)
    pub s_rows: Vec<u32>,
    /// per-row class tags, parallel to `accepted`.  Empty means the
    /// round carried no class information (everything is class 0)
    pub classes: Vec<u8>,
    /// tokens committed to real rows this round
    pub committed: usize,
    /// measured round latency in seconds (wall or virtual)
    pub round_time: f64,
}

/// A speculation-length policy with a feedback edge.
///
/// `choose` is read-only (drivers may query it for metadata without
/// perturbing the learned state); all adaptation happens in `observe`.
pub trait SpeculationPolicy {
    /// Speculation length for a round serving `live` requests.  `max_s`
    /// caps at what the executable matrix provides.
    fn choose(&self, live: usize, max_s: usize) -> usize;

    /// Per-row speculation lengths for a round serving `rows.len()`
    /// requests, one entry per live row in batch order; `rows[i]` is the
    /// row's class tag (0 = untagged).  The default broadcasts
    /// [`choose`](Self::choose), so every policy that does not override
    /// this is bit-identical to the scalar path; class-aware policies
    /// ([`ModelBased`]) return genuinely ragged vectors once their
    /// per-class fits are warm.  Execution cost is paid at
    /// `max(s_rows)` (padded verify), so a policy only benefits from
    /// raggedness through the shrinking draft width.
    fn choose_ragged(&self, rows: &[u8], max_s: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(rows.len());
        self.choose_ragged_into(rows, max_s, &mut out);
        out
    }

    /// Allocation-free spelling of [`choose_ragged`]: clears `out` and
    /// fills it with one `s` per row.  Drivers on the zero-allocation
    /// hot path reuse `out` across rounds.
    ///
    /// [`choose_ragged`]: Self::choose_ragged
    fn choose_ragged_into(&self, rows: &[u8], max_s: usize, out: &mut Vec<usize>) {
        out.clear();
        out.resize(rows.len(), self.choose(rows.len(), max_s));
    }

    /// Ingest one round of feedback (no-op for static policies).
    fn observe(&mut self, _feedback: &RoundFeedback) {}

    /// Whether the policy can ever speculate (gates the SSM prefill).
    fn wants_speculation(&self) -> bool {
        true
    }

    /// Predicted per-token request latency (seconds) a batch of `live`
    /// requests would see under this policy's current model of the world,
    /// or `None` when the policy has no such model (static policies, or
    /// an online policy that is still cold).  The cluster's cost-aware
    /// router ([`crate::cluster`]) consults this to place new requests on
    /// the shard where they hurt least.
    fn predict_token_time(&self, _live: usize) -> Option<f64> {
        None
    }

    fn label(&self) -> String;

    /// Fitted-model snapshot for experiment reports (online policies).
    fn snapshot(&self) -> Option<Json> {
        None
    }

    /// Cumulative acceptance-window flushes fired by the policy's drift
    /// detector, 0 for policies without one.  Drivers poll this between
    /// rounds: an increment is a changepoint the operator will want the
    /// surrounding rounds for, so it arms a flight-recorder dump.
    fn drift_flushes(&self) -> usize {
        0
    }
}

/// Plain batched decoding (the paper's baseline).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoSpec;

impl SpeculationPolicy for NoSpec {
    fn choose(&self, _live: usize, _max_s: usize) -> usize {
        0
    }

    fn wants_speculation(&self) -> bool {
        false
    }

    fn label(&self) -> String {
        "no-spec".into()
    }
}

/// Fixed speculation length regardless of batch size (prior schemes).
///
/// `Fixed(0)` is deliberately equivalent to [`NoSpec`] — it reports
/// `wants_speculation() == false`, so drivers skip the SSM prefill
/// entirely instead of paying for a draft model that never runs.
#[derive(Debug, Clone, Copy)]
pub struct Fixed(pub usize);

impl SpeculationPolicy for Fixed {
    fn choose(&self, _live: usize, max_s: usize) -> usize {
        self.0.min(max_s)
    }

    fn wants_speculation(&self) -> bool {
        self.0 > 0
    }

    fn label(&self) -> String {
        format!("fixed-{}", self.0)
    }
}

/// The paper's adaptive scheme: `s = LUT[batch]`, built by offline
/// profiling, with the smaller-of-neighbours interpolation rule.
#[derive(Debug, Clone)]
pub struct LutAdaptive(pub Lut);

impl SpeculationPolicy for LutAdaptive {
    fn choose(&self, live: usize, max_s: usize) -> usize {
        self.0.lookup(live).min(max_s)
    }

    fn label(&self) -> String {
        "adaptive".into()
    }
}

/// Knobs of the online [`ModelBased`] policy.
#[derive(Debug, Clone)]
pub struct ModelBasedConfig {
    /// accepted-count samples kept (one per live row per spec round)
    pub acceptance_window: usize,
    /// (s, round latency) points kept per batch bucket
    pub cost_window: usize,
    /// samples required before the acceptance fit is trusted
    pub min_acceptance_samples: usize,
    /// cost points required per bucket before its fit is trusted
    pub min_cost_points: usize,
    /// relative predicted improvement required to switch `s`
    pub hysteresis: f64,
    /// every Nth round at a bucket probes `max(s + 1, 2)` (0 disables
    /// probing)
    pub explore_every: usize,
    /// CUSUM drift detector slack per round, in units of the running
    /// residual std (the normalized mean shift the detector deliberately
    /// ignores); see `cusum_h`
    pub cusum_k: f64,
    /// CUSUM alarm threshold in residual-std units: when the two-sided
    /// statistic over normalized per-round acceptance residuals crosses
    /// it, the acceptance window is flushed so the next refit sees only
    /// post-changepoint samples (0 disables drift detection)
    pub cusum_h: f64,
}

impl Default for ModelBasedConfig {
    fn default() -> Self {
        ModelBasedConfig {
            acceptance_window: 512,
            cost_window: 64,
            min_acceptance_samples: 48,
            min_cost_points: 6,
            hysteresis: 0.02,
            explore_every: 16,
            cusum_k: 0.5,
            cusum_h: 12.0,
        }
    }
}

/// One row class's private acceptance window + Eq. 5 fit.  Class
/// windows exist *next to* the global window: the global fit keeps
/// serving `choose` (so classless runs are bit-identical to the
/// pre-ragged policy), while per-class fits drive
/// [`SpeculationPolicy::choose_ragged_into`] for mixed-class batches.
#[derive(Debug, Clone, Default)]
struct ClassWindow {
    /// windowed (accepted, s_used) samples, newest at the back
    samples: VecDeque<(u32, u32)>,
    /// latest per-class Eq. 5 fit (None until warm) — kept for
    /// snapshots and external inspection
    fit: Option<AcceptanceModel>,
    /// empirical acceptance curve: mean accepted tokens at s = 1.. —
    /// what the per-class argmin actually consumes (see
    /// [`class_time_per_token`] for why the parametric fit is not used
    /// here); empty until warm
    curve: Vec<f64>,
    /// rounds this class contributed samples to (amortizes the refit)
    observes: usize,
    /// per-class committed choice — the ragged analogue of
    /// [`ModelBased::current`].  Re-solving Eq. 7 from the raw fits on
    /// every round would let cost-fit noise flip the class between
    /// adjacent `s` values each refit, so the choice only moves when
    /// the predicted improvement clears the same hysteresis band the
    /// scalar path uses.
    committed: Option<usize>,
}

/// Rebuild an Eq. 4/5 acceptance curve from one sample window — the
/// same estimator [`ModelBased::refit_acceptance`] applies to the
/// global window, extracted so per-class windows share it.  Returns a
/// fit only when the curve has >= 2 stable points AND the fit is
/// sublinear (Eq. 6); callers keep their previous fit otherwise.
fn acceptance_curve(samples: &VecDeque<(u32, u32)>, min_samples: usize) -> Vec<f64> {
    let s_hi = samples
        .iter()
        .map(|&(_, s_used)| s_used as usize)
        .max()
        .unwrap_or(0);
    let mut curve: Vec<f64> = Vec::new();
    for s in 1..=s_hi {
        let mut sum = 0.0;
        let mut n = 0usize;
        for &(a, s_used) in samples {
            if s_used as usize >= s {
                sum += (a as usize).min(s) as f64;
                n += 1;
            }
        }
        // a curve point needs enough unclipped samples to be stable
        if n * 4 < min_samples {
            break;
        }
        // floor keeps the log-log regression finite when acceptance
        // collapses entirely
        curve.push((sum / n as f64).max(1e-3));
    }
    curve
}

fn fit_acceptance_window(
    samples: &VecDeque<(u32, u32)>,
    min_samples: usize,
) -> Option<AcceptanceModel> {
    let curve = acceptance_curve(samples, min_samples);
    if curve.len() < 2 {
        return None;
    }
    AcceptanceModel::fit(&curve).ok().filter(|f| f.is_sublinear())
}

/// Predicted per-token time for a class executing at integer `s`,
/// from its **empirical** acceptance curve (mean accepted tokens at
/// each observed `s`, flat-tailed beyond the observed support) and the
/// bucket's step-cost fit.  The parametric Eq. 5 power fit serves the
/// global blended window well — its optimum sits far from the verify
/// knee — but `c·s^γ` cannot represent geometric saturation, so for a
/// slowly-decaying class it exaggerates the tail and the argmin chases
/// phantom tokens past the knee.  The empirical curve says exactly
/// what was measured and assumes saturation beyond it, which makes
/// per-token time strictly worsen past the support: the class argmin
/// can only move up after a probe has measured the next step.
fn class_time_per_token(curve: &[f64], cost: &StepCostModel, s: usize) -> f64 {
    if s == 0 {
        return cost.beta;
    }
    let l = curve[(s - 1).min(curve.len() - 1)];
    (cost.beta + (cost.alpha + cost.t_ssm) * s as f64) / (l + 1.0)
}

/// Argmin of [`class_time_per_token`] over `0..=cap`.
fn class_s_opt(curve: &[f64], cost: &StepCostModel, cap: usize) -> usize {
    let mut best = (0, class_time_per_token(curve, cost, 0));
    for s in 1..=cap {
        let t = class_time_per_token(curve, cost, s);
        if t < best.1 {
            best = (s, t);
        }
    }
    best.0
}

/// Online model-based speculation: ingests [`RoundFeedback`], maintains
/// windowed acceptance / step-cost fits, and re-solves `s_opt(live)`
/// with hysteresis and a cold-start fallback to an offline LUT.
pub struct ModelBased {
    cfg: ModelBasedConfig,
    fallback: Lut,
    /// windowed (accepted, s_used) samples, newest at the back
    accept_samples: VecDeque<(u32, u32)>,
    /// per bucket: windowed (s, measured round seconds) points
    cost_points: BTreeMap<usize, VecDeque<(f64, f64)>>,
    /// per bucket: rounds observed (drives the probe cadence)
    rounds_seen: BTreeMap<usize, usize>,
    /// per bucket: committed choice (the hysteresis state)
    current: BTreeMap<usize, usize>,
    /// latest Eq. 5 fit (None until warm)
    acceptance: Option<AcceptanceModel>,
    /// latest Fig. 3 fit per bucket (t_ssm folded into alpha)
    cost_fit: BTreeMap<usize, StepCostModel>,
    /// total observations (amortizes the acceptance refit)
    observes: usize,
    /// two-sided CUSUM statistics over normalized per-round acceptance
    /// residuals
    cusum_pos: f64,
    cusum_neg: f64,
    /// slow EWMA of the squared per-round residual (the normalizing
    /// variance; None until the first residual)
    resid_var: Option<f64>,
    /// a flush happened and the acceptance fit has not refit since:
    /// probe at the escalated cadence until it does
    flush_reprobe: bool,
    /// acceptance-window flushes triggered by the CUSUM detector
    drift_flushes: usize,
    /// per cost bucket: (total round seconds, total committed tokens) —
    /// the *realized* per-token cost the fits can be audited against
    realized: BTreeMap<usize, (f64, usize)>,
    /// per row class: private acceptance window + fit, feeding the
    /// ragged per-row decision (empty until classed feedback arrives)
    class_acc: BTreeMap<u8, ClassWindow>,
}

impl ModelBased {
    pub fn new(fallback: Lut) -> ModelBased {
        ModelBased::with_config(fallback, ModelBasedConfig::default())
    }

    pub fn with_config(fallback: Lut, cfg: ModelBasedConfig) -> ModelBased {
        ModelBased {
            cfg,
            fallback,
            accept_samples: VecDeque::new(),
            cost_points: BTreeMap::new(),
            rounds_seen: BTreeMap::new(),
            current: BTreeMap::new(),
            acceptance: None,
            cost_fit: BTreeMap::new(),
            observes: 0,
            cusum_pos: 0.0,
            cusum_neg: 0.0,
            resid_var: None,
            flush_reprobe: false,
            drift_flushes: 0,
            realized: BTreeMap::new(),
            class_acc: BTreeMap::new(),
        }
    }

    /// Pre-seeded instance for analysis/tests: the fits are installed
    /// directly and `choose` solves from them (no committed choices yet).
    /// Each cost model's `t_ssm` should already be folded into `alpha`,
    /// matching what the online fit produces.
    pub fn with_models(
        fallback: Lut,
        acceptance: AcceptanceModel,
        costs: &[StepCostModel],
    ) -> ModelBased {
        let mut p = ModelBased::new(fallback);
        p.acceptance = Some(acceptance);
        for m in costs {
            p.cost_fit.insert(m.batch, *m);
        }
        p
    }

    /// Power-of-two bucket a live batch size falls into.
    pub fn bucket_of(live: usize) -> usize {
        live.max(1).next_power_of_two()
    }

    /// Latest acceptance fit, if warm.
    pub fn fitted_acceptance(&self) -> Option<AcceptanceModel> {
        self.acceptance
    }

    /// Latest per-class acceptance fit, if that class's window is warm.
    pub fn fitted_class_acceptance(&self, class: u8) -> Option<AcceptanceModel> {
        self.class_acc.get(&class).and_then(|w| w.fit)
    }

    /// Latest step-cost fit for a bucket, if warm.
    pub fn fitted_cost(&self, bucket: usize) -> Option<StepCostModel> {
        self.cost_fit.get(&bucket).copied()
    }

    /// Committed choice for a bucket (None before the first solve).
    pub fn committed_choice(&self, bucket: usize) -> Option<usize> {
        self.current.get(&bucket).copied()
    }

    /// Acceptance-window flushes the CUSUM changepoint detector fired.
    pub fn drift_flushes(&self) -> usize {
        self.drift_flushes
    }

    /// Measured per-token cost at a bucket: total round seconds over
    /// total committed tokens, across every round filed there.  `None`
    /// until the bucket has committed at least one token.
    pub fn realized_token_time(&self, bucket: usize) -> Option<f64> {
        self.realized
            .get(&bucket)
            .filter(|&&(_, n)| n > 0)
            .map(|&(t, n)| t / n as f64)
    }

    /// The step-cost fit serving a bucket: exact hit, else the nearest
    /// fitted bucket above (conservative: larger batches imply a larger
    /// α'_b and thus a smaller s_opt), else the largest below.
    fn cost_for(&self, bucket: usize) -> Option<&StepCostModel> {
        if let Some(m) = self.cost_fit.get(&bucket) {
            return Some(m);
        }
        if let Some((_, m)) = self.cost_fit.range(bucket..).next() {
            return Some(m);
        }
        self.cost_fit.range(..bucket).next_back().map(|(_, m)| m)
    }

    /// Largest speculation length the bucket's cost window actually
    /// measured, resolved with the same nearest-bucket fallback as
    /// [`ModelBased::cost_for`].  The per-class Eq. 7 argmin is capped
    /// one step past this: a cost fit is only trustworthy inside its
    /// data support, and letting a slowly-decaying acceptance class
    /// chase an extrapolated fit past the verify knee is how a class
    /// gets slammed to the cap (probes extend the support one honest,
    /// paid-for step at a time instead).
    fn cost_support_max(&self, bucket: usize) -> Option<usize> {
        let pts = if let Some(p) = self.cost_points.get(&bucket) {
            Some(p)
        } else if let Some((_, p)) = self.cost_points.range(bucket..).next() {
            Some(p)
        } else {
            self.cost_points.range(..bucket).next_back().map(|(_, p)| p)
        };
        pts.and_then(|p| p.iter().map(|&(s, _)| s as usize).max())
    }

    /// Eq. 7 argmin at a bucket from the current fits (None while cold).
    fn solve(&self, bucket: usize) -> Option<usize> {
        let acceptance = self.acceptance?;
        let cost = *self.cost_for(bucket)?;
        let model = TotalTimeModel { acceptance, cost };
        Some(model.s_opt(MAX_SOLVE_S))
    }

    /// Re-estimate `l(s) = c·s^γ` from the sample window.  Point `s` of
    /// the Eq. 4 curve averages `min(accepted, s)` over samples whose
    /// round used a speculation length >= s (shorter rounds would clip
    /// the estimate).
    fn refit_acceptance(&mut self) {
        if self.accept_samples.len() < self.cfg.min_acceptance_samples {
            return;
        }
        // the full curve rebuild is O(window·s); once a fit exists,
        // amortize it — the window only shifts by one round per call
        if self.acceptance.is_some() && self.observes % ACCEPT_REFIT_EVERY != 0 {
            return;
        }
        let s_hi = self
            .accept_samples
            .iter()
            .map(|&(_, s_used)| s_used as usize)
            .max()
            .unwrap_or(0);
        let mut curve: Vec<f64> = Vec::new();
        for s in 1..=s_hi {
            let mut sum = 0.0;
            let mut n = 0usize;
            for &(a, s_used) in &self.accept_samples {
                if s_used as usize >= s {
                    sum += (a as usize).min(s) as f64;
                    n += 1;
                }
            }
            // a curve point needs enough unclipped samples to be stable
            if n * 4 < self.cfg.min_acceptance_samples {
                break;
            }
            // floor keeps the log-log regression finite when acceptance
            // collapses entirely
            curve.push((sum / n as f64).max(1e-3));
        }
        if curve.len() >= 2 {
            if let Ok(fit) = AcceptanceModel::fit(&curve) {
                // Eq. 6 guarantees any true l(s) = E[min(L, s)] curve is
                // sublinear, so a fit with γ >= 1 can only be window
                // noise (a two-point log-log fit always reports r² = 1)
                // — and the Eq. 7 argmin would reward it by slamming s
                // to the cap.  Keep the previous fit instead.
                if fit.is_sublinear() {
                    self.acceptance = Some(fit);
                    // the fit now reflects the post-flush window
                    self.flush_reprobe = false;
                }
            }
        }
    }

    /// Re-fit `round_time(s) ≈ α'_b·s + β` for one bucket's window.
    fn refit_cost(&mut self, bucket: usize) {
        let Some(pts) = self.cost_points.get(&bucket) else {
            return;
        };
        if pts.len() < self.cfg.min_cost_points {
            return;
        }
        let xs: Vec<f64> = pts.iter().map(|&(s, _)| s).collect();
        let ys: Vec<f64> = pts.iter().map(|&(_, t)| t).collect();
        // the fit needs at least two distinct s values in the window
        if xs.iter().all(|&x| x == xs[0]) {
            return;
        }
        let (slope, intercept, r2) = linear_fit(&xs, &ys);
        let alpha_new = slope.max(0.0);
        let beta_new = intercept.max(1e-9);
        // blend with the previous fit, weighted by this window's r²: a
        // noisy window (slope explains little variance) barely moves the
        // model, while the noiseless DES world (r² ≈ 1) updates at full
        // speed — this keeps wall-clock jitter from thrashing s_opt
        let (alpha, beta) = match self.cost_fit.get(&bucket) {
            Some(prev) => {
                let w = r2.clamp(0.0, 1.0);
                (
                    prev.alpha + w * (alpha_new - prev.alpha),
                    prev.beta + w * (beta_new - prev.beta),
                )
            }
            None => (alpha_new, beta_new),
        };
        self.cost_fit.insert(
            bucket,
            StepCostModel {
                batch: bucket,
                // the slope already merges the SSM draft cost (α'_b of
                // Eq. 11), so t_ssm stays 0 in the total-time model
                alpha,
                beta,
                t_ssm: 0.0,
                r2,
            },
        );
    }

    /// Two-sided CUSUM over **normalized** per-round acceptance
    /// residuals: the round's mean accepted count minus what the current
    /// fit predicts at the `s` the round used, divided by a slow running
    /// estimate of the residual std (residual variance scales with both
    /// the batch size and the acceptance process, so an un-normalized
    /// statistic either false-alarms at small batch or goes deaf at
    /// large).  An alarm means the acceptance process shifted faster
    /// than the sliding window can track (a workload change, a draft
    /// model gone stale), so the stale window is **flushed**: the
    /// previous fit keeps serving until `min_acceptance_samples`
    /// post-changepoint samples justify a fresh one, cutting
    /// re-convergence from a full window turnover (`acceptance_window`
    /// samples) to a warmup (`min_acceptance_samples`).
    fn cusum_step(&mut self, fb: &RoundFeedback) {
        if self.cfg.cusum_h <= 0.0 || fb.s == 0 || fb.accepted.is_empty() {
            return;
        }
        // residuals need a reference model; while cold the window is all
        // post-start data anyway
        let Some(acc) = self.acceptance else {
            return;
        };
        // hold the detector while the window is below the refit
        // threshold: right after a flush the serving fit is still the
        // pre-changepoint one, and accumulating its (large) residuals
        // would re-alarm before the window can ever refill — at one
        // sample per round that loop starves the refit forever
        if self.accept_samples.len() < self.cfg.min_acceptance_samples {
            return;
        }
        let observed = fb.accepted.iter().map(|&a| a as f64).sum::<f64>()
            / fb.accepted.len() as f64;
        let expected = acc.l(fb.s as f64).min(fb.s as f64);
        let r = observed - expected;
        let Some(var) = self.resid_var else {
            // the first residual lands right after the fit installed and
            // is often near zero; floor the initial variance at a sane
            // acceptance-noise prior (σ = 0.2 drafts) so one lucky round
            // cannot make every ordinary residual look like an alarm
            self.resid_var = Some((r * r).max(0.04));
            return;
        };
        let sigma = var.sqrt().max(0.05);
        let z = r / sigma;
        self.cusum_pos = (self.cusum_pos + z - self.cfg.cusum_k).max(0.0);
        self.cusum_neg = (self.cusum_neg - z - self.cfg.cusum_k).max(0.0);
        let alarm =
            self.cusum_pos > self.cfg.cusum_h || self.cusum_neg > self.cfg.cusum_h;
        // the variance EWMA updates after the decision, so a shift
        // inflates the statistic before it inflates the yardstick
        self.resid_var = Some(var + CUSUM_VAR_EWMA * (r * r - var));
        if alarm {
            self.accept_samples.clear();
            self.cusum_pos = 0.0;
            self.cusum_neg = 0.0;
            self.flush_reprobe = true;
            self.drift_flushes += 1;
        }
    }

    /// Re-solve the bucket's `s_opt` and commit it through hysteresis.
    fn update_choice(&mut self, bucket: usize) {
        let Some(acceptance) = self.acceptance else {
            return;
        };
        let Some(cost) = self.cost_fit.get(&bucket).copied() else {
            return;
        };
        let model = TotalTimeModel { acceptance, cost };
        let s_new = model.s_opt(MAX_SOLVE_S);
        match self.current.entry(bucket) {
            Entry::Vacant(v) => {
                v.insert(s_new);
            }
            Entry::Occupied(mut o) => {
                let cur = *o.get();
                if s_new != cur {
                    let t = |s: usize| {
                        if s == 0 {
                            model.time_per_token_nospec()
                        } else {
                            model.time_per_token(s as f64)
                        }
                    };
                    if t(cur) > t(s_new) * (1.0 + self.cfg.hysteresis) {
                        o.insert(s_new);
                    }
                }
            }
        }
    }
}

impl SpeculationPolicy for ModelBased {
    fn choose(&self, live: usize, max_s: usize) -> usize {
        let bucket = ModelBased::bucket_of(live);
        let base = match self.current.get(&bucket) {
            Some(&s) => s,
            None => match self.solve(bucket) {
                Some(s) => s,
                // cold start: behave exactly like the offline LUT
                None => self.fallback.lookup(live),
            },
        };
        let rounds = self.rounds_seen.get(&bucket).copied().unwrap_or(0);
        // escalated cadence while re-identifying after a CUSUM flush
        // (probing stays off if the user disabled it entirely)
        let every = if self.flush_reprobe && self.cfg.explore_every > 0 {
            FLUSH_REPROBE_EVERY.min(self.cfg.explore_every)
        } else {
            self.cfg.explore_every
        };
        let probe = every > 0 && rounds % every == every - 1;
        let s = if probe {
            // probes reach for s = 2 so the Eq. 4 curve keeps >= 2
            // points even from a committed s of 0/1 (a bucket parked at
            // no-spec must still notice acceptance recovering); when the
            // upward probe cannot move (base at the cap) they step DOWN
            // instead, so the cost fit still sees two distinct s values
            let up = (base + 1).max(2).min(max_s);
            if up != base {
                up
            } else {
                base.saturating_sub(1)
            }
        } else {
            base
        };
        s.min(max_s)
    }

    /// Ragged per-row decision: rows whose class has a warm private
    /// acceptance window get their own Eq. 7 argmin (empirical
    /// acceptance curve from the class window, step cost from the
    /// batch bucket's global fit — cost depends on the execution
    /// shape, not on who sits in it), committed through hysteresis at
    /// observe time; cold classes ride the scalar `choose` result.  A single-regime
    /// batch short-circuits to an exact broadcast of `choose`, so runs
    /// where every row shares one class recover the uniform policy
    /// bit-for-bit.
    fn choose_ragged_into(&self, rows: &[u8], max_s: usize, out: &mut Vec<usize>) {
        out.clear();
        if rows.is_empty() {
            return;
        }
        let live = rows.len();
        let base = self.choose(live, max_s);
        let first = rows[0];
        if rows.iter().all(|&c| c == first) {
            out.resize(live, base);
            return;
        }
        let bucket = ModelBased::bucket_of(live);
        let cost = self.cost_for(bucket).copied();
        for &class in rows {
            let mut s_class = base;
            if let (Some(w), Some(cost)) = (self.class_acc.get(&class), cost) {
                if !w.curve.is_empty() {
                    // serve the hysteresis-committed choice; fall back
                    // to a fresh solve only before the first commit
                    s_class = w.committed.unwrap_or_else(|| {
                        let cap = self
                            .cost_support_max(bucket)
                            .map_or(MAX_SOLVE_S, |hi| (hi + 1).min(MAX_SOLVE_S));
                        class_s_opt(&w.curve, &cost, cap)
                    });
                    // a class parked at s = 0 stops feeding its window;
                    // probe it on the global cadence (keyed by the
                    // class's own observe count) so recovery stays
                    // detectable — the same reach-for-2 rule as the
                    // scalar probe, and the only way the empirical
                    // curve (and thus the committed choice) can extend
                    // one step past its current support
                    let every = self.cfg.explore_every;
                    if every > 0 && w.observes % every == every - 1 {
                        s_class = (s_class + 1).max(2);
                    }
                }
            }
            out.push(s_class.min(max_s));
        }
    }

    /// Per-token latency prediction from the current fits at the bucket a
    /// batch of `live` requests would execute in, evaluated at the `s`
    /// the policy would commit there — the cost-aware router's signal.
    /// `None` while either fit is cold (the router falls back to JSQ).
    fn predict_token_time(&self, live: usize) -> Option<f64> {
        let bucket = ModelBased::bucket_of(live);
        let acceptance = self.acceptance?;
        let cost = *self.cost_for(bucket)?;
        let model = TotalTimeModel { acceptance, cost };
        let s = match self.current.get(&bucket) {
            Some(&s) => s,
            None => model.s_opt(MAX_SOLVE_S),
        };
        let t = if s == 0 {
            model.time_per_token_nospec()
        } else {
            model.time_per_token(s as f64)
        };
        t.is_finite().then_some(t)
    }

    fn observe(&mut self, fb: &RoundFeedback) {
        if fb.live == 0 {
            return;
        }
        // decisions are keyed by the LIVE batch (the paper's axis), but
        // cost observations by the width the round actually executed at
        // — in batch-to-completion mode rows finish while the padded
        // bucket keeps charging full-width rounds, and filing those
        // times under the shrinking live count would corrupt the
        // small-bucket fits
        let live_bucket = ModelBased::bucket_of(fb.live);
        let cost_bucket = ModelBased::bucket_of(fb.width.max(fb.live));
        // a ragged round drafted different lengths per row: its scalar
        // `s` is only the padding width, so the CUSUM residual (which
        // compares the round's mean accepted count against the fit *at
        // that s*) would be fed a mislabeled x — skip it; the per-sample
        // acceptance path below carries the true per-row `s` and stays
        // exact.  The cost point keeps flowing, labeled `s_max`: padded
        // verify means the round's cost IS the cost of executing at the
        // padding width, and without these points the per-class Eq. 7
        // argmin would extrapolate a fit identified entirely in the
        // flat (memory-bound) region past the verify knee — slamming a
        // high-acceptance class to the cap and never observing the cost
        // that choice incurs, because the resulting rounds are all
        // ragged.  Feeding (s_max, round_time) closes that loop: an
        // overreaching class choice shows up in the very next refit.
        let ragged = !fb.s_rows.is_empty();
        if fb.s >= 1 {
            for (i, &a) in fb.accepted.iter().enumerate() {
                let s_i = fb.s_rows.get(i).copied().unwrap_or(fb.s as u32);
                if s_i >= 1 {
                    self.accept_samples.push_back((a, s_i));
                }
            }
            while self.accept_samples.len() > self.cfg.acceptance_window {
                self.accept_samples.pop_front();
            }
            if !ragged {
                self.cusum_step(fb);
            }
        }
        if fb.round_time.is_finite() && fb.round_time > 0.0 {
            {
                let pts = self.cost_points.entry(cost_bucket).or_default();
                pts.push_back((fb.s as f64, fb.round_time));
                while pts.len() > self.cfg.cost_window {
                    pts.pop_front();
                }
            }
            if fb.committed > 0 {
                let acc = self.realized.entry(cost_bucket).or_insert((0.0, 0));
                acc.0 += fb.round_time;
                acc.1 += fb.committed;
            }
        }
        // classed feedback additionally bins each row's sample into its
        // class's private window, so rows in different acceptance
        // regimes converge to different per-class fits.  Classless
        // feedback (`classes` empty) touches none of this — the global
        // path above is the whole story, bit-for-bit as before
        if !fb.classes.is_empty() {
            for (i, &a) in fb.accepted.iter().enumerate() {
                let s_i = fb.s_rows.get(i).copied().unwrap_or(fb.s as u32);
                if s_i == 0 {
                    continue;
                }
                let class = fb.classes.get(i).copied().unwrap_or(0);
                let w = self.class_acc.entry(class).or_default();
                w.samples.push_back((a, s_i));
                while w.samples.len() > self.cfg.acceptance_window {
                    w.samples.pop_front();
                }
            }
            for w in self.class_acc.values_mut() {
                w.observes += 1;
                if w.samples.len() < self.cfg.min_acceptance_samples {
                    continue;
                }
                if !w.curve.is_empty() && w.observes % ACCEPT_REFIT_EVERY != 0 {
                    continue;
                }
                let curve = acceptance_curve(&w.samples, self.cfg.min_acceptance_samples);
                if curve.len() >= 2 {
                    if let Some(fit) =
                        AcceptanceModel::fit(&curve).ok().filter(|f| f.is_sublinear())
                    {
                        w.fit = Some(fit);
                    }
                }
                if !curve.is_empty() {
                    w.curve = curve;
                }
            }
        }
        *self.rounds_seen.entry(live_bucket).or_insert(0) += 1;
        self.observes += 1;
        self.refit_acceptance();
        self.refit_cost(cost_bucket);
        self.update_choice(cost_bucket);
        if live_bucket != cost_bucket {
            self.update_choice(live_bucket);
        }
        // commit per-class choices through the same hysteresis band the
        // scalar path uses (no-op on classless runs: `class_acc` is
        // empty, so uniform-regime behavior is bit-identical)
        let cost = self.cost_for(cost_bucket).copied();
        if let Some(cost) = cost {
            let cap = self
                .cost_support_max(cost_bucket)
                .map_or(MAX_SOLVE_S, |hi| (hi + 1).min(MAX_SOLVE_S));
            for w in self.class_acc.values_mut() {
                if w.curve.is_empty() {
                    continue;
                }
                let s_new = class_s_opt(&w.curve, &cost, cap);
                match w.committed {
                    None => w.committed = Some(s_new),
                    Some(cur) if s_new != cur => {
                        // trust region: the committed choice walks at
                        // most one step per round toward the argmin, so
                        // every expansion is executed and measured (the
                        // new `s_max` feeds a cost point) before the
                        // next — a noisy refit can no longer teleport
                        // a class across the verify knee
                        let step = s_new.clamp(cur.saturating_sub(1), cur + 1);
                        let better = class_time_per_token(&w.curve, &cost, cur)
                            > class_time_per_token(&w.curve, &cost, step)
                                * (1.0 + self.cfg.hysteresis);
                        if step != cur && better {
                            w.committed = Some(step);
                        }
                    }
                    Some(_) => {}
                }
            }
        }
    }

    fn label(&self) -> String {
        "model-based".into()
    }

    fn snapshot(&self) -> Option<Json> {
        let acceptance = match &self.acceptance {
            Some(a) => Json::obj(vec![
                ("c", Json::Num(a.c)),
                ("gamma", Json::Num(a.gamma)),
                ("r2", Json::Num(a.r2)),
            ]),
            None => Json::Null,
        };
        let buckets = Json::Obj(
            self.cost_fit
                .iter()
                .map(|(b, m)| {
                    (
                        b.to_string(),
                        Json::obj(vec![
                            ("alpha", Json::Num(m.alpha)),
                            ("beta", Json::Num(m.beta)),
                            ("r2", Json::Num(m.r2)),
                        ]),
                    )
                })
                .collect(),
        );
        let chosen = Json::Obj(
            self.current
                .iter()
                .map(|(b, s)| (b.to_string(), Json::Num(*s as f64)))
                .collect(),
        );
        let probes = Json::Obj(
            self.rounds_seen
                .iter()
                .map(|(b, n)| (b.to_string(), Json::Num(*n as f64)))
                .collect(),
        );
        // fitted model vs measurement, per bucket — the audit trail for
        // the waste analysis: `inspect` compares where the *predicted*
        // speculation crossover sits against the realized cost surface
        let per_token = Json::Obj(
            self.realized
                .iter()
                .filter(|&(_, &(_, n))| n > 0)
                .map(|(&b, &(t, n))| {
                    (
                        b.to_string(),
                        Json::obj(vec![
                            (
                                "predicted_s",
                                self.predict_token_time(b)
                                    .map_or(Json::Null, Json::Num),
                            ),
                            ("realized_s", Json::Num(t / n as f64)),
                            ("committed_tokens", Json::Num(n as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        // per-class window state (empty object on classless runs)
        let class_acceptance = Json::Obj(
            self.class_acc
                .iter()
                .map(|(class, w)| {
                    (
                        class.to_string(),
                        Json::obj(vec![
                            ("samples", Json::Num(w.samples.len() as f64)),
                            (
                                "committed_s",
                                w.committed
                                    .map_or(Json::Null, |s| Json::Num(s as f64)),
                            ),
                            (
                                "fit",
                                w.fit.map_or(Json::Null, |f| {
                                    Json::obj(vec![
                                        ("c", Json::Num(f.c)),
                                        ("gamma", Json::Num(f.gamma)),
                                        ("r2", Json::Num(f.r2)),
                                    ])
                                }),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Some(Json::obj(vec![
            ("policy", Json::Str("model-based".into())),
            ("samples", Json::Num(self.accept_samples.len() as f64)),
            ("class_acceptance", class_acceptance),
            ("observes", Json::Num(self.observes as f64)),
            ("acceptance", acceptance),
            ("buckets", buckets),
            ("chosen_s", chosen),
            ("rounds_seen", probes),
            ("per_token", per_token),
            ("explore_every", Json::Num(self.cfg.explore_every as f64)),
            (
                "cusum",
                Json::obj(vec![
                    ("pos", Json::Num(self.cusum_pos)),
                    ("neg", Json::Num(self.cusum_neg)),
                    (
                        "resid_var",
                        self.resid_var.map_or(Json::Null, Json::Num),
                    ),
                    ("flush_reprobe", Json::Bool(self.flush_reprobe)),
                ]),
            ),
            ("drift_flushes", Json::Num(self.drift_flushes as f64)),
        ]))
    }

    fn drift_flushes(&self) -> usize {
        self.drift_flushes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::AcceptanceProcess;
    use crate::util::prng::Pcg64;

    fn lut(pairs: &[(usize, usize)]) -> Lut {
        Lut::new(pairs.iter().copied().collect()).unwrap()
    }

    /// The three prior policy behaviours are preserved bit-for-bit.
    #[test]
    fn static_policies_match_the_old_enum_semantics() {
        assert_eq!(NoSpec.choose(4, 8), 0);
        assert!(!NoSpec.wants_speculation());
        assert_eq!(Fixed(3).choose(99, 8), 3);
        assert_eq!(Fixed(8).choose(1, 4), 4);
        assert!(Fixed(2).wants_speculation());
        assert!(!Fixed(0).wants_speculation());
        let adaptive = LutAdaptive(lut(&[(1, 6)]));
        assert_eq!(adaptive.choose(1, 4), 4);
        let l = LutAdaptive(lut(&[(1, 5), (2, 4), (4, 3), (8, 2), (16, 1)]));
        assert_eq!(l.choose(1, 8), 5);
        assert_eq!(l.choose(16, 8), 1);
        // between-bucket smaller-of-neighbours rule still applies
        let l2 = LutAdaptive(lut(&[(4, 3), (8, 2)]));
        assert_eq!(l2.choose(5, 8), 2);
        assert!(l.wants_speculation());
    }

    #[test]
    fn labels() {
        assert_eq!(NoSpec.label(), "no-spec");
        assert_eq!(Fixed(2).label(), "fixed-2");
        assert_eq!(LutAdaptive(lut(&[(1, 1)])).label(), "adaptive");
        assert_eq!(ModelBased::new(lut(&[(1, 1)])).label(), "model-based");
    }

    #[test]
    fn model_based_cold_start_follows_the_fallback_lut() {
        let p = ModelBased::new(lut(&[(1, 5), (4, 3), (16, 1)]));
        assert_eq!(p.choose(1, 8), 5);
        assert_eq!(p.choose(4, 8), 3);
        assert_eq!(p.choose(16, 8), 1);
        assert_eq!(p.choose(16, 0), 0);
        assert!(p.wants_speculation());
        assert!(p.fitted_acceptance().is_none());
    }

    /// Synthetic feedback drawn from a known power-law acceptance process
    /// and a known linear round cost: the online fits must recover the
    /// parameters and the committed choice must land on the true optimum.
    #[test]
    fn model_based_fits_converge_on_synthetic_feedback() {
        let truth = AcceptanceProcess::PowerLaw {
            c: 0.9,
            gamma: 0.548,
        };
        // round_time(s) = alpha'·s + beta at one bucket (live = 4); the
        // slope is steep enough that the total-time optimum is sharp
        let alpha = 0.008;
        let beta = 0.030;
        let mut rng = Pcg64::new(0xF17);
        let mut p = ModelBased::new(lut(&[(1, 6), (4, 4), (16, 1)]));
        for _ in 0..400 {
            let s = p.choose(4, 8);
            let s_used = s.max(1); // the synthetic driver always speculates
            let accepted: Vec<u32> =
                (0..4).map(|_| truth.sample(s_used, &mut rng) as u32).collect();
            let committed: usize =
                accepted.iter().map(|&a| a as usize + 1).sum();
            p.observe(&RoundFeedback {
                live: 4,
                width: 4,
                s: s_used,
                accepted,
                committed,
                round_time: alpha * s_used as f64 + beta,
                ..RoundFeedback::default()
            });
        }
        // once converged the window only spans s ∈ {s_opt, s_opt+1}, so
        // the γ estimate is noisy — the tolerances reflect that
        let acc = p.fitted_acceptance().expect("acceptance fit warm");
        assert!((acc.c - 0.9).abs() < 0.15, "c = {}", acc.c);
        assert!((acc.gamma - 0.548).abs() < 0.3, "gamma = {}", acc.gamma);
        assert!(acc.is_sublinear());
        let cost = p.fitted_cost(4).expect("cost fit warm");
        assert!((cost.alpha - alpha).abs() < 5e-4, "alpha = {}", cost.alpha);
        assert!((cost.beta - beta).abs() < 2e-3, "beta = {}", cost.beta);

        // the committed choice must match the analytic optimum of the
        // true parameters within +-1
        let oracle = TotalTimeModel {
            acceptance: AcceptanceModel {
                c: 0.9,
                gamma: 0.548,
                r2: 1.0,
            },
            cost: StepCostModel {
                batch: 4,
                alpha,
                beta,
                t_ssm: 0.0,
                r2: 1.0,
            },
        }
        .s_opt(MAX_SOLVE_S);
        let chosen = p.committed_choice(4).expect("choice committed");
        assert!(
            (chosen as i64 - oracle as i64).abs() <= 1,
            "chosen {chosen} vs oracle {oracle}"
        );
    }

    #[test]
    fn hysteresis_keeps_the_choice_steady_under_noise() {
        // wide hysteresis band; probing stays on so the cost fit sees
        // more than one s and can warm up at all
        let mut p = ModelBased::with_config(
            lut(&[(1, 4)]),
            ModelBasedConfig {
                hysteresis: 0.10,
                ..ModelBasedConfig::default()
            },
        );
        let truth = AcceptanceProcess::PowerLaw {
            c: 0.9,
            gamma: 0.548,
        };
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            let s = p.choose(2, 8).max(1);
            let accepted: Vec<u32> =
                (0..2).map(|_| truth.sample(s, &mut rng) as u32).collect();
            let committed: usize = accepted.iter().map(|&a| a as usize + 1).sum();
            // +-10% multiplicative noise on the measured round time
            let noise = 0.9 + 0.2 * rng.next_f64();
            p.observe(&RoundFeedback {
                live: 2,
                width: 2,
                s,
                accepted,
                committed,
                round_time: (0.002 * s as f64 + 0.03) * noise,
                ..RoundFeedback::default()
            });
        }
        assert!(p.committed_choice(2).is_some(), "fits must be warm");
        // count how many times the committed choice CHANGES over another
        // 200 noisy rounds: slow convergence may still move it a couple
        // of times, but fit jitter must not thrash it
        let mut changes = 0;
        let mut last = p.committed_choice(2);
        for _ in 0..200 {
            let s = p.choose(2, 8).max(1);
            let accepted: Vec<u32> =
                (0..2).map(|_| truth.sample(s, &mut rng) as u32).collect();
            let committed: usize = accepted.iter().map(|&a| a as usize + 1).sum();
            let noise = 0.9 + 0.2 * rng.next_f64();
            p.observe(&RoundFeedback {
                live: 2,
                width: 2,
                s,
                accepted,
                committed,
                round_time: (0.002 * s as f64 + 0.03) * noise,
                ..RoundFeedback::default()
            });
            let cur = p.committed_choice(2);
            if cur != last {
                changes += 1;
                last = cur;
            }
        }
        assert!(changes <= 8, "choice changed {changes} times under noise");
    }

    /// Low-to-high re-convergence: after acceptance collapses and the
    /// policy parks at tiny s, probes (>= 2, stepping down at the cap)
    /// keep both fits identifiable, so a later recovery pushes s back up.
    #[test]
    fn recovers_after_acceptance_collapses_and_returns() {
        let collapsed = AcceptanceProcess::PowerLaw {
            c: 0.3,
            gamma: 0.02,
        };
        let good = AcceptanceProcess::PowerLaw { c: 0.9, gamma: 0.8 };
        let run = |p: &mut ModelBased,
                   rng: &mut Pcg64,
                   acc: &AcceptanceProcess,
                   rounds: usize| {
            for _ in 0..rounds {
                let s = p.choose(1, 8);
                let accepted: Vec<u32> = if s > 0 {
                    vec![acc.sample(s, rng) as u32]
                } else {
                    Vec::new()
                };
                let committed =
                    accepted.iter().map(|&a| a as usize + 1).sum::<usize>().max(1);
                p.observe(&RoundFeedback {
                    live: 1,
                    width: 1,
                    s,
                    accepted,
                    committed,
                    // memory-bound-ish cost: speculation pays when drafts
                    // are accepted, barely costs when they are not
                    round_time: 0.0008 * s as f64 + 0.025,
                    ..RoundFeedback::default()
                });
            }
        };
        let mut rng = Pcg64::new(3);
        let mut p = ModelBased::new(lut(&[(1, 8)]));
        run(&mut p, &mut rng, &collapsed, 300);
        let low = p.committed_choice(1).expect("warm after the collapse");
        assert!(low <= 2, "collapsed acceptance must push s down: {low}");
        run(&mut p, &mut rng, &good, 300);
        let high = p.committed_choice(1).expect("still warm");
        assert!(high >= 4, "recovered acceptance must push s back up: {high}");
    }

    #[test]
    fn with_models_solves_without_history_and_probes_stay_off() {
        let acceptance = AcceptanceModel {
            c: 0.9,
            gamma: 0.548,
            r2: 1.0,
        };
        let costs = [
            StepCostModel {
                batch: 1,
                alpha: 0.0004,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
            StepCostModel {
                batch: 16,
                alpha: 0.02,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
        ];
        let p = ModelBased::with_models(lut(&[(1, 1)]), acceptance, &costs);
        let s_small = p.choose(1, 8);
        let s_big = p.choose(16, 8);
        assert!(
            s_small >= s_big,
            "s_opt must not grow with batch: {s_small} vs {s_big}"
        );
        assert!(s_small >= 3, "cheap verify should want long speculation");
        // choose is pure: repeated queries agree
        assert_eq!(p.choose(1, 8), s_small);
        // an un-fitted in-between bucket resolves to a fitted neighbour
        let s_mid = p.choose(4, 8);
        assert!(s_mid <= s_small && s_mid >= s_big);
    }

    /// Round feedback drawn from one process, then an abrupt collapse:
    /// the CUSUM detector must stay quiet while the process is
    /// stationary and flush the acceptance window soon after the shift.
    #[test]
    fn cusum_flushes_on_an_acceptance_collapse_and_not_before() {
        let good = AcceptanceProcess::PowerLaw { c: 0.9, gamma: 0.8 };
        let bad = AcceptanceProcess::PowerLaw {
            c: 0.3,
            gamma: 0.05,
        };
        let mut rng = Pcg64::new(0xD21F7);
        let mut p = ModelBased::new(lut(&[(1, 6), (8, 3)]));
        let run = |p: &mut ModelBased,
                   rng: &mut Pcg64,
                   acc: &AcceptanceProcess,
                   rounds: usize| {
            for _ in 0..rounds {
                let s = p.choose(8, 8).max(1);
                let accepted: Vec<u32> =
                    (0..8).map(|_| acc.sample(s, rng) as u32).collect();
                let committed: usize =
                    accepted.iter().map(|&a| a as usize + 1).sum();
                p.observe(&RoundFeedback {
                    live: 8,
                    width: 8,
                    s,
                    accepted,
                    committed,
                    round_time: 0.004 * s as f64 + 0.03,
                    ..RoundFeedback::default()
                });
            }
        };
        run(&mut p, &mut rng, &good, 200);
        let warm_flushes = p.drift_flushes();
        run(&mut p, &mut rng, &good, 200);
        assert_eq!(
            p.drift_flushes(),
            warm_flushes,
            "stationary feedback must not trigger the detector"
        );
        run(&mut p, &mut rng, &bad, 40);
        assert!(
            p.drift_flushes() > warm_flushes,
            "an abrupt acceptance collapse must flush the window"
        );
        // the flush emptied the stale window: what remains accumulated
        // after the changepoint
        assert!(p.accept_samples.len() < 8 * 40);
    }

    #[test]
    fn predict_token_time_cold_then_warm_and_monotone_in_load() {
        let p = ModelBased::new(lut(&[(1, 3)]));
        assert!(p.predict_token_time(4).is_none(), "cold policy predicts nothing");

        let acceptance = AcceptanceModel {
            c: 0.9,
            gamma: 0.548,
            r2: 1.0,
        };
        let costs = [
            StepCostModel {
                batch: 1,
                alpha: 0.0004,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
            StepCostModel {
                batch: 16,
                alpha: 0.02,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
        ];
        let p = ModelBased::with_models(lut(&[(1, 1)]), acceptance, &costs);
        let t1 = p.predict_token_time(1).expect("warm");
        let t16 = p.predict_token_time(16).expect("warm");
        assert!(t1 > 0.0);
        assert!(
            t16 > t1,
            "a heavier batch must predict a worse per-token time: {t1} vs {t16}"
        );
    }

    #[test]
    fn snapshot_reports_the_fits() {
        let mut p = ModelBased::new(lut(&[(1, 3)]));
        let snap = p.snapshot().expect("model-based always snapshots");
        assert_eq!(snap.get("policy").unwrap().as_str().unwrap(), "model-based");
        // warm it with deterministic feedback
        for i in 0..200u32 {
            p.observe(&RoundFeedback {
                live: 1,
                width: 1,
                s: 1 + (i % 3) as usize,
                accepted: vec![1],
                committed: 2,
                round_time: 0.01 + 0.001 * (1 + (i % 3)) as f64,
                ..RoundFeedback::default()
            });
        }
        let snap = p.snapshot().unwrap();
        assert!(snap.get("acceptance").unwrap().get_opt("c").unwrap().is_some());
        let txt = snap.compact();
        assert!(txt.contains("\"buckets\""), "{txt}");
        // the telemetry additions: probe/CUSUM state ride along
        assert_eq!(snap.get("observes").unwrap().as_usize().unwrap(), 200);
        assert_eq!(
            snap.get("rounds_seen").unwrap().get("1").unwrap().as_usize().unwrap(),
            200
        );
        assert_eq!(snap.get("explore_every").unwrap().as_usize().unwrap(), 16);
        let cusum = snap.get("cusum").unwrap();
        assert!(cusum.get("pos").unwrap().as_f64().unwrap() >= 0.0);
        assert!(!cusum.get("flush_reprobe").unwrap().as_bool().unwrap());
    }

    /// The default ragged API is an exact broadcast of `choose` for
    /// every policy that does not override it.
    #[test]
    fn choose_ragged_default_broadcasts_choose() {
        let rows = [0u8; 5];
        assert_eq!(Fixed(3).choose_ragged(&rows, 8), vec![3; 5]);
        assert_eq!(NoSpec.choose_ragged(&rows, 8), vec![0; 5]);
        let l = LutAdaptive(lut(&[(1, 5), (4, 3), (8, 2)]));
        assert_eq!(l.choose_ragged(&[0u8; 4], 8), vec![l.choose(4, 8); 4]);
        // the `_into` spelling fills a caller-owned buffer
        let mut buf = Vec::with_capacity(8);
        Fixed(2).choose_ragged_into(&rows, 8, &mut buf);
        assert_eq!(buf, vec![2; 5]);
        Fixed(2).choose_ragged_into(&[], 8, &mut buf);
        assert!(buf.is_empty());
    }

    /// A single-regime batch must resolve to an exact broadcast of the
    /// scalar `choose`, cold or warm — the uniform-recovery property.
    #[test]
    fn model_based_single_regime_ragged_is_an_exact_broadcast() {
        let p = ModelBased::new(lut(&[(1, 5), (4, 3), (16, 1)]));
        for live in [1usize, 4, 16] {
            let rows = vec![7u8; live];
            assert_eq!(p.choose_ragged(&rows, 8), vec![p.choose(live, 8); live]);
        }
    }

    /// Mixed-class feedback must grow per-class acceptance fits that
    /// pull the two regimes to different per-row speculation lengths:
    /// the high-acceptance class strictly longer than the collapsed one.
    #[test]
    fn per_class_windows_diverge_and_drive_ragged_choices() {
        let hi = AcceptanceProcess::Geometric { q: 0.95 };
        let lo = AcceptanceProcess::Geometric { q: 0.05 };
        let mut rng = Pcg64::new(0xA11);
        let mut p = ModelBased::new(lut(&[(1, 4), (16, 4)]));
        let classes: Vec<u8> = (0..8).map(|i| (i % 2) as u8).collect();
        for _ in 0..400 {
            let s_rows = p.choose_ragged(&classes, 8);
            let s_max = s_rows.iter().copied().max().unwrap();
            let uniform = s_rows.iter().all(|&s| s == s_rows[0]);
            let mut accepted = Vec::new();
            for (i, &class) in classes.iter().enumerate() {
                let proc_ = if class == 0 { &hi } else { &lo };
                let a = if s_rows[i] > 0 {
                    proc_.sample(s_rows[i], &mut rng) as u32
                } else {
                    0
                };
                accepted.push(a);
            }
            let committed: usize = accepted.iter().map(|&a| a as usize + 1).sum();
            p.observe(&RoundFeedback {
                live: 8,
                width: 8,
                s: s_max,
                accepted,
                s_rows: if uniform {
                    Vec::new()
                } else {
                    s_rows.iter().map(|&s| s as u32).collect()
                },
                classes: classes.clone(),
                committed,
                round_time: 0.004 * s_max as f64 + 0.03,
            });
        }
        let f0 = p.fitted_class_acceptance(0).expect("class 0 fit warm");
        let f1 = p.fitted_class_acceptance(1).expect("class 1 fit warm");
        assert!(
            f0.c > f1.c + 0.3,
            "class fits must separate the regimes: c0 = {}, c1 = {}",
            f0.c,
            f1.c
        );
        let s_rows = p.choose_ragged(&classes, 8);
        let s0 = s_rows[0];
        let s1 = s_rows[1];
        assert!(
            s0 > s1,
            "high-acceptance rows must draft longer: s0 = {s0}, s1 = {s1} ({s_rows:?})"
        );
        assert!(s1 <= 2, "collapsed class must park near no-spec: {s1}");
        // classless feedback must never touch the class windows
        let mut q = ModelBased::new(lut(&[(1, 4)]));
        q.observe(&RoundFeedback {
            live: 2,
            width: 2,
            s: 2,
            accepted: vec![1, 2],
            committed: 5,
            round_time: 0.03,
            ..RoundFeedback::default()
        });
        assert!(q.fitted_class_acceptance(0).is_none());
    }
}
