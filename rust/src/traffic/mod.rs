//! Traffic generation: the paper's client model (Sec. 5.3).
//!
//! Inter-arrival times are Gamma-distributed with a target mean interval
//! and coefficient of variation (CV).  Two patterns:
//!
//! * [`TrafficPattern::Stationary`] — fixed (interval, CV), the Fig. 5
//!   grid sweeps interval ∈ {0.1..0.8}s and CV ∈ {0.5, 1, 2, 5};
//! * [`TrafficPattern::Alternating`] — Fig. 6: switch between *intense*
//!   (0.2 s) and *sparse* (1.0 s) mean intervals every 50 s, CV = 1.
//!
//! A generated [`Trace`] is a deterministic list of (send time, prompt)
//! pairs, so every comparison point (no-spec / fixed-2 / fixed-4 /
//! adaptive) replays the *identical* request sequence — the paper: "For
//! each setting, we generate only one sequence of requests, which is used
//! to evaluate all comparison points."

use crate::dataset::Prompt;
use crate::util::prng::{GammaIntervals, Pcg64};

/// Shape of the arrival process.
#[derive(Debug, Clone, PartialEq)]
pub enum TrafficPattern {
    /// Gamma arrivals with fixed mean interval (s) and CV.
    Stationary { interval: f64, cv: f64 },
    /// Alternate intense/sparse mean intervals every `period` seconds
    /// (Fig. 6: intense 0.2 s, sparse 1.0 s, period 50 s, cv 1.0).
    Alternating {
        intense_interval: f64,
        sparse_interval: f64,
        period: f64,
        cv: f64,
    },
}

impl TrafficPattern {
    pub fn fig6() -> TrafficPattern {
        TrafficPattern::Alternating {
            intense_interval: 0.2,
            sparse_interval: 1.0,
            period: 50.0,
            cv: 1.0,
        }
    }

    /// Mean interval in effect at absolute time `t`.
    pub fn interval_at(&self, t: f64) -> f64 {
        match *self {
            TrafficPattern::Stationary { interval, .. } => interval,
            TrafficPattern::Alternating {
                intense_interval,
                sparse_interval,
                period,
                ..
            } => {
                let phase = (t / period).floor() as i64;
                if phase % 2 == 0 {
                    intense_interval
                } else {
                    sparse_interval
                }
            }
        }
    }

    pub fn cv(&self) -> f64 {
        match *self {
            TrafficPattern::Stationary { cv, .. } => cv,
            TrafficPattern::Alternating { cv, .. } => cv,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            TrafficPattern::Stationary { interval, cv } => {
                format!("stationary(interval={interval}s,cv={cv})")
            }
            TrafficPattern::Alternating {
                intense_interval,
                sparse_interval,
                period,
                cv,
            } => format!(
                "alternating({intense_interval}s/{sparse_interval}s,period={period}s,cv={cv})"
            ),
        }
    }
}

/// Per-request latency-SLO sampling: each request's end-to-end budget is
/// drawn log-uniformly from `[p50 / scale, p50 * scale]` and its deadline
/// is `send_at + budget`.  Budgets are sampled on a **separate** PRNG
/// stream, so attaching SLOs to a trace never perturbs the send times or
/// prompt assignment — the same request schedule replays against every
/// comparison point, deadlined or not (the paper's one-sequence rule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// median latency budget, seconds (must be > 0)
    pub p50: f64,
    /// log-uniform spread factor (>= 1; 1 = every budget is exactly p50)
    pub scale: f64,
}

impl SloSpec {
    pub fn new(p50: f64, scale: f64) -> SloSpec {
        assert!(p50 > 0.0, "SLO p50 must be positive");
        assert!(scale >= 1.0, "SLO scale must be >= 1");
        SloSpec { p50, scale }
    }

    /// Budget pegged to the traffic pattern: `factor` mean inter-arrival
    /// intervals of the pattern's *intense* phase (the phase that decides
    /// whether SLOs survive a burst).
    pub fn of_pattern(pattern: &TrafficPattern, factor: f64, scale: f64) -> SloSpec {
        let interval = match *pattern {
            TrafficPattern::Stationary { interval, .. } => interval,
            TrafficPattern::Alternating {
                intense_interval, ..
            } => intense_interval,
        };
        SloSpec::new(interval * factor, scale)
    }

    /// One budget sample.
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        // log-uniform over [p50/scale, p50*scale]
        let u = rng.next_f64();
        self.p50 * self.scale.powf(2.0 * u - 1.0)
    }
}

/// Multi-tenant shared-prefix workload shape: every request's prompt is
/// rebuilt as `tenant system prompt ++ template body ++ fresh user
/// suffix`.  Tenants are drawn uniformly; templates within a tenant
/// follow a Zipf popularity law (a few templates dominate, the regime
/// where a prefix cache pays).  All prompt material is a deterministic
/// function of (tenant, template) except the user suffix, so requests of
/// the same (tenant, template) share `system_len + template_len` leading
/// tokens exactly — what the prefix trie deduplicates block-for-block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SharedPrefixSpec {
    /// tenants, each with its own system prompt (uniform assignment)
    pub tenants: usize,
    /// prompt templates per tenant (Zipf popularity)
    pub templates: usize,
    /// tokens in a tenant's system prompt
    pub system_len: usize,
    /// tokens in a template body
    pub template_len: usize,
    /// fresh (randomly sampled) per-request suffix tokens
    pub user_len: usize,
    /// Zipf exponent for template popularity (0 = uniform)
    pub zipf: f64,
    /// token-id space: generated ids land in `[4, vocab)`, matching the
    /// stub's reserved specials (pad/bos/eos/unk at 0..=3)
    pub vocab: usize,
}

impl Default for SharedPrefixSpec {
    fn default() -> Self {
        // 96 shared leading tokens = 6 full 16-token KV blocks per
        // (tenant, template), over a 4-token unique tail
        SharedPrefixSpec {
            tenants: 4,
            templates: 4,
            system_len: 48,
            template_len: 48,
            user_len: 4,
            zipf: 1.2,
            vocab: 64,
        }
    }
}

impl SharedPrefixSpec {
    /// Length of every rebuilt prompt.
    pub fn prompt_len(&self) -> usize {
        self.system_len + self.template_len + self.user_len
    }

    /// Tokens two same-(tenant, template) prompts share.
    pub fn shared_len(&self) -> usize {
        self.system_len + self.template_len
    }
}

/// Deterministic token in `[4, vocab)` from a (stream, lane, position)
/// triple — how tenant system prompts and template bodies are minted
/// without a PRNG (their content must be a pure function of identity).
fn prefix_token(stream: u64, lane: u64, pos: u64, vocab: usize) -> i32 {
    let h = stream
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ lane.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ pos.wrapping_mul(0x1656_67B1_9E37_79F9);
    // avalanche so neighbouring positions don't correlate
    let h = (h ^ (h >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    4 + (h % (vocab as u64 - 4)) as i32
}

/// One scheduled request.
#[derive(Debug, Clone)]
pub struct TraceItem {
    pub id: u64,
    /// absolute send time in seconds from trace start
    pub send_at: f64,
    /// absolute deadline in seconds from trace start (None = no SLO)
    pub deadline: Option<f64>,
    /// workload class tag (0 = default).  Classes partition requests by
    /// acceptance regime — e.g. code-completion vs chat — and feed the
    /// per-class acceptance windows of the ragged speculation policy
    pub class: u8,
    pub prompt: Prompt,
}

/// A deterministic request schedule.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub items: Vec<TraceItem>,
}

impl Trace {
    /// Generate `n` requests under `pattern`, sampling prompts from `pool`.
    ///
    /// Interval samples are scaled to the mean in effect at the *current*
    /// simulated time, so alternating patterns switch correctly even when
    /// an interval straddles the phase boundary.
    pub fn generate(
        pattern: &TrafficPattern,
        pool: &[Prompt],
        n: usize,
        seed: u64,
    ) -> Trace {
        assert!(!pool.is_empty(), "prompt pool must be non-empty");
        let mut rng = Pcg64::with_stream(seed, 0x7261_6666_6963); // "raffic"
        let cv = pattern.cv();
        // unit-mean gamma; scaled by the phase's mean interval
        let unit = GammaIntervals::new(1.0, cv);
        let mut t = 0.0;
        let mut items = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let gap = unit.sample(&mut rng) * pattern.interval_at(t);
            t += gap;
            let prompt = pool[rng.next_below(pool.len())].clone();
            items.push(TraceItem {
                id,
                send_at: t,
                deadline: None,
                class: 0,
                prompt,
            });
        }
        Trace { items }
    }

    /// Attach per-request deadlines sampled from `slo` (see [`SloSpec`]).
    /// The base schedule — ids, send times, prompts — is untouched, so a
    /// deadlined trace replays the identical request sequence.
    pub fn with_deadlines(&self, slo: &SloSpec, seed: u64) -> Trace {
        let mut rng = Pcg64::with_stream(seed, 0x510_DEAD); // "slo deadline"
        Trace {
            items: self
                .items
                .iter()
                .map(|i| TraceItem {
                    id: i.id,
                    send_at: i.send_at,
                    deadline: Some(i.send_at + slo.sample(&mut rng)),
                    class: i.class,
                    prompt: i.prompt.clone(),
                })
                .collect(),
        }
    }

    /// Tag requests with workload classes round-robin by id
    /// (`class = id % n_classes`).  Deterministic and schedule-preserving:
    /// ids, send times, deadlines, and prompts are untouched, so a tagged
    /// trace replays the identical request sequence (the paper's
    /// one-sequence rule).  Used by the mixed-domain scenario where two
    /// acceptance regimes share one batch.
    pub fn with_classes_alternating(&self, n_classes: u8) -> Trace {
        assert!(n_classes > 0, "n_classes must be >= 1");
        Trace {
            items: self
                .items
                .iter()
                .map(|i| TraceItem {
                    id: i.id,
                    send_at: i.send_at,
                    deadline: i.deadline,
                    class: (i.id % n_classes as u64) as u8,
                    prompt: i.prompt.clone(),
                })
                .collect(),
        }
    }

    /// Rebuild every prompt as a multi-tenant shared-prefix prompt (see
    /// [`SharedPrefixSpec`]).  Layered like [`Trace::with_deadlines`]: a
    /// **separate** PRNG stream samples tenant/template/user-suffix, and
    /// ids, send times, deadlines and classes are untouched, so the same
    /// arrival schedule replays cache-on vs cache-off (the paper's
    /// one-sequence rule).
    pub fn with_shared_prefix(&self, spec: &SharedPrefixSpec, seed: u64) -> Trace {
        assert!(spec.tenants > 0, "need at least one tenant");
        assert!(spec.templates > 0, "need at least one template");
        assert!(spec.user_len > 0, "each request needs a unique suffix");
        assert!(spec.vocab > 4, "vocab must clear the reserved specials");
        let mut rng = Pcg64::with_stream(seed, 0x7072_6566_6978); // "prefix"
        // Zipf popularity over templates: weight(rank j) = 1/(j+1)^zipf
        let weights: Vec<f64> = (0..spec.templates)
            .map(|j| 1.0 / ((j + 1) as f64).powf(spec.zipf))
            .collect();
        let total: f64 = weights.iter().sum();
        let items = self
            .items
            .iter()
            .map(|i| {
                let tenant = rng.next_below(spec.tenants);
                let mut u = rng.next_f64() * total;
                let mut template = spec.templates - 1;
                for (j, w) in weights.iter().enumerate() {
                    if u < *w {
                        template = j;
                        break;
                    }
                    u -= *w;
                }
                let mut ids = Vec::with_capacity(spec.prompt_len());
                for k in 0..spec.system_len {
                    ids.push(prefix_token(0xA11CE, tenant as u64, k as u64, spec.vocab));
                }
                for k in 0..spec.template_len {
                    ids.push(prefix_token(
                        0xB0B0 + tenant as u64,
                        template as u64,
                        k as u64,
                        spec.vocab,
                    ));
                }
                for _ in 0..spec.user_len {
                    ids.push(4 + rng.next_below(spec.vocab - 4) as i32);
                }
                TraceItem {
                    id: i.id,
                    send_at: i.send_at,
                    deadline: i.deadline,
                    class: i.class,
                    prompt: Prompt {
                        ids,
                        text: format!("tenant{tenant}/template{template}"),
                    },
                }
            })
            .collect();
        Trace { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total span of the schedule in seconds.
    pub fn span(&self) -> f64 {
        self.items.last().map(|i| i.send_at).unwrap_or(0.0)
    }

    /// Scale all send times (and deadlines, which are absolute) by
    /// `factor` (used to time-compress paper-scale traces for the
    /// real-server experiments).
    pub fn time_scaled(&self, factor: f64) -> Trace {
        Trace {
            items: self
                .items
                .iter()
                .map(|i| TraceItem {
                    id: i.id,
                    send_at: i.send_at * factor,
                    deadline: i.deadline.map(|d| d * factor),
                    class: i.class,
                    prompt: i.prompt.clone(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Vec<Prompt> {
        vec![
            Prompt {
                ids: vec![1, 5],
                text: "a".into(),
            },
            Prompt {
                ids: vec![1, 6, 7],
                text: "b".into(),
            },
        ]
    }

    #[test]
    fn stationary_mean_interval_is_respected() {
        let p = TrafficPattern::Stationary {
            interval: 0.4,
            cv: 1.0,
        };
        let t = Trace::generate(&p, &pool(), 4000, 7);
        let mean_gap = t.span() / (t.len() as f64);
        assert!(
            (mean_gap - 0.4).abs() < 0.03,
            "mean gap {mean_gap} != 0.4"
        );
        // monotone non-decreasing send times
        for w in t.items.windows(2) {
            assert!(w[1].send_at >= w[0].send_at);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = TrafficPattern::Stationary {
            interval: 0.2,
            cv: 2.0,
        };
        let a = Trace::generate(&p, &pool(), 100, 42);
        let b = Trace::generate(&p, &pool(), 100, 42);
        let c = Trace::generate(&p, &pool(), 100, 43);
        let times =
            |t: &Trace| t.items.iter().map(|i| i.send_at).collect::<Vec<_>>();
        assert_eq!(times(&a), times(&b));
        assert_ne!(times(&a), times(&c));
    }

    #[test]
    fn alternating_switches_phase() {
        let p = TrafficPattern::fig6();
        assert_eq!(p.interval_at(10.0), 0.2);
        assert_eq!(p.interval_at(60.0), 1.0);
        assert_eq!(p.interval_at(110.0), 0.2);
        // arrivals in intense phases come much faster: count requests in
        // the first (intense) vs second (sparse) 50 s window
        let t = Trace::generate(&p, &pool(), 2000, 3);
        let intense = t
            .items
            .iter()
            .filter(|i| i.send_at < 50.0)
            .count();
        let sparse = t
            .items
            .iter()
            .filter(|i| (50.0..100.0).contains(&i.send_at))
            .count();
        assert!(
            intense > 3 * sparse,
            "intense {intense} not >> sparse {sparse}"
        );
    }

    /// Same seed -> the identical schedule including prompt assignment;
    /// the paper replays one sequence against every comparison point.
    #[test]
    fn deterministic_prompts_and_ids_per_seed() {
        let p = TrafficPattern::fig6();
        let a = Trace::generate(&p, &pool(), 64, 5);
        let b = Trace::generate(&p, &pool(), 64, 5);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.send_at, y.send_at);
            assert_eq!(x.prompt.ids, y.prompt.ids);
        }
        // ids are the positional sequence
        assert_eq!(
            a.items.iter().map(|i| i.id).collect::<Vec<_>>(),
            (0..64).collect::<Vec<u64>>()
        );
    }

    /// `time_scaled` preserves arrival order (monotone in the original
    /// send times) for any positive factor, and scales exactly.
    #[test]
    fn time_scaled_is_monotone_and_exact() {
        let p = TrafficPattern::Stationary {
            interval: 0.3,
            cv: 2.0,
        };
        let t = Trace::generate(&p, &pool(), 200, 11);
        for factor in [0.25, 1.0, 3.0] {
            let scaled = t.time_scaled(factor);
            assert_eq!(scaled.len(), t.len());
            for w in scaled.items.windows(2) {
                assert!(
                    w[1].send_at >= w[0].send_at,
                    "scaling by {factor} broke ordering"
                );
            }
            for (orig, s) in t.items.iter().zip(&scaled.items) {
                assert!((s.send_at - orig.send_at * factor).abs() < 1e-12);
                assert_eq!(s.id, orig.id);
            }
        }
    }

    /// The alternating pattern switches exactly at phase boundaries and
    /// is constant inside each phase (piecewise continuity: approaching a
    /// boundary from the left holds the old interval, the boundary itself
    /// starts the new one, and the cycle repeats with period 2x).
    #[test]
    fn interval_at_is_piecewise_constant_across_phase_boundaries() {
        let p = TrafficPattern::fig6();
        let eps = 1e-9;
        // inside phases: constant
        assert_eq!(p.interval_at(0.0), 0.2);
        assert_eq!(p.interval_at(25.0), 0.2);
        assert_eq!(p.interval_at(75.0), 1.0);
        // left limit vs boundary value at every flip in two full cycles
        for boundary in [50.0, 100.0, 150.0, 200.0] {
            let left = p.interval_at(boundary - eps);
            let at = p.interval_at(boundary);
            assert_ne!(left, at, "no switch at t={boundary}");
            assert_eq!(p.interval_at(boundary + eps), at, "unstable just past {boundary}");
        }
        // periodicity: shifted by a full cycle the schedule repeats
        for t in [0.0, 10.0, 49.0, 50.0, 99.0] {
            assert_eq!(p.interval_at(t), p.interval_at(t + 100.0));
        }
        // stationary patterns are constant everywhere
        let s = TrafficPattern::Stationary {
            interval: 0.7,
            cv: 1.0,
        };
        for t in [0.0, 49.9, 50.0, 1e6] {
            assert_eq!(s.interval_at(t), 0.7);
        }
    }

    /// Attaching SLOs must not perturb the base schedule, budgets must
    /// land in the configured band, and `time_scaled` must scale the
    /// absolute deadlines along with the send times.
    #[test]
    fn deadlines_ride_on_top_of_the_schedule() {
        let p = TrafficPattern::Stationary {
            interval: 0.3,
            cv: 1.0,
        };
        let base = Trace::generate(&p, &pool(), 120, 9);
        assert!(base.items.iter().all(|i| i.deadline.is_none()));
        let slo = SloSpec::new(2.0, 4.0);
        let t = base.with_deadlines(&slo, 9);
        for (b, d) in base.items.iter().zip(&t.items) {
            assert_eq!(b.id, d.id);
            assert_eq!(b.send_at, d.send_at);
            assert_eq!(b.prompt.ids, d.prompt.ids);
            let budget = d.deadline.unwrap() - d.send_at;
            assert!(
                (0.5..=8.0).contains(&budget),
                "budget {budget} outside [p50/scale, p50*scale]"
            );
        }
        // deterministic per seed, distinct across seeds
        let again = base.with_deadlines(&slo, 9);
        let other = base.with_deadlines(&slo, 10);
        let ds = |t: &Trace| t.items.iter().map(|i| i.deadline).collect::<Vec<_>>();
        assert_eq!(ds(&t), ds(&again));
        assert_ne!(ds(&t), ds(&other));
        // scale = 1 pins every budget at exactly p50
        let fixed = base.with_deadlines(&SloSpec::new(1.5, 1.0), 3);
        for i in &fixed.items {
            assert!((i.deadline.unwrap() - i.send_at - 1.5).abs() < 1e-12);
        }
        // time_scaled scales deadlines with the clock
        let half = t.time_scaled(0.5);
        for (orig, s) in t.items.iter().zip(&half.items) {
            assert!((s.deadline.unwrap() - orig.deadline.unwrap() * 0.5).abs() < 1e-12);
        }
        // pattern-pegged budgets read the intense phase
        let slo6 = SloSpec::of_pattern(&TrafficPattern::fig6(), 10.0, 2.0);
        assert!((slo6.p50 - 2.0).abs() < 1e-12);
    }

    /// Class tagging rides on top of the schedule exactly like deadlines:
    /// the base schedule is untouched, tags alternate by id, and tags
    /// survive deadline attachment and time scaling.
    #[test]
    fn class_tags_ride_on_top_of_the_schedule() {
        let p = TrafficPattern::Stationary {
            interval: 0.3,
            cv: 1.0,
        };
        let base = Trace::generate(&p, &pool(), 50, 13);
        assert!(base.items.iter().all(|i| i.class == 0));
        let tagged = base.with_classes_alternating(2);
        for (b, t) in base.items.iter().zip(&tagged.items) {
            assert_eq!(b.id, t.id);
            assert_eq!(b.send_at, t.send_at);
            assert_eq!(b.prompt.ids, t.prompt.ids);
            assert_eq!(t.class, (t.id % 2) as u8);
        }
        // tags survive deadline attachment and time scaling
        let slo = SloSpec::new(2.0, 2.0);
        let chained = tagged.with_deadlines(&slo, 7).time_scaled(0.5);
        for (t, c) in tagged.items.iter().zip(&chained.items) {
            assert_eq!(t.class, c.class);
        }
        // n_classes = 1 is the identity tagging
        assert!(base
            .with_classes_alternating(1)
            .items
            .iter()
            .all(|i| i.class == 0));
    }

    /// The shared-prefix rebuild rides on top of the schedule (ids, send
    /// times, deadlines, classes untouched), produces identical leading
    /// tokens within a (tenant, template) bucket, distinct system prompts
    /// across tenants, and a Zipf-skewed template popularity.
    #[test]
    fn shared_prefix_rides_on_top_of_the_schedule() {
        let p = TrafficPattern::Stationary {
            interval: 0.05,
            cv: 1.0,
        };
        let base = Trace::generate(&p, &pool(), 400, 21).with_deadlines(&SloSpec::new(2.0, 2.0), 4);
        let spec = SharedPrefixSpec::default();
        let t = base.with_shared_prefix(&spec, 21);
        assert_eq!(t.len(), base.len());
        for (b, s) in base.items.iter().zip(&t.items) {
            assert_eq!(b.id, s.id);
            assert_eq!(b.send_at, s.send_at);
            assert_eq!(b.deadline, s.deadline);
            assert_eq!(b.class, s.class);
            assert_eq!(s.prompt.ids.len(), spec.prompt_len());
            assert!(s.prompt.ids.iter().all(|&id| (4..64).contains(&id)));
        }
        // deterministic per seed, distinct across seeds
        let again = base.with_shared_prefix(&spec, 21);
        let other = base.with_shared_prefix(&spec, 22);
        let ids = |t: &Trace| {
            t.items
                .iter()
                .map(|i| i.prompt.ids.clone())
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&t), ids(&again));
        assert_ne!(ids(&t), ids(&other));

        // same (tenant, template) ⇒ identical shared span, unique tails
        use std::collections::HashMap;
        let mut by_bucket: HashMap<&str, Vec<&TraceItem>> = HashMap::new();
        for i in &t.items {
            by_bucket.entry(i.prompt.text.as_str()).or_default().push(i);
        }
        let shared = spec.shared_len();
        for group in by_bucket.values().filter(|g| g.len() > 1) {
            let head = &group[0].prompt.ids[..shared];
            for i in &group[1..] {
                assert_eq!(&i.prompt.ids[..shared], head, "shared span diverged");
            }
        }
        // tenants got distinct system prompts
        let sys: std::collections::BTreeSet<Vec<i32>> = t
            .items
            .iter()
            .map(|i| i.prompt.ids[..spec.system_len].to_vec())
            .collect();
        assert!(sys.len() > 1, "all tenants share one system prompt");
        // Zipf skew: rank-0 templates outnumber rank-(last) templates
        let count = |suffix: &str| {
            t.items
                .iter()
                .filter(|i| i.prompt.text.ends_with(suffix))
                .count()
        };
        assert!(
            count("template0") > count("template3"),
            "template popularity is not skewed: {} vs {}",
            count("template0"),
            count("template3")
        );
    }

    #[test]
    fn time_scaling() {
        let p = TrafficPattern::Stationary {
            interval: 1.0,
            cv: 0.5,
        };
        let t = Trace::generate(&p, &pool(), 10, 1);
        let half = t.time_scaled(0.5);
        assert!((half.span() - t.span() * 0.5).abs() < 1e-9);
        assert_eq!(half.len(), t.len());
    }
}
