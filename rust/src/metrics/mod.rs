//! Request-level latency metrics and timeline bucketing.
//!
//! The paper's serving metric (Sec. 5.3) is end-to-end request latency
//! `t_b - t_a`: from the client send to the server finishing the request,
//! *including queueing delay*.  [`LatencyRecorder`] accumulates completed
//! requests; [`timeline_groups`] reproduces Fig. 6's presentation (each
//! point = one group of 40 consecutive requests by send time).

use crate::util::csv::{f, Csv};
use crate::util::stats::{percentile_sorted, summary, Summary};

/// One finished request — completed *or* shed by admission control — in
/// seconds on a common clock.  Shed requests are recorded too (with
/// `shed == true`, zero tokens and `finished_at` = the shed time), so
/// requests that never complete stay visible in every experiment outcome
/// instead of silently vanishing from the accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    /// client send time (t_a)
    pub sent_at: f64,
    /// server pulled it into a batch (sheds: the shed time)
    pub started_at: f64,
    /// server finished generating (t_b); sheds: the shed time
    pub finished_at: f64,
    /// generated tokens (0 for shed requests)
    pub tokens: usize,
    /// batch size it was served in (0 for shed requests)
    pub batch: usize,
    /// speculation length used for (the first round of) its batch
    pub spec_len: usize,
    /// worker shard that served it (0 on the single-worker paths)
    pub shard: usize,
    /// absolute deadline on the common clock (None = no SLO attached)
    pub deadline: Option<f64>,
    /// round boundaries admission control deferred this request at
    pub deferred_rounds: usize,
    /// true when admission control shed the request before it ever
    /// occupied a batch row
    pub shed: bool,
    /// when the first generated token was committed, on the common clock
    /// (None for shed requests and paths that don't track it) — TTFT is
    /// the headline metric prefix sharing moves: a prefix hit skips most
    /// of the prefill, which lands entirely before the first token
    pub first_token_at: Option<f64>,
}

impl RequestRecord {
    /// The paper's latency: t_b - t_a (queueing included).
    pub fn latency(&self) -> f64 {
        self.finished_at - self.sent_at
    }

    pub fn queue_delay(&self) -> f64 {
        self.started_at - self.sent_at
    }

    pub fn service_time(&self) -> f64 {
        self.finished_at - self.started_at
    }

    /// Time to first token: `first_token_at - sent_at` (queueing
    /// included), `None` where the first-token instant wasn't tracked.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.sent_at)
    }

    /// Whether the request met its SLO: `None` when it carried no
    /// deadline, `Some(false)` for sheds and late completions.
    pub fn slo_met(&self) -> Option<bool> {
        self.deadline
            .map(|d| !self.shed && self.finished_at <= d)
    }
}

/// SLO attainment accounting over a set of request records.
///
/// Conservation (pinned by the property tests): every deadlined request
/// is exactly one of met / missed / shed, i.e.
/// `met + missed + shed_deadlined == deadlined`, and with every request
/// deadlined, `met + missed + shed == completed + shed == total`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloSummary {
    /// requests carrying a deadline (completed or shed)
    pub deadlined: usize,
    /// deadlined requests that completed on time
    pub met: usize,
    /// deadlined requests that completed late
    pub missed: usize,
    /// requests shed by admission control (all sheds, deadlined or not)
    pub shed: usize,
    /// requests that completed (with or without a deadline)
    pub completed: usize,
}

impl SloSummary {
    /// Fraction of deadlined requests that met their SLO; sheds count
    /// against attainment.  NaN when nothing carried a deadline.
    pub fn attainment(&self) -> f64 {
        if self.deadlined == 0 {
            return f64::NAN;
        }
        self.met as f64 / self.deadlined as f64
    }
}

/// Accumulates completed requests and summarizes them.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    records: Vec<RequestRecord>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Records of requests that actually completed (sheds excluded).
    pub fn completed(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records.iter().filter(|r| !r.shed)
    }

    /// Requests shed by admission control.
    pub fn shed_count(&self) -> usize {
        self.records.iter().filter(|r| r.shed).count()
    }

    /// End-to-end latencies of **completed** requests; a shed request has
    /// no service latency, only the attainment accounting sees it.
    pub fn latencies(&self) -> Vec<f64> {
        self.completed().map(|r| r.latency()).collect()
    }

    /// TTFTs of completed requests that tracked their first-token instant
    /// (shed requests never commit a token).
    pub fn ttfts(&self) -> Vec<f64> {
        self.completed().filter_map(|r| r.ttft()).collect()
    }

    /// Mean TTFT over completed requests; NaN when nothing tracked it.
    pub fn mean_ttft(&self) -> f64 {
        let t = self.ttfts();
        if t.is_empty() {
            return f64::NAN;
        }
        t.iter().sum::<f64>() / t.len() as f64
    }

    /// (p50, p90, p99) TTFT, zeros on runs that tracked none (mirrors
    /// [`Self::percentiles`]'s degenerate-run convention).
    pub fn ttft_percentiles(&self) -> (f64, f64, f64) {
        let mut t = self.ttfts();
        if t.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        t.sort_by(f64::total_cmp);
        (
            percentile_sorted(&t, 50.0),
            percentile_sorted(&t, 90.0),
            percentile_sorted(&t, 99.0),
        )
    }

    /// SLO attainment accounting across all records, sheds included.
    pub fn slo_attainment(&self) -> SloSummary {
        let mut s = SloSummary::default();
        for r in &self.records {
            if r.shed {
                s.shed += 1;
            } else {
                s.completed += 1;
            }
            if r.deadline.is_some() {
                s.deadlined += 1;
                match r.slo_met() {
                    Some(true) => s.met += 1,
                    Some(false) if !r.shed => s.missed += 1,
                    _ => {}
                }
            }
        }
        s
    }

    pub fn summary(&self) -> Summary {
        summary(&self.latencies())
    }

    /// (p50, p90, p99) request latency.  Well-defined on degenerate
    /// runs: an empty recorder (or all-shed run) yields `(0, 0, 0)`
    /// rather than NaN, and a single completed record yields that
    /// record's latency for every percentile.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        let mut l = self.latencies();
        if l.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        l.sort_by(f64::total_cmp);
        (
            percentile_sorted(&l, 50.0),
            percentile_sorted(&l, 90.0),
            percentile_sorted(&l, 99.0),
        )
    }

    /// Mean per-token request latency over **completed** requests: each
    /// request's end-to-end latency (queueing included) divided by its
    /// generated tokens, averaged over requests — the cluster routing
    /// comparison metric.  Shed requests generated nothing and used to
    /// silently skew this with their queue delay over `max(tokens, 1)`;
    /// they are excluded here and accounted by [`Self::slo_attainment`].
    pub fn mean_per_token_latency(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for r in self.completed() {
            sum += r.latency() / r.tokens.max(1) as f64;
            n += 1;
        }
        if n == 0 {
            return f64::NAN;
        }
        sum / n as f64
    }

    /// Requests **completed** per shard, indexed 0..=max shard id seen
    /// (sheds are counted separately by [`Self::per_shard_shed_counts`],
    /// not silently dropped).
    pub fn per_shard_counts(&self) -> Vec<usize> {
        self.per_shard_by(|r| !r.shed)
    }

    /// Requests shed per shard, indexed 0..=max shard id seen.
    pub fn per_shard_shed_counts(&self) -> Vec<usize> {
        self.per_shard_by(|r| r.shed)
    }

    fn per_shard_by(&self, keep: impl Fn(&RequestRecord) -> bool) -> Vec<usize> {
        let n = self.records.iter().map(|r| r.shard + 1).max().unwrap_or(0);
        let mut counts = vec![0usize; n];
        for r in self.records.iter().filter(|r| keep(r)) {
            counts[r.shard] += 1;
        }
        counts
    }

    /// Generated tokens per second of span (first send -> last finish,
    /// completed requests only — sheds generate nothing).
    pub fn throughput_tokens_per_s(&self) -> f64 {
        let mut t0 = f64::INFINITY;
        let mut t1 = f64::NEG_INFINITY;
        let mut tokens = 0usize;
        for r in self.completed() {
            t0 = t0.min(r.sent_at);
            t1 = t1.max(r.finished_at);
            tokens += r.tokens;
        }
        if !t0.is_finite() {
            return 0.0;
        }
        if t1 <= t0 {
            return f64::NAN;
        }
        tokens as f64 / (t1 - t0)
    }

    /// Full export (one row per request, sheds included).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "id",
            "sent_at_s",
            "started_at_s",
            "finished_at_s",
            "latency_s",
            "queue_delay_s",
            "ttft_s",
            "tokens",
            "batch",
            "spec_len",
            "shard",
            "deadline_s",
            "slo_met",
            "deferred_rounds",
            "shed",
        ]);
        let mut sorted = self.records.clone();
        sorted.sort_by(|a, b| a.sent_at.total_cmp(&b.sent_at));
        for r in &sorted {
            csv.row(&[
                r.id.to_string(),
                f(r.sent_at),
                f(r.started_at),
                f(r.finished_at),
                f(r.latency()),
                f(r.queue_delay()),
                r.ttft().map(f).unwrap_or_default(),
                r.tokens.to_string(),
                r.batch.to_string(),
                r.spec_len.to_string(),
                r.shard.to_string(),
                r.deadline.map(f).unwrap_or_default(),
                r.slo_met().map(|m| m.to_string()).unwrap_or_default(),
                r.deferred_rounds.to_string(),
                r.shed.to_string(),
            ]);
        }
        csv
    }
}

/// One decode-round boundary of the serving loop, as recorded by the
/// continuous batcher (and mirrored by the DES simulator): when it
/// happened, which serving epoch it belonged to, how many requests were
/// live and queued, and the speculation length the policy chose.  This is
/// the raw material of the "s adapts to the live batch" timelines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundEvent {
    /// experiment-clock seconds of the round boundary
    pub t: f64,
    /// serving epoch (contiguous busy period / static batch index)
    pub epoch: usize,
    /// live requests when the policy was queried
    pub live: usize,
    /// executing width (the padded bucket, `>= live`); with `live`,
    /// `s`, and `accepted` this makes the round's goodput/waste split
    /// (`telemetry::attrib::RoundWaste`) recoverable from the record
    pub width: usize,
    /// requests waiting in the queue
    pub queued: usize,
    /// speculation length chosen for the round — the widest per-row
    /// draft length (`s_max`) when the policy chose a ragged vector
    pub s: usize,
    /// draft tokens actually produced over the live rows (`Σ s_i`; equal
    /// to `live * s` on uniform rounds, 0 for plain rounds).  With
    /// `accepted` this makes the generalized waste split exact even on
    /// ragged rounds, where intra-row slack `(s - s_i)` is padding
    pub drafted: usize,
    /// drafts accepted over the live rows (0 for plain rounds)
    pub accepted: usize,
    /// measured cost of the round in seconds (wall or virtual)
    pub round_cost: f64,
    /// KV blocks held at the round boundary under the paged layout (the
    /// block-utilization counter; 0 under the dense layout and on the
    /// batch-to-completion path, which reconstructs rounds post hoc)
    pub kv_blocks: usize,
}

/// Export a round timeline (columns: t_s, epoch, live, width, queued,
/// s, drafted, accepted, rejected, padding, round_cost_s, kv_blocks).
/// The `rejected`/`padding` columns are the round's mispeculation waste
/// and padding slack in token slots, derived from the generalized
/// slot-tiling identity (`telemetry::attrib::RoundWaste`): `rejected =
/// drafted - accepted` and `padding = width*(s+1) - live - drafted`, so
/// on ragged rounds intra-row slack `(s - s_i)` lands in `padding` and
/// the CSV stays self-describing for downstream waste-surface analysis.
pub fn rounds_to_csv(events: &[RoundEvent]) -> Csv {
    let mut csv = Csv::new(&[
        "t_s",
        "epoch",
        "live",
        "width",
        "queued",
        "s",
        "drafted",
        "accepted",
        "rejected",
        "padding",
        "round_cost_s",
        "kv_blocks",
    ]);
    for e in events {
        csv.row(&[
            f(e.t),
            e.epoch.to_string(),
            e.live.to_string(),
            e.width.to_string(),
            e.queued.to_string(),
            e.s.to_string(),
            e.drafted.to_string(),
            e.accepted.to_string(),
            e.drafted.saturating_sub(e.accepted).to_string(),
            (e.width * (e.s + 1))
                .saturating_sub(e.live + e.drafted)
                .to_string(),
            f(e.round_cost),
            e.kv_blocks.to_string(),
        ]);
    }
    csv
}

/// One Fig. 6 timeline point: a group of consecutive requests by send time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    /// send time of the first request in the group (the X axis)
    pub t_start: f64,
    /// mean latency of the group (the Y axis)
    pub mean_latency: f64,
    pub n: usize,
}

/// Group completed requests into consecutive-`group_size` buckets by send
/// time (Fig. 6 uses groups of 40).  Shed requests have no service
/// latency and are skipped.  Degenerate inputs are well-defined: an
/// empty record set or a zero `group_size` yields no points (a short run
/// with fewer records than `group_size` yields one partial point).
pub fn timeline_groups(records: &[RequestRecord], group_size: usize) -> Vec<TimelinePoint> {
    if group_size == 0 {
        return Vec::new();
    }
    let mut sorted: Vec<&RequestRecord> = records.iter().filter(|r| !r.shed).collect();
    sorted.sort_by(|a, b| a.sent_at.total_cmp(&b.sent_at));
    sorted
        .chunks(group_size)
        .map(|chunk| TimelinePoint {
            t_start: chunk[0].sent_at,
            mean_latency: chunk.iter().map(|r| r.latency()).sum::<f64>() / chunk.len() as f64,
            n: chunk.len(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, sent: f64, started: f64, fin: f64) -> RequestRecord {
        RequestRecord {
            id,
            sent_at: sent,
            started_at: started,
            finished_at: fin,
            tokens: 10,
            batch: 2,
            spec_len: 3,
            shard: 0,
            deadline: None,
            deferred_rounds: 0,
            shed: false,
            first_token_at: Some(started),
        }
    }

    fn shed_rec(id: u64, sent: f64, shed_at: f64, deadline: f64) -> RequestRecord {
        RequestRecord {
            id,
            sent_at: sent,
            started_at: shed_at,
            finished_at: shed_at,
            tokens: 0,
            batch: 0,
            spec_len: 0,
            shard: 0,
            deadline: Some(deadline),
            deferred_rounds: 2,
            shed: true,
            first_token_at: None,
        }
    }

    #[test]
    fn latency_includes_queueing() {
        let r = rec(1, 0.0, 2.0, 5.0);
        assert_eq!(r.latency(), 5.0);
        assert_eq!(r.queue_delay(), 2.0);
        assert_eq!(r.service_time(), 3.0);
    }

    #[test]
    fn recorder_summary_and_throughput() {
        let mut rec_ = LatencyRecorder::new();
        rec_.push(rec(1, 0.0, 0.0, 1.0));
        rec_.push(rec(2, 1.0, 1.5, 3.0));
        let s = rec_.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 1.5).abs() < 1e-12);
        // 20 tokens over [0, 3] seconds
        assert!((rec_.throughput_tokens_per_s() - 20.0 / 3.0).abs() < 1e-12);
        let (p50, p90, p99) = rec_.percentiles();
        assert!(p50 <= p90 && p90 <= p99);
        // per-token: latencies 1.0 and 2.0 over 10 tokens each
        assert!((rec_.mean_per_token_latency() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn per_shard_counts_index_by_shard_id() {
        let mut rec_ = LatencyRecorder::new();
        rec_.push(rec(1, 0.0, 0.0, 1.0)); // shard 0
        let mut r2 = rec(2, 1.0, 1.5, 3.0);
        r2.shard = 2;
        rec_.push(r2);
        assert_eq!(rec_.per_shard_counts(), vec![1, 0, 1]);
        assert!(LatencyRecorder::new().per_shard_counts().is_empty());
        // sheds are counted separately, not silently dropped
        let mut s = shed_rec(3, 0.5, 0.9, 0.8);
        s.shard = 2;
        rec_.push(s);
        assert_eq!(rec_.per_shard_counts(), vec![1, 0, 1]);
        assert_eq!(rec_.per_shard_shed_counts(), vec![0, 0, 1]);
    }

    #[test]
    fn slo_met_and_attainment_accounting() {
        let mut r = rec(1, 0.0, 0.0, 1.0);
        assert_eq!(r.slo_met(), None, "no deadline, no verdict");
        r.deadline = Some(1.5);
        assert_eq!(r.slo_met(), Some(true));
        r.deadline = Some(0.5);
        assert_eq!(r.slo_met(), Some(false));
        let s = shed_rec(2, 0.0, 0.4, 0.3);
        assert_eq!(s.slo_met(), Some(false), "sheds never meet their SLO");

        let mut recd = LatencyRecorder::new();
        let mut met = rec(1, 0.0, 0.0, 1.0);
        met.deadline = Some(2.0);
        let mut missed = rec(2, 0.0, 0.5, 3.0);
        missed.deadline = Some(2.0);
        recd.push(met);
        recd.push(missed);
        recd.push(rec(3, 0.0, 0.0, 1.0)); // no deadline
        recd.push(shed_rec(4, 0.0, 0.4, 0.3));
        let s = recd.slo_attainment();
        assert_eq!(s.deadlined, 3);
        assert_eq!(s.met, 1);
        assert_eq!(s.missed, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 3);
        // conservation: every deadlined request is met, missed, or shed
        assert_eq!(s.met + s.missed + 1, s.deadlined);
        assert!((s.attainment() - 1.0 / 3.0).abs() < 1e-12);
        assert!(LatencyRecorder::new().slo_attainment().attainment().is_nan());
    }

    #[test]
    fn ttft_tracks_first_token_and_skips_untracked_records() {
        let mut recd = LatencyRecorder::new();
        let mut a = rec(1, 0.0, 2.0, 5.0); // rec() stamps first token at start
        a.first_token_at = Some(3.0);
        recd.push(a);
        assert_eq!(a.ttft(), Some(3.0));
        let mut b = rec(2, 1.0, 1.0, 4.0);
        b.first_token_at = None; // untracked path: no TTFT contribution
        recd.push(b);
        recd.push(shed_rec(3, 0.0, 0.4, 0.3)); // sheds never count
        assert_eq!(recd.ttfts(), vec![3.0]);
        assert!((recd.mean_ttft() - 3.0).abs() < 1e-12);
        assert_eq!(recd.ttft_percentiles(), (3.0, 3.0, 3.0));
        assert!(LatencyRecorder::new().mean_ttft().is_nan());
        assert_eq!(LatencyRecorder::new().ttft_percentiles(), (0.0, 0.0, 0.0));
        // the CSV export carries the ttft_s column
        let out = recd.to_csv().to_string();
        assert!(out.lines().next().unwrap().contains("ttft_s"));
    }

    #[test]
    fn shed_records_stay_out_of_latency_and_throughput_stats() {
        let mut recd = LatencyRecorder::new();
        recd.push(rec(1, 0.0, 0.0, 1.0));
        recd.push(rec(2, 1.0, 1.5, 3.0));
        let clean_mean = recd.summary().mean;
        let clean_tput = recd.throughput_tokens_per_s();
        let clean_ptl = recd.mean_per_token_latency();
        // a shed far in the future must not move any service-side stat
        recd.push(shed_rec(3, 2.0, 99.0, 4.0));
        assert_eq!(recd.shed_count(), 1);
        assert_eq!(recd.len(), 3, "sheds stay visible in the record count");
        assert!((recd.summary().mean - clean_mean).abs() < 1e-12);
        assert!((recd.throughput_tokens_per_s() - clean_tput).abs() < 1e-12);
        assert!((recd.mean_per_token_latency() - clean_ptl).abs() < 1e-12);
    }

    #[test]
    fn percentiles_are_well_defined_on_degenerate_runs() {
        // empty recorder: zeros, not NaN
        assert_eq!(LatencyRecorder::new().percentiles(), (0.0, 0.0, 0.0));
        // all-shed run behaves like empty (no completed latencies)
        let mut all_shed = LatencyRecorder::new();
        all_shed.push(shed_rec(1, 0.0, 0.4, 0.3));
        assert_eq!(all_shed.percentiles(), (0.0, 0.0, 0.0));

        // single record: every percentile is that record's latency
        let mut one = LatencyRecorder::new();
        one.push(rec(1, 0.0, 0.0, 2.5));
        assert_eq!(one.percentiles(), (2.5, 2.5, 2.5));

        // two records (latencies 1.0 and 3.0): linear interpolation
        let mut two = LatencyRecorder::new();
        two.push(rec(1, 0.0, 0.0, 1.0));
        two.push(rec(2, 0.0, 0.0, 3.0));
        let (p50, p90, p99) = two.percentiles();
        assert!((p50 - 2.0).abs() < 1e-12);
        assert!((p90 - 2.8).abs() < 1e-12);
        assert!((p99 - 2.98).abs() < 1e-12);
    }

    #[test]
    fn percentiles_pinned_on_100_element_run() {
        // latencies 1..=100 seconds
        let mut r = LatencyRecorder::new();
        for i in 1..=100u64 {
            r.push(rec(i, 0.0, 0.0, i as f64));
        }
        let (p50, p90, p99) = r.percentiles();
        // interpolated index q/100 * 99 over sorted [1, 100]
        assert!((p50 - 50.5).abs() < 1e-9, "p50 {p50}");
        assert!((p90 - 90.1).abs() < 1e-9, "p90 {p90}");
        assert!((p99 - 99.01).abs() < 1e-9, "p99 {p99}");
    }

    #[test]
    fn timeline_groups_degenerate_inputs() {
        // zero group size: no points rather than a panic
        assert!(timeline_groups(&[rec(1, 0.0, 0.0, 1.0)], 0).is_empty());
        // empty input
        assert!(timeline_groups(&[], 40).is_empty());
        // fewer records than the group size: one partial point
        let pts = timeline_groups(&[rec(1, 0.0, 0.0, 1.0)], 40);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].n, 1);
        assert!((pts[0].mean_latency - 1.0).abs() < 1e-12);
        // all-shed input yields no points
        assert!(timeline_groups(&[shed_rec(1, 0.0, 0.4, 0.3)], 40).is_empty());
    }

    #[test]
    fn timeline_grouping_is_by_send_time() {
        let records = vec![
            rec(3, 2.0, 2.0, 4.0), // out of order on purpose
            rec(1, 0.0, 0.0, 1.0),
            rec(2, 1.0, 1.0, 3.0),
        ];
        let pts = timeline_groups(&records, 2);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].t_start, 0.0);
        assert_eq!(pts[0].n, 2);
        // group 0: latencies 1.0 and 2.0
        assert!((pts[0].mean_latency - 1.5).abs() < 1e-12);
        assert_eq!(pts[1].n, 1);
    }

    #[test]
    fn round_events_export_to_csv() {
        let events = vec![
            RoundEvent {
                t: 0.1,
                epoch: 1,
                live: 1,
                width: 2,
                queued: 3,
                s: 5,
                drafted: 5,
                accepted: 2,
                round_cost: 0.03,
                kv_blocks: 2,
            },
            RoundEvent {
                t: 0.2,
                epoch: 1,
                live: 4,
                width: 4,
                queued: 0,
                s: 2,
                drafted: 8,
                accepted: 5,
                round_cost: 0.04,
                kv_blocks: 9,
            },
            // ragged round: 3 live rows at s_max 4 drafted only 4+2+0=6
            // of the 3*4 uniform slots; the 6-slot shortfall is padding
            RoundEvent {
                t: 0.3,
                epoch: 2,
                live: 3,
                width: 4,
                queued: 1,
                s: 4,
                drafted: 6,
                accepted: 4,
                round_cost: 0.05,
                kv_blocks: 7,
            },
        ];
        let out = rounds_to_csv(&events).to_string();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(
            lines[0],
            "t_s,epoch,live,width,queued,s,drafted,accepted,rejected,padding,round_cost_s,kv_blocks"
        );
        assert_eq!(lines.len(), 4);
        // live 1, width 2, s 5, drafted 5, accepted 2 → rejected 5-2=3,
        // padding 2*(5+1)-1-5=6
        assert!(lines[1].contains(",1,1,2,3,5,5,2,3,6,"), "{}", lines[1]);
        assert!(lines[1].ends_with(",2"), "{}", lines[1]);
        // live 4, width 4, s 2, drafted 8, accepted 5 → rejected 3, padding 0
        assert!(lines[2].contains(",1,4,4,0,2,8,5,3,0,"), "{}", lines[2]);
        assert!(lines[2].ends_with(",9"), "{}", lines[2]);
        // ragged: rejected 6-4=2, padding 4*(4+1)-3-6=11
        assert!(lines[3].contains(",2,3,4,1,4,6,4,2,11,"), "{}", lines[3]);
        assert!(lines[3].ends_with(",7"), "{}", lines[3]);
    }

    #[test]
    fn csv_is_sorted_by_send_time() {
        let mut r = LatencyRecorder::new();
        r.push(rec(2, 5.0, 5.0, 6.0));
        r.push(rec(1, 0.0, 0.0, 1.0));
        let out = r.to_csv().to_string();
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[1].starts_with('1'));
        assert!(lines[2].starts_with('2'));
    }
}
