//! Request-level latency metrics and timeline bucketing.
//!
//! The paper's serving metric (Sec. 5.3) is end-to-end request latency
//! `t_b - t_a`: from the client send to the server finishing the request,
//! *including queueing delay*.  [`LatencyRecorder`] accumulates completed
//! requests; [`timeline_groups`] reproduces Fig. 6's presentation (each
//! point = one group of 40 consecutive requests by send time).

use crate::util::csv::{f, Csv};
use crate::util::stats::{percentile_sorted, summary, Summary};

/// One completed request, in seconds on a common clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    /// client send time (t_a)
    pub sent_at: f64,
    /// server pulled it into a batch
    pub started_at: f64,
    /// server finished generating (t_b)
    pub finished_at: f64,
    /// generated tokens
    pub tokens: usize,
    /// batch size it was served in
    pub batch: usize,
    /// speculation length used for (the first round of) its batch
    pub spec_len: usize,
    /// worker shard that served it (0 on the single-worker paths)
    pub shard: usize,
}

impl RequestRecord {
    /// The paper's latency: t_b - t_a (queueing included).
    pub fn latency(&self) -> f64 {
        self.finished_at - self.sent_at
    }

    pub fn queue_delay(&self) -> f64 {
        self.started_at - self.sent_at
    }

    pub fn service_time(&self) -> f64 {
        self.finished_at - self.started_at
    }
}

/// Accumulates completed requests and summarizes them.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    records: Vec<RequestRecord>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency()).collect()
    }

    pub fn summary(&self) -> Summary {
        summary(&self.latencies())
    }

    /// (p50, p90, p99) request latency.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        let mut l = self.latencies();
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            percentile_sorted(&l, 50.0),
            percentile_sorted(&l, 90.0),
            percentile_sorted(&l, 99.0),
        )
    }

    /// Mean per-token request latency: each request's end-to-end latency
    /// (queueing included) divided by its generated tokens, averaged over
    /// requests — the cluster routing comparison metric.
    pub fn mean_per_token_latency(&self) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        self.records
            .iter()
            .map(|r| r.latency() / r.tokens.max(1) as f64)
            .sum::<f64>()
            / self.records.len() as f64
    }

    /// Requests served per shard, indexed 0..=max shard id seen.
    pub fn per_shard_counts(&self) -> Vec<usize> {
        let n = self.records.iter().map(|r| r.shard + 1).max().unwrap_or(0);
        let mut counts = vec![0usize; n];
        for r in &self.records {
            counts[r.shard] += 1;
        }
        counts
    }

    /// Generated tokens per second of span (first send -> last finish).
    pub fn throughput_tokens_per_s(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let t0 = self.records.iter().map(|r| r.sent_at).fold(f64::INFINITY, f64::min);
        let t1 = self
            .records
            .iter()
            .map(|r| r.finished_at)
            .fold(f64::NEG_INFINITY, f64::max);
        let tokens: usize = self.records.iter().map(|r| r.tokens).sum();
        if t1 <= t0 {
            return f64::NAN;
        }
        tokens as f64 / (t1 - t0)
    }

    /// Full export (one row per request).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "id",
            "sent_at_s",
            "started_at_s",
            "finished_at_s",
            "latency_s",
            "queue_delay_s",
            "tokens",
            "batch",
            "spec_len",
            "shard",
        ]);
        let mut sorted = self.records.clone();
        sorted.sort_by(|a, b| a.sent_at.partial_cmp(&b.sent_at).unwrap());
        for r in &sorted {
            csv.row(&[
                r.id.to_string(),
                f(r.sent_at),
                f(r.started_at),
                f(r.finished_at),
                f(r.latency()),
                f(r.queue_delay()),
                r.tokens.to_string(),
                r.batch.to_string(),
                r.spec_len.to_string(),
                r.shard.to_string(),
            ]);
        }
        csv
    }
}

/// One decode-round boundary of the serving loop, as recorded by the
/// continuous batcher (and mirrored by the DES simulator): when it
/// happened, which serving epoch it belonged to, how many requests were
/// live and queued, and the speculation length the policy chose.  This is
/// the raw material of the "s adapts to the live batch" timelines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundEvent {
    /// experiment-clock seconds of the round boundary
    pub t: f64,
    /// serving epoch (contiguous busy period / static batch index)
    pub epoch: usize,
    /// live requests when the policy was queried
    pub live: usize,
    /// requests waiting in the queue
    pub queued: usize,
    /// speculation length chosen for the round
    pub s: usize,
    /// drafts accepted over the live rows (0 for plain rounds)
    pub accepted: usize,
    /// measured cost of the round in seconds (wall or virtual)
    pub round_cost: f64,
    /// KV blocks held at the round boundary under the paged layout (the
    /// block-utilization counter; 0 under the dense layout and on the
    /// batch-to-completion path, which reconstructs rounds post hoc)
    pub kv_blocks: usize,
}

/// Export a round timeline (columns: t_s, epoch, live, queued, s,
/// accepted, round_cost_s, kv_blocks).
pub fn rounds_to_csv(events: &[RoundEvent]) -> Csv {
    let mut csv = Csv::new(&[
        "t_s",
        "epoch",
        "live",
        "queued",
        "s",
        "accepted",
        "round_cost_s",
        "kv_blocks",
    ]);
    for e in events {
        csv.row(&[
            f(e.t),
            e.epoch.to_string(),
            e.live.to_string(),
            e.queued.to_string(),
            e.s.to_string(),
            e.accepted.to_string(),
            f(e.round_cost),
            e.kv_blocks.to_string(),
        ]);
    }
    csv
}

/// One Fig. 6 timeline point: a group of consecutive requests by send time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    /// send time of the first request in the group (the X axis)
    pub t_start: f64,
    /// mean latency of the group (the Y axis)
    pub mean_latency: f64,
    pub n: usize,
}

/// Group completed requests into consecutive-`group_size` buckets by send
/// time (Fig. 6 uses groups of 40).
pub fn timeline_groups(records: &[RequestRecord], group_size: usize) -> Vec<TimelinePoint> {
    assert!(group_size > 0);
    let mut sorted: Vec<&RequestRecord> = records.iter().collect();
    sorted.sort_by(|a, b| a.sent_at.partial_cmp(&b.sent_at).unwrap());
    sorted
        .chunks(group_size)
        .map(|chunk| TimelinePoint {
            t_start: chunk[0].sent_at,
            mean_latency: chunk.iter().map(|r| r.latency()).sum::<f64>() / chunk.len() as f64,
            n: chunk.len(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, sent: f64, started: f64, fin: f64) -> RequestRecord {
        RequestRecord {
            id,
            sent_at: sent,
            started_at: started,
            finished_at: fin,
            tokens: 10,
            batch: 2,
            spec_len: 3,
            shard: 0,
        }
    }

    #[test]
    fn latency_includes_queueing() {
        let r = rec(1, 0.0, 2.0, 5.0);
        assert_eq!(r.latency(), 5.0);
        assert_eq!(r.queue_delay(), 2.0);
        assert_eq!(r.service_time(), 3.0);
    }

    #[test]
    fn recorder_summary_and_throughput() {
        let mut rec_ = LatencyRecorder::new();
        rec_.push(rec(1, 0.0, 0.0, 1.0));
        rec_.push(rec(2, 1.0, 1.5, 3.0));
        let s = rec_.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 1.5).abs() < 1e-12);
        // 20 tokens over [0, 3] seconds
        assert!((rec_.throughput_tokens_per_s() - 20.0 / 3.0).abs() < 1e-12);
        let (p50, p90, p99) = rec_.percentiles();
        assert!(p50 <= p90 && p90 <= p99);
        // per-token: latencies 1.0 and 2.0 over 10 tokens each
        assert!((rec_.mean_per_token_latency() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn per_shard_counts_index_by_shard_id() {
        let mut rec_ = LatencyRecorder::new();
        rec_.push(rec(1, 0.0, 0.0, 1.0)); // shard 0
        let mut r2 = rec(2, 1.0, 1.5, 3.0);
        r2.shard = 2;
        rec_.push(r2);
        assert_eq!(rec_.per_shard_counts(), vec![1, 0, 1]);
        assert!(LatencyRecorder::new().per_shard_counts().is_empty());
    }

    #[test]
    fn timeline_grouping_is_by_send_time() {
        let records = vec![
            rec(3, 2.0, 2.0, 4.0), // out of order on purpose
            rec(1, 0.0, 0.0, 1.0),
            rec(2, 1.0, 1.0, 3.0),
        ];
        let pts = timeline_groups(&records, 2);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].t_start, 0.0);
        assert_eq!(pts[0].n, 2);
        // group 0: latencies 1.0 and 2.0
        assert!((pts[0].mean_latency - 1.5).abs() < 1e-12);
        assert_eq!(pts[1].n, 1);
    }

    #[test]
    fn round_events_export_to_csv() {
        let events = vec![
            RoundEvent {
                t: 0.1,
                epoch: 1,
                live: 1,
                queued: 3,
                s: 5,
                accepted: 2,
                round_cost: 0.03,
                kv_blocks: 2,
            },
            RoundEvent {
                t: 0.2,
                epoch: 1,
                live: 4,
                queued: 0,
                s: 2,
                accepted: 5,
                round_cost: 0.04,
                kv_blocks: 9,
            },
        ];
        let out = rounds_to_csv(&events).to_string();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(
            lines[0],
            "t_s,epoch,live,queued,s,accepted,round_cost_s,kv_blocks"
        );
        assert_eq!(lines.len(), 3);
        assert!(lines[1].contains(",1,1,3,5,2,"), "{}", lines[1]);
        assert!(lines[1].ends_with(",2"), "{}", lines[1]);
        assert!(lines[2].contains(",1,4,0,2,5,"), "{}", lines[2]);
        assert!(lines[2].ends_with(",9"), "{}", lines[2]);
    }

    #[test]
    fn csv_is_sorted_by_send_time() {
        let mut r = LatencyRecorder::new();
        r.push(rec(2, 5.0, 5.0, 6.0));
        r.push(rec(1, 0.0, 0.0, 1.0));
        let out = r.to_csv().to_string();
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[1].starts_with('1'));
        assert!(lines[2].starts_with('2'));
    }
}
