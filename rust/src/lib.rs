//! # specbatch — batched speculative decoding with adaptive speculation
//!
//! Rust coordinator (L3) of the three-layer reproduction of *"The Synergy
//! of Speculative Decoding and Batching in Serving Large Language Models"*
//! (Su, Giannoula, Pekhimenko, 2023).
//!
//! The layers (see DESIGN.md):
//!
//! * **L1** — Pallas kernels (masked verify-attention, vocab argmax),
//!   authored in `python/compile/kernels/`, lowered into the same HLO as…
//! * **L2** — the JAX OPT-style LLM/SSM pair (`python/compile/model.py`),
//!   AOT-lowered to HLO text per `(kind, batch, s)` executable.
//! * **L3** — this crate: loads the artifacts through the PJRT C API
//!   ([`runtime`]), runs the batched speculative decoding loop
//!   ([`engine`]), picks speculation lengths ([`scheduler`]), serves
//!   Gamma-distributed traffic through a message queue ([`server`],
//!   [`traffic`]) and reproduces every figure of the paper ([`simulator`],
//!   [`analytic`], `rust/benches/`).
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use specbatch::prelude::*;
//!
//! let rt = Runtime::load("artifacts")?;
//! let mut engine = Engine::new(&rt, EngineConfig::default())?;
//! let out = engine.generate_batch(
//!     &[vec![1, 5, 9]],
//!     16,
//!     &SpecPolicy::Fixed(3),
//! )?;
//! println!("{:?}", out.tokens[0]);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod analytic;
pub mod config;
pub mod dataset;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod testkit;
pub mod traffic;
pub mod util;


/// Most-used types in one import.
pub mod prelude {
    pub use crate::config::{PolicySpec, ServingConfig};
    pub use crate::engine::{Engine, EngineConfig, GenOutput};
    pub use crate::runtime::Runtime;
    pub use crate::scheduler::{Lut, SpecPolicy};
}
