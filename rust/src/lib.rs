//! # specbatch — batched speculative decoding with adaptive speculation
//!
//! Rust coordinator (L3) of the three-layer reproduction of *"The Synergy
//! of Speculative Decoding and Batching in Serving Large Language Models"*
//! (Su, Giannoula, Pekhimenko, 2023).
//!
//! The layers (see DESIGN.md):
//!
//! * **L1** — Pallas kernels (masked verify-attention, vocab argmax),
//!   authored in `python/compile/kernels/`, lowered into the same HLO as…
//! * **L2** — the JAX OPT-style LLM/SSM pair (`python/compile/model.py`),
//!   AOT-lowered to HLO text per `(kind, batch, s)` executable.
//! * **L3** — this crate: runs the batched speculative decoding loop at
//!   round granularity ([`engine`]), schedules requests through static or
//!   continuous batching ([`batcher`], [`server`]), picks speculation
//!   lengths through the feedback-driven [`policy`] subsystem (offline
//!   LUT [`scheduler`] or the online model-based policy), shards traffic
//!   across multiple workers with speculation-aware routing ([`cluster`]),
//!   generates Gamma-distributed traffic ([`traffic`]) and reproduces
//!   every figure of the paper ([`simulator`], [`analytic`],
//!   `rust/benches/`).
//!
//! Backends: with `--features pjrt` the engine executes the AOT artifacts
//! through the PJRT C API ([`runtime`]; Python never runs on the request
//! path).  The default build substitutes a deterministic stub model pair
//! ([`testkit::stub`]) honouring the identical calling convention, so the
//! whole serving stack builds, tests and demos without artifacts.
//!
//! ## Quick start
//!
//! ```no_run
//! use specbatch::prelude::*;
//!
//! // default build: deterministic stub pair (swap in Engine::new(&rt, …)
//! // over a loaded Runtime with --features pjrt + `make artifacts`)
//! let mut engine = Engine::stub(StubSpec::default(), EngineConfig::default())?;
//! let out = engine.generate_batch(
//!     &[vec![4, 5, 9]],
//!     16,
//!     &mut Fixed(3),
//! )?;
//! println!("{:?}", out.tokens[0]);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod admission;
pub mod analytic;
pub mod batcher;
pub mod cluster;
pub mod config;
pub mod dataset;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod telemetry;
pub mod testkit;
pub mod traffic;
pub mod util;

/// Most-used types in one import.
pub mod prelude {
    pub use crate::admission::{
        build_controller, AdmissionController, Edf, Fifo, SloAware,
    };
    pub use crate::batcher::{BatchRequest, BatcherConfig, ContinuousBatcher};
    pub use crate::cluster::sim::simulate_trace_cluster;
    pub use crate::cluster::{build_router, replicate_policies, Router, ShardLoad};
    pub use crate::config::{AdmissionSpec, PolicySpec, RouterSpec, ServingConfig};
    pub use crate::engine::{BatchState, Engine, EngineConfig, GenOutput};
    pub use crate::kvcache::prefix::{PrefixCache, PrefixStats};
    pub use crate::kvcache::{BlockManager, KvBlockStats, KvLayout};
    pub use crate::policy::{
        Fixed, LutAdaptive, ModelBased, NoSpec, RoundFeedback, SpeculationPolicy,
    };
    #[cfg(feature = "pjrt")]
    pub use crate::runtime::Runtime;
    pub use crate::scheduler::Lut;
    pub use crate::server::{Backend, SchedulingMode};
    pub use crate::telemetry::{Telemetry, TelemetryMode};
    pub use crate::testkit::stub::StubSpec;
}
