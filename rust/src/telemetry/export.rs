//! Telemetry exporters: Chrome `trace_event` JSON, Prometheus text
//! exposition, and JSONL event dumps.
//!
//! The Chrome trace loads directly in Perfetto (`ui.perfetto.dev`) or
//! `chrome://tracing`.  Track layout: one *process* per shard (`pid` =
//! shard index), with four *threads* per shard —
//!
//! | tid | track          | events                                  |
//! |-----|----------------|-----------------------------------------|
//! | 0   | rounds         | `ph:"X"` complete spans, one per round  |
//! | 1   | phases         | `ph:"X"` draft/verify/accept/… sub-spans|
//! | 2   | requests       | `ph:"i"` admission/finish/route instants|
//! | 3   | policy         | `ph:"i"` policy-fit snapshots           |
//!
//! KV-pool samples become `ph:"C"` counter events so Perfetto renders a
//! utilization track.  Timestamps are microseconds (`ts = t * 1e6`) on
//! whichever clock produced the events — virtual time for the DES,
//! wall time for the threaded server — the schema is identical.

use super::{Event, EventKind, Histogram, Registry, Telemetry};
use crate::util::json::Json;

const TID_ROUND: usize = 0;
const TID_PHASE: usize = 1;
const TID_REQUEST: usize = 2;
const TID_POLICY: usize = 3;

fn us(t: f64) -> Json {
    Json::Num((t * 1e6).round())
}

fn trace_record(
    name: &str,
    ph: &str,
    ev: &Event,
    tid: usize,
    args: Vec<(&str, Json)>,
) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.into())),
        ("ph", Json::Str(ph.into())),
        ("ts", us(ev.t)),
        ("pid", Json::Num(ev.shard as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(args)),
    ];
    if ph == "X" {
        pairs.push(("dur", us(ev.dur)));
    }
    if ph == "i" {
        // thread-scoped instant: renders as a tick on its own track
        pairs.push(("s", Json::Str("t".into())));
    }
    Json::obj(pairs)
}

/// Render an event list as a Chrome `trace_event` document
/// (`{"traceEvents": [...], "displayTimeUnit": "ms"}`).
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 8);
    let mut shards: Vec<usize> = events.iter().map(|e| e.shard).collect();
    shards.sort_unstable();
    shards.dedup();
    // metadata: name the per-shard processes and their tracks
    for &k in &shards {
        out.push(Json::obj(vec![
            ("name", Json::Str("process_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(k as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::Str(format!("shard {k}")))]),
            ),
        ]));
        for (tid, label) in [
            (TID_ROUND, "rounds"),
            (TID_PHASE, "phases"),
            (TID_REQUEST, "requests"),
            (TID_POLICY, "policy"),
        ] {
            out.push(Json::obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(k as f64)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::Str(label.into()))])),
            ]));
        }
    }
    for ev in events {
        match &ev.kind {
            EventKind::Round {
                epoch,
                live,
                width,
                queued,
                s,
                drafted,
                committed,
                accepted,
                s_rows,
                kv_blocks,
            } => {
                out.push(trace_record(
                    &format!("round b={live} s={s}"),
                    "X",
                    ev,
                    TID_ROUND,
                    vec![
                        ("epoch", Json::Num(*epoch as f64)),
                        ("live", Json::Num(*live as f64)),
                        ("width", Json::Num(*width as f64)),
                        ("queued", Json::Num(*queued as f64)),
                        ("s", Json::Num(*s as f64)),
                        ("drafted", Json::Num(*drafted as f64)),
                        ("committed", Json::Num(*committed as f64)),
                        (
                            "accepted",
                            Json::Arr(
                                accepted.iter().map(|&a| Json::Num(a as f64)).collect(),
                            ),
                        ),
                        (
                            "s_rows",
                            Json::Arr(
                                s_rows.iter().map(|&si| Json::Num(si as f64)).collect(),
                            ),
                        ),
                        ("kv_blocks", Json::Num(*kv_blocks as f64)),
                    ],
                ));
                // companion counter sample so Perfetto draws a KV track
                out.push(Json::obj(vec![
                    ("name", Json::Str("kv_blocks".into())),
                    ("ph", Json::Str("C".into())),
                    ("ts", us(ev.t)),
                    ("pid", Json::Num(ev.shard as f64)),
                    (
                        "args",
                        Json::obj(vec![("in_use", Json::Num(*kv_blocks as f64))]),
                    ),
                ]));
            }
            EventKind::Phase { phase } => {
                out.push(trace_record(phase.label(), "X", ev, TID_PHASE, vec![]));
            }
            EventKind::Admission {
                id,
                verdict,
                deadline,
                predicted_slack,
                deferred,
            } => {
                let opt = |v: &Option<f64>| v.map_or(Json::Null, Json::Num);
                out.push(trace_record(
                    &format!("{verdict} #{id}"),
                    "i",
                    ev,
                    TID_REQUEST,
                    vec![
                        ("id", Json::Num(*id as f64)),
                        ("verdict", Json::Str((*verdict).into())),
                        ("deadline", opt(deadline)),
                        ("predicted_slack", opt(predicted_slack)),
                        ("deferred", Json::Num(*deferred as f64)),
                    ],
                ));
            }
            EventKind::Finish {
                id,
                tokens,
                shed,
                slack,
                waterfall,
            } => {
                let name = if *shed { "shed" } else { "finish" };
                out.push(trace_record(
                    &format!("{name} #{id}"),
                    "i",
                    ev,
                    TID_REQUEST,
                    vec![
                        ("id", Json::Num(*id as f64)),
                        ("tokens", Json::Num(*tokens as f64)),
                        ("shed", Json::Bool(*shed)),
                        ("slack", slack.map_or(Json::Null, Json::Num)),
                        (
                            "waterfall",
                            waterfall.map_or(Json::Null, |w| w.to_json()),
                        ),
                    ],
                ));
            }
            EventKind::Route { id, scores } => {
                out.push(trace_record(
                    &format!("route #{id}"),
                    "i",
                    ev,
                    TID_REQUEST,
                    vec![
                        ("id", Json::Num(*id as f64)),
                        ("scores", Json::from_f64_slice(scores)),
                    ],
                ));
            }
            EventKind::PolicyFit { snapshot } => {
                out.push(trace_record(
                    "policy_fit",
                    "i",
                    ev,
                    TID_POLICY,
                    vec![("snapshot", snapshot.clone())],
                ));
            }
            EventKind::KvPool {
                in_use,
                capacity,
                frag,
                prefix_hits,
                prefill_saved,
            } => {
                out.push(Json::obj(vec![
                    ("name", Json::Str("kv_pool".into())),
                    ("ph", Json::Str("C".into())),
                    ("ts", us(ev.t)),
                    ("pid", Json::Num(ev.shard as f64)),
                    (
                        "args",
                        Json::obj(vec![
                            ("in_use", Json::Num(*in_use as f64)),
                            ("free", Json::Num(capacity.saturating_sub(*in_use) as f64)),
                            ("frag", Json::Num(*frag)),
                            ("prefix_hits", Json::Num(*prefix_hits as f64)),
                            ("prefill_saved", Json::Num(*prefill_saved as f64)),
                        ]),
                    ),
                ]));
            }
            EventKind::Trigger { cause } => {
                out.push(trace_record(
                    &format!("trigger:{cause}"),
                    "i",
                    ev,
                    TID_REQUEST,
                    vec![("cause", Json::Str((*cause).into()))],
                ));
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Render the registry in Prometheus text exposition format (OpenMetrics
/// subset): counters, gauges, and cumulative-`le` histograms.
pub fn prometheus_text(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in &reg.counters {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in &reg.gauges {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in &reg.histograms {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, &c) in h.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            out.push_str(&format!(
                "{name}_bucket{{le=\"{}\"}} {cum}\n",
                Histogram::bucket_edge(i)
            ));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// One compact-JSON line per event.
pub fn events_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().compact());
        out.push('\n');
    }
    out
}

/// Write every exporter for a handle under `<prefix>.{trace.json,
/// events.jsonl, prom}`.  Returns the paths written.  No-op (empty Vec)
/// for a disabled handle.
pub fn write_all(tel: &Telemetry, prefix: &str) -> anyhow::Result<Vec<std::path::PathBuf>> {
    if !tel.enabled() {
        return Ok(vec![]);
    }
    let mut written = Vec::new();
    if let Some(dir) = std::path::Path::new(prefix).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let prom = std::path::PathBuf::from(format!("{prefix}.prom"));
    std::fs::write(&prom, prometheus_text(&tel.registry()))?;
    written.push(prom);
    if tel.tracing() {
        let events = tel.events();
        let trace = std::path::PathBuf::from(format!("{prefix}.trace.json"));
        chrome_trace(&events).write_file(&trace)?;
        written.push(trace);
        let jsonl = std::path::PathBuf::from(format!("{prefix}.events.jsonl"));
        std::fs::write(&jsonl, events_jsonl(&events))?;
        written.push(jsonl);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{PhaseKind, TelemetryMode};

    fn sample_handle() -> Telemetry {
        let t = Telemetry::new(TelemetryMode::Trace);
        t.round(0.0, 0.10, 1, 2, 4, 1, 3, 5, &[2, 3], &[2, 3], 8);
        t.phase(0.00, 0.04, PhaseKind::Draft);
        t.phase(0.04, 0.05, PhaseKind::Verify);
        t.phase(0.09, 0.01, PhaseKind::Accept);
        t.admission(0.10, 7, "defer", Some(1.0), Some(0.4), 1);
        t.finish(0.12, 3, 24, false, Some(0.2));
        let mut wf = crate::telemetry::attrib::Waterfall {
            queue: 0.02,
            verify: 0.05,
            ..Default::default()
        };
        wf.seal(0.12);
        t.finish_attrib(0.14, 4, 24, false, None, Some(wf));
        t.for_shard(1).route(0.05, 9, 1, &[0.3, 0.1]);
        t.kv_pool(0.10, 8, 32, 0.12);
        t
    }

    #[test]
    fn chrome_trace_schema_is_valid() {
        let t = sample_handle();
        let doc = chrome_trace(&t.events());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(
                matches!(ph, "X" | "i" | "C" | "M"),
                "unexpected phase {ph}"
            );
            assert!(e.get("name").unwrap().as_str().is_ok());
            assert!(e.get("pid").unwrap().as_usize().is_ok());
            if ph != "M" {
                assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            }
            if ph == "X" {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
        }
        // round-trips through the parser (i.e. it is real JSON)
        assert_eq!(Json::parse(&doc.pretty()).unwrap(), doc);
        // both shards got process metadata
        let meta: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .collect();
        assert!(meta.len() >= 2 * 5, "process + 4 thread names per shard");
    }

    #[test]
    fn prometheus_text_exposes_cumulative_buckets() {
        let t = sample_handle();
        let text = prometheus_text(&t.registry());
        assert!(text.contains("# TYPE specbatch_rounds_total counter"));
        assert!(text.contains("specbatch_rounds_total 1"));
        assert!(text.contains("# TYPE specbatch_round_seconds histogram"));
        assert!(text.contains("specbatch_round_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("specbatch_round_seconds_count 1"));
        assert!(text.contains("specbatch_kv_blocks_in_use 8"));
        // cumulative counts never decrease
        let mut last = 0u64;
        for line in text.lines().filter(|l| {
            l.starts_with("specbatch_round_seconds_bucket") && !l.contains("+Inf")
        }) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last);
            last = v;
        }
    }

    #[test]
    fn jsonl_lines_parse_individually() {
        let t = sample_handle();
        let text = events_jsonl(&t.events());
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), t.events().len());
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("ev").unwrap().as_str().is_ok());
        }
    }

    #[test]
    fn write_all_emits_three_files_for_trace_none_for_disabled() {
        let dir = std::env::temp_dir().join("specbatch_export_test");
        let _ = std::fs::remove_dir_all(&dir);
        let prefix = dir.join("run").to_string_lossy().into_owned();
        let t = sample_handle();
        let written = write_all(&t, &prefix).unwrap();
        assert_eq!(written.len(), 3);
        for p in &written {
            assert!(p.exists(), "{p:?} missing");
        }
        assert!(write_all(&Telemetry::disabled(), &prefix)
            .unwrap()
            .is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
