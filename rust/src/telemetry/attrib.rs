//! Causal attribution: per-request latency waterfalls and per-round
//! goodput / waste accounting.
//!
//! Two tiling invariants anchor this module (pinned by
//! `rust/tests/attribution.rs`):
//!
//! 1. **Waterfall tiling** — a finished request's [`Waterfall`]
//!    components sum *exactly* to its measured end-to-end latency.
//!    Sealing ([`Waterfall::seal`]) computes `other` as the remainder,
//!    so the identity holds by construction; the DES paths additionally
//!    pin that `other` is ~0 (every virtual-time advance is attributed
//!    to a named component).
//! 2. **Slot tiling** — every decode round executes exactly
//!    `width * (s + 1)` token slots, and [`RoundWaste`] splits them
//!    *integer-exactly* into committed tokens (goodput), rejected
//!    draft tokens (mispeculation waste), and bucket-padding slack:
//!    `committed + rejected + padding == width * (s + 1)`.
//!
//! The second identity is the paper's Sec. 3.3 mechanism made
//! countable: as the batch grows at fixed `s`, the verify pass prices
//! every slot higher, so the same rejection rate wastes more compute —
//! [`WasteSurface`] aggregates rounds into the batch-size × s surface
//! the `inspect` subcommand prints.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Per-request latency decomposition.  Every field is seconds of the
/// run's clock except `deferred_rounds` (a count).  `queue` covers
/// arrival→admission (including deferral waiting), `route_hop` the
/// dispatcher→shard handoff on cluster paths, and `other` the sealed
/// remainder (host scheduling, lock waits, `min_round_seconds`
/// throttling — anything not attributable to a named phase).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Waterfall {
    /// arrival → admission (queue wait + admission deferrals)
    pub queue: f64,
    /// batch prefill the request was resident for
    pub prefill: f64,
    /// SSM backlog catch-up residency
    pub catch_up: f64,
    /// drafting residency
    pub draft: f64,
    /// verify residency
    pub verify: f64,
    /// acceptance/commit residency
    pub accept: f64,
    /// epoch-reshape stalls the request was resident for
    pub reshape: f64,
    /// sealed remainder: latency minus every named component
    pub other: f64,
    /// cluster dispatcher → shard handoff
    pub route_hop: f64,
    /// admission-boundary deferrals suffered before admission
    pub deferred_rounds: usize,
}

impl Waterfall {
    /// Sum of every timed component (including the sealed `other`).
    pub fn total(&self) -> f64 {
        self.queue
            + self.prefill
            + self.catch_up
            + self.draft
            + self.verify
            + self.accept
            + self.reshape
            + self.other
            + self.route_hop
    }

    /// Sum of the named components (everything except `other`).
    pub fn named(&self) -> f64 {
        self.queue
            + self.prefill
            + self.catch_up
            + self.draft
            + self.verify
            + self.accept
            + self.reshape
            + self.route_hop
    }

    /// Accrue one decode round's phase split (the request was resident
    /// for the whole round, so it owns the full phase durations).
    pub fn add_round_split(&mut self, catch_up: f64, draft: f64, verify: f64, accept: f64) {
        self.catch_up += catch_up;
        self.draft += draft;
        self.verify += verify;
        self.accept += accept;
    }

    /// Seal the waterfall against the measured end-to-end latency:
    /// `other` becomes the exact remainder, making
    /// [`Waterfall::total`] `== latency` an identity.  A (tiny)
    /// negative remainder from float accumulation is kept as-is so the
    /// identity stays exact; the tests bound its magnitude.
    pub fn seal(&mut self, latency: f64) {
        self.other = latency - self.named();
    }

    /// Flat JSON object (the `waterfall` key of a finish event).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue", Json::Num(self.queue)),
            ("prefill", Json::Num(self.prefill)),
            ("catch_up", Json::Num(self.catch_up)),
            ("draft", Json::Num(self.draft)),
            ("verify", Json::Num(self.verify)),
            ("accept", Json::Num(self.accept)),
            ("reshape", Json::Num(self.reshape)),
            ("other", Json::Num(self.other)),
            ("route_hop", Json::Num(self.route_hop)),
            ("deferred_rounds", Json::Num(self.deferred_rounds as f64)),
        ])
    }

    /// Parse the `to_json` form back (used by `inspect`).
    pub fn from_json(j: &Json) -> anyhow::Result<Waterfall> {
        let f = |k: &str| -> anyhow::Result<f64> { Ok(j.get(k)?.as_f64()?) };
        Ok(Waterfall {
            queue: f("queue")?,
            prefill: f("prefill")?,
            catch_up: f("catch_up")?,
            draft: f("draft")?,
            verify: f("verify")?,
            accept: f("accept")?,
            reshape: f("reshape")?,
            other: f("other")?,
            route_hop: f("route_hop")?,
            deferred_rounds: j.get("deferred_rounds")?.as_usize()?,
        })
    }

    /// `(label, seconds)` pairs in waterfall order (for reports).
    pub fn components(&self) -> [(&'static str, f64); 9] {
        [
            ("queue", self.queue),
            ("prefill", self.prefill),
            ("catch_up", self.catch_up),
            ("draft", self.draft),
            ("verify", self.verify),
            ("accept", self.accept),
            ("reshape", self.reshape),
            ("route_hop", self.route_hop),
            ("other", self.other),
        ]
    }
}

/// Integer-exact slot accounting for one decode round.
///
/// A round at executing width `width` (the bucket) and executed
/// speculation length `s` (the widest per-row choice on a ragged round)
/// runs `width * (s + 1)` verify slots.  They split into:
///
/// * `committed` — tokens that advanced a sequence (accepted drafts
///   plus the one guaranteed token per live row); goodput;
/// * `rejected` — drafted-but-rejected tokens (`drafted - accepted`,
///   where `drafted = Σ s_i`, `= live*s` uniform); the mispeculation
///   waste the paper's Sec. 3.3 prices;
/// * `padding` — slots executed for empty lanes
///   (`(width - live) * (s + 1)`) plus, on ragged rounds, the intra-row
///   slack of rows that drafted less than `s` (`Σ (s - s_i)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundWaste {
    pub width: usize,
    pub live: usize,
    pub s: usize,
    pub committed: usize,
    pub rejected: usize,
    pub padding: usize,
}

impl RoundWaste {
    /// Split a uniform round's slots.  `accepted` is the summed accepted
    /// draft count across rows (0 for a plain `s == 0` round, where the
    /// split degenerates to `committed = live`, `rejected = 0`).
    ///
    /// Panics (debug) if `live > width` or `accepted > live * s` —
    /// both would mean the caller's bookkeeping is broken.
    pub fn from_round(width: usize, live: usize, s: usize, accepted: usize) -> RoundWaste {
        RoundWaste::from_ragged_round(width, live, s, live * s, accepted)
    }

    /// Split a ragged round's slots: the round executed at the widest
    /// per-row choice `s` but only `drafted = Σ s_i` draft tokens were
    /// requested, so `Σ (s - s_i)` of the live lanes' slots are padding
    /// alongside the vacant-lane slack.  With `drafted == live * s`
    /// this is exactly [`RoundWaste::from_round`].
    pub fn from_ragged_round(
        width: usize,
        live: usize,
        s: usize,
        drafted: usize,
        accepted: usize,
    ) -> RoundWaste {
        debug_assert!(live <= width, "live {live} > width {width}");
        debug_assert!(drafted <= live * s, "drafted {drafted} > live*s {}", live * s);
        debug_assert!(accepted <= drafted, "accepted {accepted} > drafted {drafted}");
        RoundWaste {
            width,
            live,
            s,
            committed: accepted + live,
            rejected: drafted - accepted,
            padding: width * (s + 1) - live - drafted,
        }
    }

    /// Total slots executed: `width * (s + 1)`.
    pub fn slots(&self) -> usize {
        self.width * (self.s + 1)
    }

    /// The tiling identity: `committed + rejected + padding == slots`.
    pub fn tiles(&self) -> bool {
        self.committed + self.rejected + self.padding == self.slots()
    }
}

/// One cell of the batch-size × s waste surface.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WasteCell {
    pub rounds: u64,
    pub committed: u64,
    pub rejected: u64,
    pub padding: u64,
    /// SSM catch-up seconds attributed to rounds in this cell
    pub catch_up_s: f64,
    /// round-cost seconds in this cell
    pub round_s: f64,
}

impl WasteCell {
    pub fn slots(&self) -> u64 {
        self.committed + self.rejected + self.padding
    }

    /// Rejected-draft slots as a fraction of all executed slots.
    pub fn rejected_frac(&self) -> f64 {
        if self.slots() == 0 {
            0.0
        } else {
            self.rejected as f64 / self.slots() as f64
        }
    }

    /// Padding slots as a fraction of all executed slots.
    pub fn padding_frac(&self) -> f64 {
        if self.slots() == 0 {
            0.0
        } else {
            self.padding as f64 / self.slots() as f64
        }
    }
}

/// Aggregation of [`RoundWaste`] splits per `(width bucket, s)` cell —
/// the paper's batch-size × speculation-length waste surface,
/// printable as a text table by `inspect` and serializable for bench
/// sidecars.
#[derive(Debug, Clone, Default)]
pub struct WasteSurface {
    pub cells: BTreeMap<(usize, usize), WasteCell>,
}

impl WasteSurface {
    /// Power-of-two bucket the surface keys widths by (matches the
    /// engine's bucket ladder and `ModelBased`'s cost buckets).
    pub fn bucket_of(width: usize) -> usize {
        width.max(1).next_power_of_two()
    }

    /// Fold one round into the surface.
    pub fn add_round(&mut self, waste: RoundWaste, catch_up_s: f64, round_s: f64) {
        let cell = self
            .cells
            .entry((Self::bucket_of(waste.width), waste.s))
            .or_default();
        cell.rounds += 1;
        cell.committed += waste.committed as u64;
        cell.rejected += waste.rejected as u64;
        cell.padding += waste.padding as u64;
        cell.catch_up_s += catch_up_s;
        cell.round_s += round_s;
    }

    /// Distinct s values present, ascending.
    pub fn s_values(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.cells.keys().map(|&(_, s)| s).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct width buckets present, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.cells.keys().map(|&(b, _)| b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Rejected-waste fraction at `(bucket, s)`, if that cell has data.
    pub fn rejected_frac(&self, bucket: usize, s: usize) -> Option<f64> {
        self.cells.get(&(bucket, s)).map(|c| c.rejected_frac())
    }

    /// Render the surface as an aligned text table: one row per width
    /// bucket, one column per s, each cell `rej%/pad%` of executed
    /// slots (the two waste species).
    pub fn render(&self) -> String {
        let ss = self.s_values();
        let buckets = self.buckets();
        let mut out = String::new();
        out.push_str("waste surface (rejected% / padding% of executed slots)\n");
        out.push_str(&format!("{:>8}", "width"));
        for s in &ss {
            out.push_str(&format!("{:>14}", format!("s={s}")));
        }
        out.push('\n');
        for b in &buckets {
            out.push_str(&format!("{:>8}", b));
            for s in &ss {
                match self.cells.get(&(*b, *s)) {
                    Some(c) => out.push_str(&format!(
                        "{:>14}",
                        format!(
                            "{:.1}/{:.1}",
                            c.rejected_frac() * 100.0,
                            c.padding_frac() * 100.0
                        )
                    )),
                    None => out.push_str(&format!("{:>14}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// JSON form: an array of cell objects (stable order).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.cells
                .iter()
                .map(|(&(bucket, s), c)| {
                    Json::obj(vec![
                        ("bucket", Json::Num(bucket as f64)),
                        ("s", Json::Num(s as f64)),
                        ("rounds", Json::Num(c.rounds as f64)),
                        ("committed", Json::Num(c.committed as f64)),
                        ("rejected", Json::Num(c.rejected as f64)),
                        ("padding", Json::Num(c.padding as f64)),
                        ("catch_up_s", Json::Num(c.catch_up_s)),
                        ("round_s", Json::Num(c.round_s)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waterfall_seal_makes_total_exact() {
        let mut wf = Waterfall {
            queue: 0.25,
            prefill: 0.1,
            ..Default::default()
        };
        wf.add_round_split(0.01, 0.02, 0.05, 0.005);
        wf.add_round_split(0.0, 0.02, 0.05, 0.005);
        let latency = 0.6;
        wf.seal(latency);
        assert_eq!(wf.total(), latency, "seal makes the tiling an identity");
        assert!(wf.other > 0.0);
        // re-sealing against the same latency is a no-op
        let other = wf.other;
        wf.seal(latency);
        assert_eq!(wf.other, other);
    }

    #[test]
    fn waterfall_json_round_trips() {
        let mut wf = Waterfall {
            queue: 1.5,
            prefill: 0.25,
            catch_up: 0.01,
            draft: 0.125,
            verify: 0.5,
            accept: 0.0625,
            reshape: 0.03125,
            route_hop: 0.015625,
            deferred_rounds: 3,
            ..Default::default()
        };
        wf.seal(3.0);
        let back = Waterfall::from_json(&wf.to_json()).unwrap();
        assert_eq!(back, wf);
        assert!(Waterfall::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn round_waste_tiles_integer_exactly() {
        // speculative round: width 8, live 5, s 4, 11 drafts accepted
        let w = RoundWaste::from_round(8, 5, 4, 11);
        assert_eq!(w.committed, 16); // 11 accepted + 5 bonus
        assert_eq!(w.rejected, 9); // 5*4 - 11
        assert_eq!(w.padding, 15); // 3 empty lanes * 5 slots
        assert_eq!(w.slots(), 40);
        assert!(w.tiles());
        // plain round degenerates: no drafts, no rejection
        let p = RoundWaste::from_round(4, 3, 0, 0);
        assert_eq!((p.committed, p.rejected, p.padding), (3, 0, 1));
        assert!(p.tiles());
        // full batch, perfect acceptance: zero waste
        let f = RoundWaste::from_round(4, 4, 2, 8);
        assert_eq!((f.rejected, f.padding), (0, 0));
        assert_eq!(f.committed, f.slots());
        assert!(f.tiles());
    }

    #[test]
    fn ragged_round_waste_generalizes_the_tiling_identity() {
        // width 8, live 6, per-row s = [3, 3, 2, 1, 0, 0] -> s_max 3,
        // drafted = 9; accepted per row [3, 1, 2, 0, 0, 0] = 6
        let w = RoundWaste::from_ragged_round(8, 6, 3, 9, 6);
        assert_eq!(w.committed, 12); // 6 accepted + 6 bonus
        assert_eq!(w.rejected, 3); // 9 drafted - 6 accepted
        // slots 8*4 = 32; padding = 2 vacant lanes * 4 slots, plus the
        // intra-row slack Σ(s_max - s_i) = 0+0+1+2+3+3 = 9
        assert_eq!(w.padding, 17);
        assert_eq!(w.slots(), 32);
        assert!(w.tiles());
        // a uniform per-row vector reduces to from_round exactly
        assert_eq!(
            RoundWaste::from_ragged_round(8, 6, 3, 18, 6),
            RoundWaste::from_round(8, 6, 3, 6)
        );
        // rows finishing mid-round: a row drafts s_i tokens but its
        // budget lets it commit fewer — the driver clips its accepted
        // count, the clipped drafts surface as rejected slots, and the
        // identity still tiles (width 4, live 2, s = [3, 3], one row
        // commits all 3, the finishing row only 1)
        let fin = RoundWaste::from_ragged_round(4, 2, 3, 6, 4);
        assert_eq!((fin.committed, fin.rejected, fin.padding), (6, 2, 8));
        assert!(fin.tiles());
        // all rows finish immediately (s_max > 0 but every draft
        // rejected): the round still tiles with pure bonus commits
        let stall = RoundWaste::from_ragged_round(4, 3, 2, 4, 0);
        assert_eq!((stall.committed, stall.rejected, stall.padding), (3, 4, 5));
        assert!(stall.tiles());
    }

    #[test]
    fn waste_surface_aggregates_and_renders() {
        let mut surf = WasteSurface::default();
        // same acceptance rate at two widths: rejected fraction of
        // *live* slots is equal, but bigger batches burn more absolute
        // rejected tokens per round
        surf.add_round(RoundWaste::from_round(4, 4, 3, 6), 0.0, 0.01);
        surf.add_round(RoundWaste::from_round(32, 32, 3, 48), 0.0, 0.05);
        assert_eq!(surf.buckets(), vec![4, 32]);
        assert_eq!(surf.s_values(), vec![3]);
        let small = surf.cells[&(4, 3)];
        let big = surf.cells[&(32, 3)];
        assert_eq!(small.rejected, 6);
        assert_eq!(big.rejected, 48);
        assert!(big.rejected > small.rejected, "waste grows with batch size");
        let table = surf.render();
        assert!(table.contains("s=3"));
        assert!(table.contains("32"));
        // non-power-of-two widths bucket up
        assert_eq!(WasteSurface::bucket_of(5), 8);
        assert_eq!(WasteSurface::bucket_of(1), 1);
        // json form parses back
        let j = surf.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}
