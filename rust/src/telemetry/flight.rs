//! Always-on flight recorder: a pre-allocated, lock-free ring of
//! compact round/admission/route/finish records that keeps running
//! even with `--telemetry off`, and dumps the last N records as a
//! Chrome trace + JSONL when an anomaly trigger fires.
//!
//! Design constraints (pinned by `rust/tests/zero_alloc.rs` and
//! `rust/tests/flight_recorder.rs`):
//!
//! * **Zero steady-state allocations.**  Every record is a fixed
//!   [`SLOT_WORDS`]`× u64` write into a ring allocated at
//!   construction; recording is a `fetch_add` ticket claim plus plain
//!   atomic stores.  The counting-allocator test still reads exactly 0
//!   over 20 decode rounds with the recorder attached.
//! * **Multi-writer safe.**  The cluster dispatcher and a worker share
//!   a shard's ring (route events land on the chosen shard), so each
//!   slot is a seqlock: the claimed ticket's sequence is published odd
//!   while the payload words are stored, even when complete.  A dump
//!   that races a writer simply skips the torn slot — the recorder is
//!   diagnostic, never authoritative.
//! * **No hot-path IO.**  Triggers ([`FlightTrigger`]) only set a
//!   pending bit; the dump itself happens in [`FlightRecorder::poll`],
//!   which drivers call at round boundaries / loop exits.  An idle
//!   poll is one relaxed load.
//!
//! Trigger table (DESIGN.md §flight-recorder): request shed, SLO-miss
//! burst (≥ [`SLO_BURST`] consecutive missed deadlines), `ModelBased`
//! CUSUM drift flush, KV pool exhaustion, explicit API request
//! ([`FlightRecorder::request_dump`]), and `SIGUSR1`
//! ([`install_sigusr1`]).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::export;
use super::{Event, EventKind};

/// `u64` words per ring slot: seqlock word, timestamp, kind/shard tag,
/// five payload words.
pub const SLOT_WORDS: usize = 8;

/// Default ring capacity (records per recorder).  256 rounds of
/// history is minutes of context at serving rates while keeping the
/// ring at 16 KiB.
pub const DEFAULT_SLOTS: usize = 256;

/// Consecutive SLO-missed finishes that arm the burst trigger.
pub const SLO_BURST: u32 = 4;

/// Compact record kinds (word 2, low byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightKind {
    Round = 0,
    Admission = 1,
    Route = 2,
    Finish = 3,
    KvPool = 4,
    Trigger = 5,
}

impl FlightKind {
    fn from_code(c: u64) -> Option<FlightKind> {
        Some(match c {
            0 => FlightKind::Round,
            1 => FlightKind::Admission,
            2 => FlightKind::Route,
            3 => FlightKind::Finish,
            4 => FlightKind::KvPool,
            5 => FlightKind::Trigger,
            _ => return None,
        })
    }
}

/// Why a dump fired.  Each variant owns one pending bit, so a burst of
/// coincident triggers produces a single dump naming all causes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightTrigger {
    /// a request was shed
    Shed = 0,
    /// [`SLO_BURST`] consecutive finishes missed their deadline
    SloMissBurst = 1,
    /// `ModelBased` flushed its windows on CUSUM drift detection
    DriftFlush = 2,
    /// the KV block pool hit capacity
    KvExhausted = 3,
    /// explicit API request ([`FlightRecorder::request_dump`])
    Manual = 4,
    /// `SIGUSR1`
    Signal = 5,
}

impl FlightTrigger {
    pub fn label(&self) -> &'static str {
        match self {
            FlightTrigger::Shed => "shed",
            FlightTrigger::SloMissBurst => "slo_miss_burst",
            FlightTrigger::DriftFlush => "drift_flush",
            FlightTrigger::KvExhausted => "kv_exhausted",
            FlightTrigger::Manual => "manual",
            FlightTrigger::Signal => "sigusr1",
        }
    }

    fn from_code(c: u64) -> &'static str {
        match c {
            0 => "shed",
            1 => "slo_miss_burst",
            2 => "drift_flush",
            3 => "kv_exhausted",
            4 => "manual",
            5 => "sigusr1",
            _ => "unknown",
        }
    }

    pub fn all() -> [FlightTrigger; 6] {
        [
            FlightTrigger::Shed,
            FlightTrigger::SloMissBurst,
            FlightTrigger::DriftFlush,
            FlightTrigger::KvExhausted,
            FlightTrigger::Manual,
            FlightTrigger::Signal,
        ]
    }
}

/// One decoded ring record (the dump-time form; the ring itself stores
/// only the packed words).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlightRecord {
    pub ticket: u64,
    pub t: f64,
    pub shard: usize,
    pub kind: FlightKind,
    pub payload: [u64; 5],
}

/// `Option<f64>` packed as bits: `None` is NaN (never a real slack or
/// deadline value).
fn opt_bits(v: Option<f64>) -> u64 {
    v.unwrap_or(f64::NAN).to_bits()
}

fn bits_opt(b: u64) -> Option<f64> {
    let v = f64::from_bits(b);
    if v.is_nan() {
        None
    } else {
        Some(v)
    }
}

struct Slot {
    words: [AtomicU64; SLOT_WORDS],
}

impl Slot {
    fn new() -> Slot {
        Slot {
            words: Default::default(),
        }
    }
}

/// `SIGUSR1` lands here (an atomic store is async-signal-safe); the
/// next [`FlightRecorder::poll`] converts it into a `Signal` trigger.
static SIGNAL_DUMP: AtomicBool = AtomicBool::new(false);

/// Install the `SIGUSR1` handler (Linux).  Idempotent; a no-op on
/// non-unix targets.  The handler only flips [`SIGNAL_DUMP`]; the dump
/// itself happens at the next poll point.
pub fn install_sigusr1() {
    #[cfg(target_os = "linux")]
    {
        const SIGUSR1: i32 = 10;
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        extern "C" fn on_sigusr1(_sig: i32) {
            SIGNAL_DUMP.store(true, Ordering::Relaxed);
        }
        unsafe {
            signal(SIGUSR1, on_sigusr1 as usize);
        }
    }
}

/// Mark a dump requested as-if by `SIGUSR1` (tests use this instead of
/// raising a real signal).
pub fn raise_signal_flag() {
    SIGNAL_DUMP.store(true, Ordering::Relaxed);
}

/// The recorder: one ring shared by every shard clone of a
/// [`super::Telemetry`] handle (records carry their shard tag).
pub struct FlightRecorder {
    slots: Box<[Slot]>,
    mask: u64,
    /// next ticket; `ticket & mask` is the slot index
    head: AtomicU64,
    start: Instant,
    /// seconds subtracted from the wall clock (epoch rebase)
    rebase: AtomicU64,
    /// pending trigger causes (bit per [`FlightTrigger`])
    pending: AtomicU32,
    /// consecutive SLO-missed finishes
    slo_streak: AtomicU32,
    /// dump file sequence number
    dump_seq: AtomicU64,
    prefix: String,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "FlightRecorder(slots={}, recorded={}, prefix={:?})",
            self.slots.len(),
            self.recorded(),
            self.prefix
        )
    }
}

impl FlightRecorder {
    /// A recorder with `slots` capacity (rounded up to a power of two,
    /// min 8) dumping to `<prefix>.<seq>.{trace.json,jsonl}`.
    pub fn new(slots: usize, prefix: impl Into<String>) -> Arc<FlightRecorder> {
        let n = slots.max(8).next_power_of_two();
        Arc::new(FlightRecorder {
            slots: (0..n).map(|_| Slot::new()).collect(),
            mask: (n - 1) as u64,
            head: AtomicU64::new(0),
            start: Instant::now(),
            rebase: AtomicU64::new(0.0f64.to_bits()),
            pending: AtomicU32::new(0),
            slo_streak: AtomicU32::new(0),
            dump_seq: AtomicU64::new(0),
            prefix: prefix.into(),
        })
    }

    /// Ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever written (not capped at capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Seconds on the recorder's wall clock (used as the event clock
    /// by `Telemetry::now` when the event sink is off), minus any
    /// epoch rebase.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64() - f64::from_bits(self.rebase.load(Ordering::Relaxed))
    }

    /// Re-zero the clock at the current instant (threaded drivers call
    /// this at their serving epoch so dump timestamps align with the
    /// run, not recorder construction).
    pub fn rebase_to_now(&self) {
        self.rebase
            .store(self.start.elapsed().as_secs_f64().to_bits(), Ordering::Relaxed);
    }

    // ---- recording (hot path: atomics only, no allocation) ----

    #[inline]
    fn write(&self, t: f64, shard: usize, kind: FlightKind, payload: [u64; 5]) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        let busy = ticket.wrapping_mul(2).wrapping_add(1);
        slot.words[0].store(busy, Ordering::Release);
        slot.words[1].store(t.to_bits(), Ordering::Relaxed);
        slot.words[2].store(kind as u64 | ((shard as u64) << 8), Ordering::Relaxed);
        for (i, &w) in payload.iter().enumerate() {
            slot.words[3 + i].store(w, Ordering::Relaxed);
        }
        slot.words[0].store(busy.wrapping_add(1), Ordering::Release);
    }

    /// One decode round.  Counts are clamped to 16 bits each (widths
    /// and spec lengths are tiny), epoch to its own word; kv_blocks and
    /// the round's drafted total (`Σ s_i`, the ragged waste input) share
    /// a word as 32-bit halves.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn record_round(
        &self,
        t: f64,
        shard: usize,
        epoch: usize,
        live: usize,
        width: usize,
        queued: usize,
        s: usize,
        committed: usize,
        accepted: usize,
        drafted: usize,
        kv_blocks: usize,
        dur: f64,
    ) {
        let pack16 = |v: usize| (v.min(0xFFFF)) as u64;
        let pack32 = |v: usize| (v.min(0xFFFF_FFFF)) as u64;
        self.write(
            t,
            shard,
            FlightKind::Round,
            [
                epoch as u64,
                pack16(live) | (pack16(width) << 16) | (pack16(s) << 32) | (pack16(queued) << 48),
                pack32(committed) | (pack32(accepted) << 32),
                pack32(kv_blocks) | (pack32(drafted) << 32),
                dur.to_bits(),
            ],
        );
    }

    /// An admission verdict (`0` admit, `1` defer, `2` shed).
    #[inline]
    pub fn record_admission(
        &self,
        t: f64,
        shard: usize,
        id: u64,
        verdict: &str,
        deadline: Option<f64>,
        slack: Option<f64>,
        deferred: usize,
    ) {
        let code = match verdict {
            "defer" => 1u64,
            "shed" => 2,
            _ => 0,
        };
        self.write(
            t,
            shard,
            FlightKind::Admission,
            [
                id,
                code | ((deferred as u64) << 8),
                opt_bits(deadline),
                opt_bits(slack),
                0,
            ],
        );
    }

    /// A routing decision (recorded on the chosen shard's tag).
    #[inline]
    pub fn record_route(&self, t: f64, chosen: usize, id: u64) {
        self.write(t, chosen, FlightKind::Route, [id, 0, 0, 0, 0]);
    }

    /// A terminal finish/shed.  Feeds the shed and SLO-miss-burst
    /// triggers.
    #[inline]
    pub fn record_finish(
        &self,
        t: f64,
        shard: usize,
        id: u64,
        tokens: usize,
        shed: bool,
        slack: Option<f64>,
    ) {
        self.write(
            t,
            shard,
            FlightKind::Finish,
            [
                id,
                (tokens as u64) | ((shed as u64) << 63),
                opt_bits(slack),
                0,
                0,
            ],
        );
        if shed {
            self.trigger(t, shard, FlightTrigger::Shed);
            return;
        }
        match slack {
            Some(sl) if sl < 0.0 => {
                let streak = self.slo_streak.fetch_add(1, Ordering::Relaxed) + 1;
                if streak == SLO_BURST {
                    self.trigger(t, shard, FlightTrigger::SloMissBurst);
                }
            }
            Some(_) => self.slo_streak.store(0, Ordering::Relaxed),
            None => {}
        }
    }

    /// A KV pool sample; exhaustion arms the `KvExhausted` trigger.
    #[inline]
    pub fn record_kv_pool(&self, t: f64, shard: usize, in_use: usize, capacity: usize, frag: f64) {
        self.record_kv_pool_prefix(t, shard, in_use, capacity, frag, 0, 0);
    }

    /// [`record_kv_pool`](Self::record_kv_pool) carrying the pool's
    /// cumulative prefix-sharing counters in the record's spare payload
    /// slots (0 when the prefix cache is off).
    #[inline]
    pub fn record_kv_pool_prefix(
        &self,
        t: f64,
        shard: usize,
        in_use: usize,
        capacity: usize,
        frag: f64,
        prefix_hits: u64,
        prefill_saved: u64,
    ) {
        self.write(
            t,
            shard,
            FlightKind::KvPool,
            [
                in_use as u64,
                capacity as u64,
                frag.to_bits(),
                prefix_hits,
                prefill_saved,
            ],
        );
        if capacity > 0 && in_use >= capacity {
            self.trigger(t, shard, FlightTrigger::KvExhausted);
        }
    }

    /// Record a trigger marker and arm its pending bit.  Recording is
    /// allocation-free; the dump happens at the next [`poll`].
    ///
    /// [`poll`]: FlightRecorder::poll
    #[inline]
    pub fn trigger(&self, t: f64, shard: usize, cause: FlightTrigger) {
        self.write(t, shard, FlightKind::Trigger, [cause as u64, 0, 0, 0, 0]);
        self.pending
            .fetch_or(1 << (cause as u32), Ordering::Release);
    }

    /// Explicitly request a dump (the API variant of `SIGUSR1`).
    pub fn request_dump(&self, t: f64) {
        self.trigger(t, 0, FlightTrigger::Manual);
    }

    // ---- dumping (cold path) ----

    /// True when a trigger is armed (one relaxed load).
    #[inline]
    pub fn dump_pending(&self) -> bool {
        self.pending.load(Ordering::Relaxed) != 0 || SIGNAL_DUMP.load(Ordering::Relaxed)
    }

    /// Dump if a trigger is armed; returns the files written (empty
    /// when idle).  IO failures are reported to stderr and swallowed —
    /// the recorder is diagnostic and must never take the server down.
    pub fn poll(&self) -> Vec<PathBuf> {
        if !self.dump_pending() {
            return Vec::new();
        }
        if SIGNAL_DUMP.swap(false, Ordering::Relaxed) {
            self.trigger(self.elapsed(), 0, FlightTrigger::Signal);
        }
        let causes = self.pending.swap(0, Ordering::AcqRel);
        if causes == 0 {
            return Vec::new();
        }
        match self.dump(causes) {
            Ok(paths) => paths,
            Err(e) => {
                eprintln!("flight recorder: dump failed: {e}");
                Vec::new()
            }
        }
    }

    /// Force a dump regardless of pending triggers (the `inspect
    /// --flight` / shutdown path).
    pub fn dump_now(&self) -> anyhow::Result<Vec<PathBuf>> {
        let causes = self.pending.swap(0, Ordering::AcqRel);
        self.dump(causes | (1 << (FlightTrigger::Manual as u32)))
    }

    fn dump(&self, causes: u32) -> anyhow::Result<Vec<PathBuf>> {
        let seq = self.dump_seq.fetch_add(1, Ordering::Relaxed);
        let records = self.snapshot();
        let events = records_to_events(&records);
        let cause_labels: Vec<&'static str> = FlightTrigger::all()
            .into_iter()
            .filter(|c| causes & (1 << (*c as u32)) != 0)
            .map(|c| c.label())
            .collect();
        let prefix = format!("{}.{seq}", self.prefix);
        if let Some(dir) = std::path::Path::new(&prefix).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut written = Vec::new();
        let trace = PathBuf::from(format!("{prefix}.trace.json"));
        export::chrome_trace(&events).write_file(&trace)?;
        written.push(trace);
        let jsonl = PathBuf::from(format!("{prefix}.jsonl"));
        let mut body = format!(
            "{{\"ev\":\"flight_dump\",\"t\":{},\"causes\":[{}],\"records\":{}}}\n",
            self.elapsed(),
            cause_labels
                .iter()
                .map(|c| format!("\"{c}\""))
                .collect::<Vec<_>>()
                .join(","),
            records.len(),
        );
        body.push_str(&export::events_jsonl(&events));
        std::fs::write(&jsonl, body)?;
        written.push(jsonl);
        Ok(written)
    }

    /// Seqlock-validated copy of the ring, oldest record first.  Slots
    /// torn by a concurrent writer are skipped.
    pub fn snapshot(&self) -> Vec<FlightRecord> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let s1 = slot.words[0].load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue; // never written, or mid-write
            }
            let mut words = [0u64; SLOT_WORDS - 1];
            for (i, w) in words.iter_mut().enumerate() {
                *w = slot.words[1 + i].load(Ordering::Relaxed);
            }
            let s2 = slot.words[0].load(Ordering::Acquire);
            if s1 != s2 {
                continue; // torn by a wrapping writer
            }
            let Some(kind) = FlightKind::from_code(words[1] & 0xFF) else {
                continue;
            };
            out.push(FlightRecord {
                ticket: s2 / 2 - 1,
                t: f64::from_bits(words[0]),
                shard: (words[1] >> 8) as usize,
                kind,
                payload: [words[2], words[3], words[4], words[5], words[6]],
            });
        }
        out.sort_unstable_by_key(|r| r.ticket);
        out
    }
}

/// Decode ring records into the standard [`Event`] schema so the
/// existing exporters render flight dumps (accepted-count vectors and
/// router score vectors are not kept in the compact records and decode
/// as empty).
pub fn records_to_events(records: &[FlightRecord]) -> Vec<Event> {
    records
        .iter()
        .map(|r| {
            let p = r.payload;
            let kind = match r.kind {
                FlightKind::Round => EventKind::Round {
                    epoch: p[0] as usize,
                    live: (p[1] & 0xFFFF) as usize,
                    width: ((p[1] >> 16) & 0xFFFF) as usize,
                    queued: ((p[1] >> 48) & 0xFFFF) as usize,
                    s: ((p[1] >> 32) & 0xFFFF) as usize,
                    drafted: (p[3] >> 32) as usize,
                    committed: (p[2] & 0xFFFF_FFFF) as usize,
                    accepted: Vec::new(),
                    // the ring stores the drafted total, not the per-row
                    // vector (fixed-width slots); empty = not recoverable
                    s_rows: Vec::new(),
                    kv_blocks: (p[3] & 0xFFFF_FFFF) as usize,
                },
                FlightKind::Admission => EventKind::Admission {
                    id: p[0],
                    verdict: match p[1] & 0xFF {
                        1 => "defer",
                        2 => "shed",
                        _ => "admit",
                    },
                    deadline: bits_opt(p[2]),
                    predicted_slack: bits_opt(p[3]),
                    deferred: (p[1] >> 8) as usize,
                },
                FlightKind::Route => EventKind::Route {
                    id: p[0],
                    scores: Vec::new(),
                },
                FlightKind::Finish => EventKind::Finish {
                    id: p[0],
                    tokens: (p[1] & !(1 << 63)) as usize,
                    shed: p[1] >> 63 == 1,
                    slack: bits_opt(p[2]),
                    waterfall: None,
                },
                FlightKind::KvPool => EventKind::KvPool {
                    in_use: p[0] as usize,
                    capacity: p[1] as usize,
                    frag: f64::from_bits(p[2]),
                    prefix_hits: p[3],
                    prefill_saved: p[4],
                },
                FlightKind::Trigger => EventKind::Trigger {
                    cause: FlightTrigger::from_code(p[0]),
                },
            };
            let dur = match r.kind {
                FlightKind::Round => f64::from_bits(p[4]),
                _ => 0.0,
            };
            Event {
                t: r.t,
                dur,
                shard: r.shard,
                kind,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_decodes_without_loss_below_capacity() {
        let fr = FlightRecorder::new(64, "/tmp/specbatch_flight_unit");
        fr.record_round(1.0, 0, 3, 5, 8, 2, 4, 16, 11, 14, 40, 0.025);
        fr.record_admission(1.1, 0, 42, "defer", Some(2.0), Some(-0.25), 3);
        fr.record_route(1.2, 2, 42);
        fr.record_finish(1.3, 0, 42, 128, false, Some(0.5));
        fr.record_kv_pool(1.4, 1, 10, 32, 0.125);
        let recs = fr.snapshot();
        assert_eq!(recs.len(), 5);
        assert_eq!(recs[0].kind, FlightKind::Round);
        let evs = records_to_events(&recs);
        match &evs[0].kind {
            EventKind::Round {
                live,
                width,
                s,
                queued,
                drafted,
                committed,
                kv_blocks,
                ..
            } => {
                assert_eq!((*live, *width, *s, *queued), (5, 8, 4, 2));
                assert_eq!((*committed, *kv_blocks), (16, 40));
                assert_eq!(*drafted, 14, "drafted rides the kv word's high half");
                assert!((evs[0].dur - 0.025).abs() < 1e-12);
            }
            other => panic!("expected round, got {other:?}"),
        }
        match &evs[1].kind {
            EventKind::Admission {
                id,
                verdict,
                deadline,
                predicted_slack,
                deferred,
            } => {
                assert_eq!(*id, 42);
                assert_eq!(*verdict, "defer");
                assert_eq!(*deadline, Some(2.0));
                assert_eq!(*predicted_slack, Some(-0.25));
                assert_eq!(*deferred, 3);
            }
            other => panic!("expected admission, got {other:?}"),
        }
        assert_eq!(evs[2].shard, 2, "route lands on the chosen shard");
        match &evs[3].kind {
            EventKind::Finish {
                tokens,
                shed,
                slack,
                ..
            } => {
                assert_eq!(*tokens, 128);
                assert!(!*shed);
                assert_eq!(*slack, Some(0.5));
            }
            other => panic!("expected finish, got {other:?}"),
        }
        assert!(!fr.dump_pending(), "nothing anomalous yet");
    }

    #[test]
    fn ring_wraps_keeping_the_newest_records() {
        let fr = FlightRecorder::new(8, "/tmp/specbatch_flight_unit");
        for i in 0..20u64 {
            fr.record_route(i as f64, 0, i);
        }
        assert_eq!(fr.recorded(), 20);
        let recs = fr.snapshot();
        assert_eq!(recs.len(), 8, "ring keeps capacity records");
        let ids: Vec<u64> = recs.iter().map(|r| r.payload[0]).collect();
        assert_eq!(ids, (12..20).collect::<Vec<u64>>(), "newest survive, in order");
    }

    #[test]
    fn triggers_arm_and_poll_dumps_once() {
        let dir = std::env::temp_dir().join("specbatch_flight_trig");
        let _ = std::fs::remove_dir_all(&dir);
        let prefix = dir.join("fl").to_string_lossy().into_owned();
        let fr = FlightRecorder::new(32, prefix);
        assert!(fr.poll().is_empty(), "idle poll writes nothing");
        // a shed arms the trigger
        fr.record_finish(0.5, 0, 7, 0, true, None);
        assert!(fr.dump_pending());
        let written = fr.poll();
        assert_eq!(written.len(), 2, "trace.json + jsonl");
        for p in &written {
            assert!(p.exists(), "{p:?} missing");
        }
        assert!(fr.poll().is_empty(), "pending cleared after dump");
        // the dump body names its cause and parses line-by-line
        let jsonl = written
            .iter()
            .find(|p| p.extension().is_some_and(|e| e == "jsonl"))
            .unwrap();
        let body = std::fs::read_to_string(jsonl).unwrap();
        let first = body.lines().next().unwrap();
        assert!(first.contains("flight_dump") && first.contains("shed"));
        for line in body.lines() {
            crate::util::json::Json::parse(line).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slo_miss_burst_fires_after_a_streak_and_resets_on_a_hit() {
        let fr = FlightRecorder::new(32, "/tmp/specbatch_flight_unit");
        for i in 0..SLO_BURST - 1 {
            fr.record_finish(i as f64, 0, i as u64, 8, false, Some(-0.1));
        }
        assert!(!fr.dump_pending(), "below the burst threshold");
        fr.record_finish(9.0, 0, 99, 8, false, Some(0.3)); // hit resets
        for i in 0..SLO_BURST - 1 {
            fr.record_finish(10.0 + i as f64, 0, 100 + i as u64, 8, false, Some(-0.1));
        }
        assert!(!fr.dump_pending(), "streak reset by the met deadline");
        fr.record_finish(20.0, 0, 200, 8, false, Some(-0.1));
        assert!(fr.dump_pending(), "burst threshold reached");
    }

    #[test]
    fn kv_exhaustion_and_signal_flag_arm_dumps() {
        let dir = std::env::temp_dir().join("specbatch_flight_kv");
        let _ = std::fs::remove_dir_all(&dir);
        let prefix = dir.join("fl").to_string_lossy().into_owned();
        let fr = FlightRecorder::new(16, prefix);
        fr.record_kv_pool(1.0, 0, 31, 32, 0.0);
        assert!(!fr.dump_pending());
        fr.record_kv_pool(2.0, 0, 32, 32, 0.0);
        assert!(fr.dump_pending(), "exhaustion arms the trigger");
        assert_eq!(fr.poll().len(), 2);
        // the signal path: flag → poll converts it into a dump
        raise_signal_flag();
        assert!(fr.dump_pending());
        let written = fr.poll();
        assert_eq!(written.len(), 2);
        let body = std::fs::read_to_string(&written[1]).unwrap();
        assert!(body.lines().next().unwrap().contains("sigusr1"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn elapsed_rebases_to_zero() {
        let fr = FlightRecorder::new(8, "/tmp/specbatch_flight_unit");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(fr.elapsed() > 0.0);
        fr.rebase_to_now();
        assert!(fr.elapsed() < 0.005, "clock re-zeroed at the rebase point");
    }
}
