//! Observability: a zero-overhead-when-disabled telemetry layer threaded
//! through the whole serving stack.
//!
//! The paper is a *characterization study* — its central claim (the
//! optimal speculation length depends on the batch size) came from
//! instrumenting every round's draft/verify/accept breakdown.  This
//! module gives the reproduction the same visibility:
//!
//! * a [`Telemetry`] handle — a cheap `Arc` clone whose disabled variant
//!   ([`Telemetry::disabled`]) is a `None` inner: every emit method is a
//!   branch on an `Option` and returns without allocating, so the decode
//!   hot path pays nothing when observability is off (pinned by the
//!   `micro_hotpath` bench and the determinism tests);
//! * a **metric registry** of named counters, gauges and log-bucketed
//!   fixed-size [`Histogram`]s (no per-sample allocation), active in
//!   `summary` and `trace` modes;
//! * a **structured event sink** ([`Event`]) with span-style round
//!   events — per-round `draft`/`verify`/`accept`/`reshape`/`admission`
//!   phases, per-row accepted counts, the chosen `s`, policy-fit
//!   snapshots, KV-pool utilization, admission defer/shed decisions with
//!   predicted deadline slack, and per-shard routing decisions with the
//!   router's score vector — active in `trace` mode only;
//! * **exporters** ([`export`]): Chrome `trace_event` JSON (Perfetto /
//!   `chrome://tracing`), Prometheus text exposition, and JSONL dumps;
//! * a **bench trajectory** ([`bench`]): `BENCH_<name>.json` emission so
//!   CI uploads a machine-readable perf history (ROADMAP item 5).
//!
//! Determinism contract: telemetry consumes **zero PRNG draws** and
//! never branches the serving logic — with the handle disabled, DES and
//! server outputs are bit-identical to a build without the calls
//! (`rust/tests/telemetry.rs` pins this across seeds).  The DES emits in
//! virtual time, the threaded path in wall time ([`Telemetry::now`]),
//! through the same event schema.
//!
//! Mode selection: `--telemetry off|summary|trace` on the CLI, or the
//! `SPECBATCH_TELEMETRY` environment variable (the CI matrix axis).

pub mod attrib;
pub mod bench;
pub mod export;
pub mod flight;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

use attrib::Waterfall;
use flight::FlightRecorder;

/// How much the telemetry layer records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TelemetryMode {
    /// no registry, no events; the handle is a no-op (`Disabled`)
    #[default]
    Off,
    /// metric registry only (counters/gauges/histograms)
    Summary,
    /// registry + the structured event sink (exportable as a Chrome
    /// trace / JSONL dump)
    Trace,
}

impl TelemetryMode {
    pub fn parse(s: &str) -> anyhow::Result<TelemetryMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "disabled" => Ok(TelemetryMode::Off),
            "summary" | "metrics" => Ok(TelemetryMode::Summary),
            "trace" | "full" => Ok(TelemetryMode::Trace),
            other => anyhow::bail!(
                "unknown telemetry mode {other:?} (expected off|summary|trace)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TelemetryMode::Off => "off",
            TelemetryMode::Summary => "summary",
            TelemetryMode::Trace => "trace",
        }
    }

    pub fn all() -> [TelemetryMode; 3] {
        [
            TelemetryMode::Off,
            TelemetryMode::Summary,
            TelemetryMode::Trace,
        ]
    }

    /// `SPECBATCH_TELEMETRY` override, panicking on an invalid value so a
    /// typo in a CI matrix axis fails loudly instead of silently running
    /// without the telemetry leg (mirrors `KvLayout::from_env`).
    pub fn env_override() -> Option<TelemetryMode> {
        let v = std::env::var("SPECBATCH_TELEMETRY").ok()?;
        Some(TelemetryMode::parse(&v).unwrap_or_else(|e| panic!("SPECBATCH_TELEMETRY: {e}")))
    }

    /// The mode used when a config does not pin one: the env override
    /// when set, else `Off`.
    pub fn default_mode() -> TelemetryMode {
        TelemetryMode::env_override().unwrap_or(TelemetryMode::Off)
    }
}

/// A phase inside one decode round (the span names of the Chrome trace's
/// per-shard phase track).  Phases are emitted back-to-back inside their
/// round span, so they nest and never overlap per shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// batch prefill of freshly admitted rows (both models)
    Prefill,
    /// SSM backlog re-ingest before a speculative round
    CatchUp,
    /// SSM drafting (`s` single-token forwards)
    Draft,
    /// LLM verify call over `s + 1` positions
    Verify,
    /// host-side acceptance + commit
    Accept,
    /// epoch reshape: carried-row KV transfer into a larger bucket
    Reshape,
    /// admission-control planning at the round boundary
    Admission,
}

impl PhaseKind {
    pub fn label(&self) -> &'static str {
        match self {
            PhaseKind::Prefill => "prefill",
            PhaseKind::CatchUp => "ssm_catch_up",
            PhaseKind::Draft => "draft",
            PhaseKind::Verify => "verify",
            PhaseKind::Accept => "accept",
            PhaseKind::Reshape => "reshape",
            PhaseKind::Admission => "admission",
        }
    }
}

/// One structured telemetry event.  `t` is seconds on the run's clock
/// (virtual time in the DES, [`Telemetry::now`] wall time on the
/// threaded path); `dur` is 0 for instant events.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub t: f64,
    pub dur: f64,
    pub shard: usize,
    pub kind: EventKind,
}

/// The event payloads (the schema table lives in DESIGN.md §telemetry).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// one decode round: the span the phase events nest inside
    Round {
        epoch: usize,
        live: usize,
        /// executing width (the bucket): `width - live` lanes are
        /// padding slack — with `s` this makes the round's waste split
        /// ([`attrib::RoundWaste`]) recoverable from the event alone
        width: usize,
        queued: usize,
        /// executed (widest) speculation length
        s: usize,
        /// draft tokens requested over the live rows (`Σ s_i`)
        drafted: usize,
        committed: usize,
        /// per-row accepted draft counts (empty for plain rounds)
        accepted: Vec<u32>,
        /// per-row drafted lengths of a ragged round (empty = uniform)
        s_rows: Vec<u32>,
        kv_blocks: usize,
    },
    /// a sub-span of the enclosing round
    Phase { phase: PhaseKind },
    /// an admission-control verdict on one queued request
    Admission {
        id: u64,
        /// "admit" | "defer" | "shed"
        verdict: &'static str,
        deadline: Option<f64>,
        /// deadline minus the predicted finish at the current load
        /// (None: no deadline, or the policy's fit is still cold)
        predicted_slack: Option<f64>,
        /// round boundaries the request had been deferred at so far
        deferred: usize,
    },
    /// terminal event of a request: served (`shed: false`) or shed
    Finish {
        id: u64,
        tokens: usize,
        shed: bool,
        /// deadline minus the actual finish time (negative = SLO miss)
        slack: Option<f64>,
        /// per-request latency decomposition (None when the driver
        /// does not attribute, e.g. compact flight-recorder decodes)
        waterfall: Option<Waterfall>,
    },
    /// a routing decision: `Event::shard` is the chosen shard,
    /// `scores` the router's per-shard score vector (lower = better)
    Route { id: u64, scores: Vec<f64> },
    /// a policy-fit snapshot (`SpeculationPolicy::snapshot`)
    PolicyFit { snapshot: Json },
    /// KV block-pool utilization sample (cumulative prefix-sharing
    /// counters ride along: 0 when the prefix cache is off)
    KvPool {
        in_use: usize,
        capacity: usize,
        frag: f64,
        prefix_hits: u64,
        prefill_saved: u64,
    },
    /// a flight-recorder anomaly trigger marker
    /// ([`flight::FlightTrigger`] label)
    Trigger { cause: &'static str },
}

impl Event {
    /// Flat JSON form (the JSONL exporter's line format).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("t", Json::Num(self.t)),
            ("dur", Json::Num(self.dur)),
            ("shard", Json::Num(self.shard as f64)),
        ];
        let opt = |v: Option<f64>| v.map_or(Json::Null, Json::Num);
        match &self.kind {
            EventKind::Round {
                epoch,
                live,
                width,
                queued,
                s,
                drafted,
                committed,
                accepted,
                s_rows,
                kv_blocks,
            } => {
                pairs.push(("ev", Json::Str("round".into())));
                pairs.push(("epoch", Json::Num(*epoch as f64)));
                pairs.push(("live", Json::Num(*live as f64)));
                pairs.push(("width", Json::Num(*width as f64)));
                pairs.push(("queued", Json::Num(*queued as f64)));
                pairs.push(("s", Json::Num(*s as f64)));
                pairs.push(("drafted", Json::Num(*drafted as f64)));
                pairs.push(("committed", Json::Num(*committed as f64)));
                pairs.push((
                    "accepted",
                    Json::Arr(accepted.iter().map(|&a| Json::Num(a as f64)).collect()),
                ));
                pairs.push((
                    "s_rows",
                    Json::Arr(s_rows.iter().map(|&si| Json::Num(si as f64)).collect()),
                ));
                pairs.push(("kv_blocks", Json::Num(*kv_blocks as f64)));
            }
            EventKind::Phase { phase } => {
                pairs.push(("ev", Json::Str("phase".into())));
                pairs.push(("phase", Json::Str(phase.label().into())));
            }
            EventKind::Admission {
                id,
                verdict,
                deadline,
                predicted_slack,
                deferred,
            } => {
                pairs.push(("ev", Json::Str("admission".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("verdict", Json::Str((*verdict).into())));
                pairs.push(("deadline", opt(*deadline)));
                pairs.push(("predicted_slack", opt(*predicted_slack)));
                pairs.push(("deferred", Json::Num(*deferred as f64)));
            }
            EventKind::Finish {
                id,
                tokens,
                shed,
                slack,
                waterfall,
            } => {
                pairs.push(("ev", Json::Str("finish".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("tokens", Json::Num(*tokens as f64)));
                pairs.push(("shed", Json::Bool(*shed)));
                pairs.push(("slack", opt(*slack)));
                pairs.push((
                    "waterfall",
                    waterfall.map_or(Json::Null, |w| w.to_json()),
                ));
            }
            EventKind::Route { id, scores } => {
                pairs.push(("ev", Json::Str("route".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("scores", Json::from_f64_slice(scores)));
            }
            EventKind::PolicyFit { snapshot } => {
                pairs.push(("ev", Json::Str("policy_fit".into())));
                pairs.push(("snapshot", snapshot.clone()));
            }
            EventKind::KvPool {
                in_use,
                capacity,
                frag,
                prefix_hits,
                prefill_saved,
            } => {
                pairs.push(("ev", Json::Str("kv_pool".into())));
                pairs.push(("in_use", Json::Num(*in_use as f64)));
                pairs.push(("capacity", Json::Num(*capacity as f64)));
                pairs.push(("frag", Json::Num(*frag)));
                pairs.push(("prefix_hits", Json::Num(*prefix_hits as f64)));
                pairs.push(("prefill_saved", Json::Num(*prefill_saved as f64)));
            }
            EventKind::Trigger { cause } => {
                pairs.push(("ev", Json::Str("trigger".into())));
                pairs.push(("cause", Json::Str((*cause).into())));
            }
        }
        Json::obj(pairs)
    }
}

/// Number of log2 buckets a [`Histogram`] keeps.  Bucket `i` covers
/// `[2^(i-30), 2^(i-29))` seconds: index 0 sits at ~1 ns, index 63 at
/// ~2^33 s — far wider than any latency this system sees.
pub const HIST_BUCKETS: usize = 64;
const HIST_EXP_OFFSET: i32 = 30;

/// Fixed-size log-bucketed histogram: recording is an array increment,
/// never an allocation.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    fn bucket_index(v: f64) -> usize {
        if !(v.is_finite() && v > 0.0) {
            return 0;
        }
        (v.log2().floor() as i32 + HIST_EXP_OFFSET).clamp(0, HIST_BUCKETS as i32 - 1) as usize
    }

    /// Upper edge of bucket `i` in seconds.
    pub fn bucket_edge(i: usize) -> f64 {
        (2.0f64).powi(i as i32 - HIST_EXP_OFFSET + 1)
    }

    pub fn record(&mut self, v: f64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile estimate (`q` in [0, 1]) from the bucket counts: the
    /// upper edge of the bucket where the cumulative count crosses
    /// `q * count`, clamped to the observed min/max.  Empty → 0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_edge(i).clamp(
                    self.min.min(self.max),
                    self.max.max(self.min),
                );
            }
        }
        self.max
    }
}

/// The named-metric registry (one per [`Telemetry`] handle, shared by
/// every shard clone).
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
    pub histograms: BTreeMap<&'static str, Histogram>,
}

struct Inner {
    mode: TelemetryMode,
    start: Instant,
    /// seconds subtracted from `start.elapsed()` by [`Telemetry::now`]
    /// (f64 bits): the epoch rebase that aligns threaded-path event
    /// clocks to the serving epoch instead of handle construction
    rebase: AtomicU64,
    metrics: Mutex<Registry>,
    events: Mutex<Vec<Event>>,
}

/// The telemetry handle.  Cloning is an `Arc` bump; the disabled handle
/// holds no allocation at all and every emit method returns after one
/// `Option` branch.  `shard` tags every event this clone emits
/// ([`Telemetry::for_shard`]).
///
/// Independently of `inner`, a handle may carry a
/// [`flight::FlightRecorder`]: emitters feed it *before* the
/// `inner`-is-`None` early return, so the flight ring keeps recording
/// with `--telemetry off` (hot paths gate span bookkeeping on
/// [`Telemetry::active`] rather than [`Telemetry::enabled`] for the
/// same reason).
#[derive(Clone)]
pub struct Telemetry {
    shard: usize,
    inner: Option<Arc<Inner>>,
    flight: Option<Arc<FlightRecorder>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Telemetry(mode={}, shard={}, flight={})",
            self.mode().label(),
            self.shard,
            self.flight.is_some()
        )
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::disabled()
    }
}

impl Telemetry {
    /// The no-op handle: no inner state, zero hot-path cost.
    pub fn disabled() -> Telemetry {
        Telemetry {
            shard: 0,
            inner: None,
            flight: None,
        }
    }

    /// A live handle at `mode` (`Off` returns the disabled handle).
    pub fn new(mode: TelemetryMode) -> Telemetry {
        if mode == TelemetryMode::Off {
            return Telemetry::disabled();
        }
        Telemetry {
            shard: 0,
            inner: Some(Arc::new(Inner {
                mode,
                start: Instant::now(),
                rebase: AtomicU64::new(0.0f64.to_bits()),
                metrics: Mutex::new(Registry::default()),
                events: Mutex::new(Vec::new()),
            })),
            flight: None,
        }
    }

    /// Attach an always-on flight recorder.  Works on any handle,
    /// including the disabled one — that is the whole point: the ring
    /// records even at `--telemetry off`.
    pub fn with_flight(mut self, flight: Arc<FlightRecorder>) -> Telemetry {
        self.flight = Some(flight);
        self
    }

    /// The attached flight recorder, if any.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Dump the flight ring if a trigger is armed (one relaxed load
    /// when idle / no recorder).  Returns the files written.
    pub fn flight_poll(&self) -> Vec<std::path::PathBuf> {
        self.flight.as_ref().map_or_else(Vec::new, |f| f.poll())
    }

    /// Arm the flight recorder's `DriftFlush` trigger: drivers call this
    /// when the policy's drift detector fires, so the rounds surrounding
    /// the changepoint get dumped.  No-op without a ring.
    pub fn drift_flush(&self, t: f64) {
        if let Some(f) = &self.flight {
            f.trigger(t, self.shard, flight::FlightTrigger::DriftFlush);
        }
    }

    /// Handle from the ambient default ([`TelemetryMode::default_mode`]).
    pub fn from_env() -> Telemetry {
        Telemetry::new(TelemetryMode::default_mode())
    }

    pub fn mode(&self) -> TelemetryMode {
        self.inner
            .as_ref()
            .map_or(TelemetryMode::Off, |i| i.mode)
    }

    /// True when the registry records (summary or trace).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// True when *any* sink records — registry/events or the flight
    /// ring.  Hot paths that compute span timestamps gate on this so
    /// the flight recorder keeps seeing rounds at `--telemetry off`.
    #[inline]
    pub fn active(&self) -> bool {
        self.inner.is_some() || self.flight.is_some()
    }

    /// True when the event sink records (trace only).
    #[inline]
    pub fn tracing(&self) -> bool {
        self.inner
            .as_ref()
            .is_some_and(|i| i.mode == TelemetryMode::Trace)
    }

    /// A clone whose events carry `shard` (same registry + sink +
    /// flight ring).
    pub fn for_shard(&self, shard: usize) -> Telemetry {
        Telemetry {
            shard,
            inner: self.inner.clone(),
            flight: self.flight.clone(),
        }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Seconds since the handle was created (minus any epoch rebase) —
    /// the threaded path's event clock.  Falls back to the flight
    /// recorder's clock when only the ring is attached; 0 when fully
    /// disabled.
    pub fn now(&self) -> f64 {
        if let Some(i) = &self.inner {
            return i.start.elapsed().as_secs_f64()
                - f64::from_bits(i.rebase.load(Ordering::Relaxed));
        }
        self.flight.as_ref().map_or(0.0, |f| f.elapsed())
    }

    /// Re-zero the event clock at the current instant.  Threaded
    /// drivers call this at their serving epoch so every shard clone —
    /// they share one `Inner` — reports timestamps on a common,
    /// run-relative clock and per-shard Chrome tracks align.  No-op on
    /// the DES (virtual time) and on a fully disabled handle.
    pub fn rebase_to_now(&self) {
        if let Some(i) = &self.inner {
            i.rebase.store(
                i.start.elapsed().as_secs_f64().to_bits(),
                Ordering::Relaxed,
            );
        }
        if let Some(f) = &self.flight {
            f.rebase_to_now();
        }
    }

    // ---- metric registry ----

    #[inline]
    pub fn counter(&self, name: &'static str, n: u64) {
        let Some(inner) = &self.inner else { return };
        let mut m = inner.metrics.lock().expect("registry lock");
        *m.counters.entry(name).or_insert(0) += n;
    }

    #[inline]
    pub fn gauge(&self, name: &'static str, v: f64) {
        let Some(inner) = &self.inner else { return };
        let mut m = inner.metrics.lock().expect("registry lock");
        m.gauges.insert(name, v);
    }

    /// Record one sample into a named histogram.
    #[inline]
    pub fn observe(&self, name: &'static str, v: f64) {
        let Some(inner) = &self.inner else { return };
        let mut m = inner.metrics.lock().expect("registry lock");
        m.histograms.entry(name).or_default().record(v);
    }

    /// Snapshot of the registry (cloned out under the lock).
    pub fn registry(&self) -> Registry {
        self.inner.as_ref().map_or_else(Registry::default, |i| {
            i.metrics.lock().expect("registry lock").clone()
        })
    }

    // ---- event sink ----

    #[inline]
    fn push(&self, t: f64, dur: f64, kind: EventKind) {
        let Some(inner) = &self.inner else { return };
        if inner.mode != TelemetryMode::Trace {
            return;
        }
        inner.events.lock().expect("event sink lock").push(Event {
            t,
            dur,
            shard: self.shard,
            kind,
        });
    }

    /// One decode round (span).  Also feeds the registry: round count,
    /// committed/accepted totals, the waste split (rejected drafts /
    /// padding slack, [`attrib::RoundWaste`]) and the round-seconds
    /// histogram — so `summary` mode aggregates without storing
    /// events.  `width` is the executing bucket (`>= live`); `s` is the
    /// executed (widest) speculation length; `s_rows` carries the
    /// per-live-row drafted lengths of a ragged round (empty = uniform,
    /// every row drafted `s`).
    #[allow(clippy::too_many_arguments)]
    pub fn round(
        &self,
        t: f64,
        dur: f64,
        epoch: usize,
        live: usize,
        width: usize,
        queued: usize,
        s: usize,
        committed: usize,
        accepted: &[u32],
        s_rows: &[u32],
        kv_blocks: usize,
    ) {
        let accepted_total: u64 = accepted.iter().map(|&a| a as u64).sum();
        // draft tokens requested this round: Σ s_i on ragged rounds,
        // live * s on uniform ones (identical when s_rows broadcasts s)
        let drafted: u64 = if s_rows.is_empty() {
            (live * s) as u64
        } else {
            s_rows.iter().map(|&si| si as u64).sum()
        };
        if let Some(fl) = &self.flight {
            fl.record_round(
                t,
                self.shard,
                epoch,
                live,
                width,
                queued,
                s,
                committed,
                accepted_total as usize,
                drafted as usize,
                kv_blocks,
                dur,
            );
        }
        if self.inner.is_none() {
            return;
        }
        self.counter("specbatch_rounds_total", 1);
        self.counter("specbatch_tokens_committed_total", committed as u64);
        self.counter("specbatch_drafts_accepted_total", accepted_total);
        self.counter(
            "specbatch_tokens_rejected_total",
            drafted - accepted_total.min(drafted),
        );
        // padding generalizes to vacant-lane slack + intra-row
        // raggedness: committed + rejected + padding == width * (s + 1)
        self.counter(
            "specbatch_slots_padding_total",
            (width * (s + 1)) as u64 - ((live as u64 + drafted).min((width * (s + 1)) as u64)),
        );
        self.observe("specbatch_round_seconds", dur);
        self.gauge("specbatch_live_rows", live as f64);
        self.gauge("specbatch_queue_depth", queued as f64);
        self.push(
            t,
            dur,
            EventKind::Round {
                epoch,
                live,
                width,
                queued,
                s,
                drafted: drafted as usize,
                committed,
                accepted: accepted.to_vec(),
                s_rows: s_rows.to_vec(),
                kv_blocks,
            },
        );
    }

    /// A phase span inside the current round.
    pub fn phase(&self, t: f64, dur: f64, phase: PhaseKind) {
        if self.inner.is_none() {
            return;
        }
        self.observe(
            match phase {
                PhaseKind::Prefill => "specbatch_prefill_seconds",
                PhaseKind::CatchUp => "specbatch_ssm_catch_up_seconds",
                PhaseKind::Draft => "specbatch_draft_seconds",
                PhaseKind::Verify => "specbatch_verify_seconds",
                PhaseKind::Accept => "specbatch_accept_seconds",
                PhaseKind::Reshape => "specbatch_reshape_seconds",
                PhaseKind::Admission => "specbatch_admission_seconds",
            },
            dur,
        );
        self.push(t, dur, EventKind::Phase { phase });
    }

    /// An admission verdict on one queued request.
    pub fn admission(
        &self,
        t: f64,
        id: u64,
        verdict: &'static str,
        deadline: Option<f64>,
        predicted_slack: Option<f64>,
        deferred: usize,
    ) {
        if let Some(fl) = &self.flight {
            fl.record_admission(t, self.shard, id, verdict, deadline, predicted_slack, deferred);
        }
        if self.inner.is_none() {
            return;
        }
        self.counter(
            match verdict {
                "defer" => "specbatch_admission_defer_total",
                "shed" => "specbatch_admission_shed_total",
                _ => "specbatch_admission_admit_total",
            },
            1,
        );
        self.push(
            t,
            0.0,
            EventKind::Admission {
                id,
                verdict,
                deadline,
                predicted_slack,
                deferred,
            },
        );
    }

    /// Terminal event of a request (exactly one per admitted request:
    /// the conservation property the tests pin).
    pub fn finish(&self, t: f64, id: u64, tokens: usize, shed: bool, slack: Option<f64>) {
        self.finish_attrib(t, id, tokens, shed, slack, None);
    }

    /// [`Telemetry::finish`] carrying the request's sealed latency
    /// [`Waterfall`] — the attribution form every serving driver emits.
    pub fn finish_attrib(
        &self,
        t: f64,
        id: u64,
        tokens: usize,
        shed: bool,
        slack: Option<f64>,
        waterfall: Option<Waterfall>,
    ) {
        if let Some(fl) = &self.flight {
            fl.record_finish(t, self.shard, id, tokens, shed, slack);
        }
        if self.inner.is_none() {
            return;
        }
        self.counter(
            if shed {
                "specbatch_requests_shed_total"
            } else {
                "specbatch_requests_finished_total"
            },
            1,
        );
        if let Some(sl) = slack {
            self.observe("specbatch_deadline_slack_seconds", sl.max(0.0));
            if sl < 0.0 {
                self.counter("specbatch_slo_missed_total", 1);
            }
        }
        if let Some(wf) = &waterfall {
            self.observe("specbatch_queue_wait_seconds", wf.queue);
            self.observe("specbatch_decode_residency_seconds", wf.draft + wf.verify + wf.accept);
        }
        self.push(t, 0.0, EventKind::Finish {
            id,
            tokens,
            shed,
            slack,
            waterfall,
        });
    }

    /// A routing decision: this handle's shard tag is ignored; the event
    /// is tagged with the *chosen* shard so it lands on that track.
    pub fn route(&self, t: f64, id: u64, chosen: usize, scores: &[f64]) {
        if let Some(fl) = &self.flight {
            fl.record_route(t, chosen, id);
        }
        let Some(inner) = &self.inner else { return };
        self.counter("specbatch_routed_total", 1);
        if inner.mode != TelemetryMode::Trace {
            return;
        }
        inner.events.lock().expect("event sink lock").push(Event {
            t,
            dur: 0.0,
            shard: chosen,
            kind: EventKind::Route {
                id,
                scores: scores.to_vec(),
            },
        });
    }

    /// A policy-fit snapshot (skipped when the policy reports none).
    pub fn policy_fit(&self, t: f64, snapshot: Option<Json>) {
        if !self.tracing() {
            return;
        }
        if let Some(snapshot) = snapshot {
            self.push(t, 0.0, EventKind::PolicyFit { snapshot });
        }
    }

    /// A KV block-pool utilization sample.
    pub fn kv_pool(&self, t: f64, in_use: usize, capacity: usize, frag: f64) {
        self.kv_pool_prefix(t, in_use, capacity, frag, 0, 0);
    }

    /// A KV block-pool sample carrying the pool's cumulative
    /// prefix-sharing counters (hits and prefill tokens saved so far) —
    /// the prefix-cache-aware variant of [`Telemetry::kv_pool`].
    pub fn kv_pool_prefix(
        &self,
        t: f64,
        in_use: usize,
        capacity: usize,
        frag: f64,
        prefix_hits: u64,
        prefill_saved: u64,
    ) {
        if let Some(fl) = &self.flight {
            fl.record_kv_pool_prefix(
                t,
                self.shard,
                in_use,
                capacity,
                frag,
                prefix_hits,
                prefill_saved,
            );
        }
        if self.inner.is_none() {
            return;
        }
        self.gauge("specbatch_kv_blocks_in_use", in_use as f64);
        self.gauge("specbatch_kv_blocks_capacity", capacity as f64);
        self.gauge("specbatch_kv_internal_frag", frag);
        if prefix_hits > 0 || prefill_saved > 0 {
            self.gauge("specbatch_prefix_hits", prefix_hits as f64);
            self.gauge("specbatch_prefix_prefill_saved", prefill_saved as f64);
        }
        self.push(
            t,
            0.0,
            EventKind::KvPool {
                in_use,
                capacity,
                frag,
                prefix_hits,
                prefill_saved,
            },
        );
    }

    /// Snapshot of the event sink (cloned out under the lock).
    pub fn events(&self) -> Vec<Event> {
        self.inner.as_ref().map_or_else(Vec::new, |i| {
            i.events.lock().expect("event sink lock").clone()
        })
    }

    /// Drain the event sink.
    pub fn take_events(&self) -> Vec<Event> {
        self.inner.as_ref().map_or_else(Vec::new, |i| {
            std::mem::take(&mut *i.events.lock().expect("event sink lock"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_and_labels_round_trip() {
        for m in TelemetryMode::all() {
            assert_eq!(TelemetryMode::parse(m.label()).unwrap(), m);
        }
        assert_eq!(
            TelemetryMode::parse("TRACE").unwrap(),
            TelemetryMode::Trace
        );
        assert!(TelemetryMode::parse("loud").is_err());
    }

    #[test]
    fn disabled_handle_records_nothing() {
        let t = Telemetry::disabled();
        assert!(!t.enabled());
        assert!(!t.tracing());
        t.counter("c", 3);
        t.gauge("g", 1.0);
        t.observe("h", 0.5);
        t.round(0.0, 0.1, 1, 2, 2, 0, 3, 4, &[1, 2], &[], 0);
        t.finish(0.0, 7, 16, false, None);
        assert!(t.registry().counters.is_empty());
        assert!(t.events().is_empty());
        assert_eq!(t.now(), 0.0);
        assert_eq!(Telemetry::new(TelemetryMode::Off).mode(), TelemetryMode::Off);
    }

    #[test]
    fn summary_mode_fills_the_registry_but_not_the_sink() {
        let t = Telemetry::new(TelemetryMode::Summary);
        assert!(t.enabled());
        assert!(!t.tracing());
        t.round(0.0, 0.01, 1, 4, 8, 2, 3, 8, &[2, 1, 3, 2], &[], 12);
        t.finish(0.1, 1, 32, false, Some(0.5));
        t.finish(0.2, 2, 0, true, Some(-0.1));
        let reg = t.registry();
        assert_eq!(reg.counters["specbatch_rounds_total"], 1);
        assert_eq!(reg.counters["specbatch_tokens_committed_total"], 8);
        assert_eq!(reg.counters["specbatch_drafts_accepted_total"], 8);
        // waste split: live=4, s=3, accepted=8 → rejected 4; width 8
        // → padding (8-4)*(3+1) = 16
        assert_eq!(reg.counters["specbatch_tokens_rejected_total"], 4);
        assert_eq!(reg.counters["specbatch_slots_padding_total"], 16);
        // a ragged round generalizes the split: drafted Σs_i = 6 over
        // rows that drafted (3,1,2,0) under an executed s of 3, so
        // rejected = 6 - 5 = 1 and padding picks up the intra-row
        // raggedness too: 8*(3+1) - 4 - 6 = 22
        t.round(0.02, 0.01, 1, 4, 8, 2, 3, 9, &[3, 1, 1, 0], &[3, 1, 2, 0], 12);
        let reg = t.registry();
        assert_eq!(reg.counters["specbatch_tokens_rejected_total"], 4 + 1);
        assert_eq!(reg.counters["specbatch_slots_padding_total"], 16 + 22);
        assert_eq!(reg.counters["specbatch_requests_finished_total"], 1);
        assert_eq!(reg.counters["specbatch_requests_shed_total"], 1);
        assert_eq!(reg.counters["specbatch_slo_missed_total"], 1);
        assert_eq!(reg.gauges["specbatch_live_rows"], 4.0);
        assert_eq!(reg.histograms["specbatch_round_seconds"].count, 1);
        assert!(t.events().is_empty(), "summary mode stores no events");
    }

    #[test]
    fn trace_mode_records_shard_tagged_events() {
        let t = Telemetry::new(TelemetryMode::Trace);
        let s1 = t.for_shard(1);
        t.round(1.0, 0.5, 1, 2, 2, 0, 3, 4, &[1, 2], &[], 0);
        s1.phase(1.0, 0.2, PhaseKind::Draft);
        s1.route(1.2, 9, 3, &[0.5, 0.1, 0.9, 0.0]);
        let ev = t.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].shard, 0);
        assert_eq!(ev[1].shard, 1);
        // route events land on the chosen shard's track
        assert_eq!(ev[2].shard, 3);
        match &ev[2].kind {
            EventKind::Route { id, scores } => {
                assert_eq!(*id, 9);
                assert_eq!(scores.len(), 4);
            }
            other => panic!("expected route, got {other:?}"),
        }
        // drain empties the sink
        assert_eq!(t.take_events().len(), 3);
        assert!(t.events().is_empty());
    }

    #[test]
    fn histogram_buckets_without_allocation_and_quantiles_bound() {
        let mut h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram quantile is 0");
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1ms..100ms
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        // log2 buckets: estimates are within one power of two
        assert!(p50 >= 0.025 && p50 <= 0.1, "p50 {p50}");
        assert!(p99 >= 0.05 && p99 <= 0.128, "p99 {p99}");
        assert!(p50 <= p99);
        // degenerate values neither panic nor skew the sum
        h.record(0.0);
        h.record(f64::NAN);
        assert_eq!(h.count, 102);
        // single-sample histogram pins the value via min/max clamping
        let mut one = Histogram::default();
        one.record(0.007);
        assert!((one.quantile(0.5) - 0.007).abs() < 1e-12);
        assert!((one.quantile(0.99) - 0.007).abs() < 1e-12);
    }

    #[test]
    fn event_json_is_flat_and_typed() {
        let e = Event {
            t: 1.5,
            dur: 0.25,
            shard: 2,
            kind: EventKind::Admission {
                id: 42,
                verdict: "defer",
                deadline: Some(3.0),
                predicted_slack: Some(-0.2),
                deferred: 4,
            },
        };
        let j = e.to_json();
        assert_eq!(j.get("ev").unwrap().as_str().unwrap(), "admission");
        assert_eq!(j.get("id").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(j.get("verdict").unwrap().as_str().unwrap(), "defer");
        assert!((j.get("predicted_slack").unwrap().as_f64().unwrap() + 0.2).abs() < 1e-12);
        let none = Event {
            t: 0.0,
            dur: 0.0,
            shard: 0,
            kind: EventKind::Finish {
                id: 1,
                tokens: 8,
                shed: false,
                slack: None,
                waterfall: None,
            },
        };
        assert!(matches!(none.to_json().get("slack").unwrap(), Json::Null));
        assert!(matches!(
            none.to_json().get("waterfall").unwrap(),
            Json::Null
        ));
        // a sealed waterfall rides along on finish events
        let mut wf = Waterfall {
            queue: 0.5,
            verify: 0.25,
            ..Default::default()
        };
        wf.seal(1.0);
        let with = Event {
            t: 1.0,
            dur: 0.0,
            shard: 0,
            kind: EventKind::Finish {
                id: 2,
                tokens: 8,
                shed: false,
                slack: None,
                waterfall: Some(wf),
            },
        };
        let j = with.to_json();
        let parsed = Waterfall::from_json(j.get("waterfall").unwrap()).unwrap();
        assert_eq!(parsed, wf);
        assert_eq!(parsed.total(), 1.0);
    }

    #[test]
    fn flight_only_handle_is_active_but_records_no_registry() {
        let fr = flight::FlightRecorder::new(16, "/tmp/specbatch_tel_flight_unit");
        let t = Telemetry::disabled().with_flight(fr.clone());
        assert!(!t.enabled(), "registry/event sink stay off");
        assert!(t.active(), "but the handle is active for the ring");
        t.round(0.5, 0.01, 1, 2, 4, 0, 3, 7, &[2, 3], &[], 6);
        t.finish(0.6, 9, 16, false, Some(0.1));
        t.for_shard(1).route(0.7, 9, 1, &[0.1, 0.2]);
        assert!(t.registry().counters.is_empty());
        assert!(t.events().is_empty());
        assert_eq!(fr.recorded(), 3, "the ring saw every emit");
        assert!(t.now() >= 0.0, "clock falls back to the flight recorder");
        // rebase works without an inner
        t.rebase_to_now();
        assert!(t.now() < 0.005);
    }
}
