//! Machine-readable bench trajectory: `BENCH_<name>.json` emission.
//!
//! ROADMAP item 5 asks for a perf *trajectory* — a number CI can chart
//! per commit, not a table that scrolls out of the log.  Every figure
//! bench and the DES drivers funnel their run through [`bench_report`] +
//! [`write_bench`], producing one JSON per bench with the serving
//! metrics that matter (per-token latency distribution, throughput,
//! rounds/s, acceptance, SLO attainment), a config fingerprint so runs
//! are only compared like-for-like, and the git SHA so the trajectory
//! is attributable.
//!
//! The latency/throughput fields are derived from the *same*
//! `LatencyRecorder`/`RoundEvent` data the experiment reports, so the
//! JSON always agrees with the run's `ExperimentOutcome` (pinned by
//! `rust/tests/telemetry.rs`).

use crate::metrics::{LatencyRecorder, RoundEvent};
use crate::util::json::Json;
use crate::util::stats::percentile_sorted;

/// FNV-1a 64-bit over the compact serialization: a stable fingerprint a
/// CI chart can group runs by (same fingerprint ⇒ comparable numbers).
pub fn config_fingerprint(config: &Json) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in config.compact().as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// Best-effort commit id: `SPECBATCH_GIT_SHA` / `GITHUB_SHA` env (what CI
/// sets), else `.git/HEAD` resolved by hand (no subprocess — the offline
/// container has no guarantee of a `git` binary on PATH), else "unknown".
pub fn git_sha() -> String {
    for var in ["SPECBATCH_GIT_SHA", "GITHUB_SHA"] {
        if let Ok(v) = std::env::var(var) {
            let v = v.trim().to_string();
            if !v.is_empty() {
                return v;
            }
        }
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..5 {
        let head = dir.join(".git/HEAD");
        if let Ok(text) = std::fs::read_to_string(&head) {
            let text = text.trim();
            if let Some(r) = text.strip_prefix("ref: ") {
                if let Ok(sha) = std::fs::read_to_string(dir.join(".git").join(r.trim())) {
                    return sha.trim().to_string();
                }
                return "unknown".into();
            }
            return text.to_string();
        }
        if !dir.pop() {
            break;
        }
    }
    "unknown".into()
}

/// Build the `BENCH_<name>.json` document from a finished run.
///
/// * per-token latency: each completed request's end-to-end latency over
///   its generated tokens — `mean` is exactly
///   [`LatencyRecorder::mean_per_token_latency`], `p50`/`p99` are the
///   request-level distribution;
/// * `tokens_per_s`: [`LatencyRecorder::throughput_tokens_per_s`];
/// * `rounds_per_s` / `accepted_per_round`: from the round timeline
///   (each `RoundEvent.t` is the round's *end*, so the span starts at
///   `first.t - first.round_cost`);
/// * `slo`: the attainment accounting, sheds included;
/// * `config` + `config_fingerprint` + `git_sha`: provenance.
pub fn bench_report(
    name: &str,
    recorder: &LatencyRecorder,
    rounds: &[RoundEvent],
    config: Json,
) -> Json {
    let mut per_token: Vec<f64> = recorder
        .completed()
        .map(|r| r.latency() / r.tokens.max(1) as f64)
        .collect();
    per_token.sort_by(f64::total_cmp);
    let (span, accepted_mean) = match (rounds.first(), rounds.last()) {
        (Some(first), Some(last)) => (
            (last.t - first.t) + first.round_cost,
            rounds.iter().map(|r| r.accepted as f64).sum::<f64>() / rounds.len() as f64,
        ),
        _ => (0.0, 0.0),
    };
    let rounds_per_s = if span > 0.0 {
        rounds.len() as f64 / span
    } else {
        0.0
    };
    let slo = recorder.slo_attainment();
    let (ttft_p50, _, ttft_p99) = recorder.ttft_percentiles();
    Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("requests", Json::Num(recorder.len() as f64)),
        ("completed", Json::Num(slo.completed as f64)),
        ("shed", Json::Num(slo.shed as f64)),
        (
            "per_token_latency_s",
            Json::obj(vec![
                ("mean", Json::Num(recorder.mean_per_token_latency())),
                ("p50", Json::Num(percentile_sorted(&per_token, 50.0))),
                ("p99", Json::Num(percentile_sorted(&per_token, 99.0))),
            ]),
        ),
        (
            "ttft_s",
            Json::obj(vec![
                ("mean", Json::Num(recorder.mean_ttft())),
                ("p50", Json::Num(ttft_p50)),
                ("p99", Json::Num(ttft_p99)),
            ]),
        ),
        (
            "tokens_per_s",
            Json::Num(recorder.throughput_tokens_per_s()),
        ),
        ("rounds", Json::Num(rounds.len() as f64)),
        ("rounds_per_s", Json::Num(rounds_per_s)),
        ("accepted_per_round", Json::Num(accepted_mean)),
        (
            "slo",
            Json::obj(vec![
                ("deadlined", Json::Num(slo.deadlined as f64)),
                ("met", Json::Num(slo.met as f64)),
                ("missed", Json::Num(slo.missed as f64)),
                ("attainment", Json::Num(slo.attainment())),
            ]),
        ),
        ("config_fingerprint", Json::Str(config_fingerprint(&config))),
        ("config", config),
        ("git_sha", Json::Str(git_sha())),
    ])
}

/// Build a `BENCH_<name>.json` document for a bench with no request
/// recorder (latency grids, acceptance curves, microbenchmarks): the
/// caller supplies its headline numbers as a `metrics` object and gets
/// the same provenance fields (`config_fingerprint`, `config`,
/// `git_sha`) as [`bench_report`], so the CI trajectory can chart every
/// bench uniformly.
pub fn bench_report_custom(name: &str, metrics: Json, config: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("metrics", metrics),
        ("config_fingerprint", Json::Str(config_fingerprint(&config))),
        ("config", config),
        ("git_sha", Json::Str(git_sha())),
    ])
}

/// Directory `BENCH_*.json` files land in: `SPECBATCH_RESULTS_DIR` when
/// set (the benches point it at `rust/results/`), else `results/`.
pub fn bench_dir() -> std::path::PathBuf {
    std::env::var("SPECBATCH_RESULTS_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// Write `BENCH_<name>.json`; returns the path.
pub fn write_bench(name: &str, report: &Json) -> anyhow::Result<std::path::PathBuf> {
    let dir = bench_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{name}.json"));
    report.write_file(&path)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RequestRecord;

    fn rec(id: u64, sent: f64, fin: f64, tokens: usize) -> RequestRecord {
        RequestRecord {
            id,
            sent_at: sent,
            started_at: sent,
            finished_at: fin,
            tokens,
            batch: 1,
            spec_len: 3,
            shard: 0,
            deadline: None,
            deferred_rounds: 0,
            shed: false,
            first_token_at: Some(sent),
        }
    }

    #[test]
    fn fingerprint_is_stable_and_order_insensitive() {
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Num(2.0))]);
        let b = Json::obj(vec![("y", Json::Num(2.0)), ("x", Json::Num(1.0))]);
        // BTreeMap keys sort, so key order in the source cannot split runs
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        let c = Json::obj(vec![("x", Json::Num(1.5)), ("y", Json::Num(2.0))]);
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
        assert_eq!(config_fingerprint(&a).len(), 16);
    }

    #[test]
    fn report_fields_match_the_recorder() {
        let mut r = LatencyRecorder::new();
        r.push(rec(1, 0.0, 1.0, 10)); // 0.1 s/token
        r.push(rec(2, 1.0, 4.0, 10)); // 0.3 s/token
        let rounds = vec![
            RoundEvent {
                t: 0.5,
                epoch: 1,
                live: 2,
                width: 2,
                queued: 0,
                s: 3,
                drafted: 6,
                accepted: 4,
                round_cost: 0.5,
                kv_blocks: 0,
            },
            RoundEvent {
                t: 1.0,
                epoch: 1,
                live: 2,
                width: 2,
                queued: 0,
                s: 3,
                drafted: 6,
                accepted: 2,
                round_cost: 0.5,
                kv_blocks: 0,
            },
        ];
        let cfg = Json::obj(vec![("max_batch", Json::Num(8.0))]);
        let doc = bench_report("unit", &r, &rounds, cfg);
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "unit");
        assert_eq!(doc.get("requests").unwrap().as_usize().unwrap(), 2);
        let ptl = doc.get("per_token_latency_s").unwrap();
        assert!(
            (ptl.get("mean").unwrap().as_f64().unwrap()
                - r.mean_per_token_latency())
            .abs()
                < 1e-12
        );
        assert!((ptl.get("p50").unwrap().as_f64().unwrap() - 0.2).abs() < 1e-12);
        assert!(
            (doc.get("tokens_per_s").unwrap().as_f64().unwrap()
                - r.throughput_tokens_per_s())
            .abs()
                < 1e-12
        );
        // 2 rounds over span (1.0 - 0.5) + 0.5 = 1.0 s
        assert!((doc.get("rounds_per_s").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        assert!(
            (doc.get("accepted_per_round").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12
        );
        assert!(!doc
            .get("config_fingerprint")
            .unwrap()
            .as_str()
            .unwrap()
            .is_empty());
        assert!(!doc.get("git_sha").unwrap().as_str().unwrap().is_empty());
        // empty round list degrades to zeros, not NaN/panic
        let empty = bench_report("unit", &r, &[], Json::Null);
        assert_eq!(empty.get("rounds_per_s").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn write_bench_lands_in_the_results_dir() {
        let dir = std::env::temp_dir().join("specbatch_bench_test");
        let _ = std::fs::remove_dir_all(&dir);
        // env-var mutation is racy across test threads; call the
        // internals directly against an explicit dir instead.
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        let doc = bench_report("unit", &LatencyRecorder::new(), &[], Json::Null);
        doc.write_file(&path).unwrap();
        let back = Json::parse_file(&path).unwrap();
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "unit");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
