//! Prefix-sharing index over the paged KV pool: a trie keyed on
//! prompt-token-ID content at block granularity, mapping to refcounted
//! block ids in a [`BlockManager`] pool.
//!
//! The cache is a *second owner* of KV blocks.  A row that prefills a
//! prompt donates its block chain to the index ([`PrefixCache::insert`]
//! retains every newly registered block); a later request whose prompt
//! starts with the same tokens maps those blocks read-only
//! ([`PrefixCache::lookup`] bumps refcounts and hands back the chain) and
//! the engine prefills only the unmatched suffix.  Shared tail blocks
//! that are about to be written are replaced copy-on-write
//! ([`PrefixCache::cow_tail`]).  Unreferenced-by-rows chains stay in the
//! trie as an LRU reserve and are reclaimed only under pool pressure
//! ([`PrefixCache::evict_until_free`]); after [`PrefixCache::evict_all`]
//! plus releasing every row-held reference, the pool's free list returns
//! to capacity (the leak invariant `rust/tests/prefix_sharing.rs` pins).
//!
//! ## Trie shape
//!
//! Every node owns exactly one block and the token content it caches:
//! a *full* node keys `block_size` tokens and may have children; a
//! *partial* node keys `1..block_size` tokens (a partially filled tail
//! block) and is always a leaf.  Lookup greedily walks full-block
//! matches and may finish on one partial leaf whose whole key matches;
//! the matched token count is therefore `16*k + t` with `t` the partial
//! key length (0 when the walk ended on a full node).  Sibling partial
//! leaves of different lengths may coexist (inserted by prompts that
//! diverge inside one block); lookup picks the longest matching one,
//! which is unique because exact keys are deduplicated on insert.

use crate::kvcache::BlockManager;
use crate::util::json::Json;
use anyhow::Result;

/// Counters of one prefix index (cumulative over its lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixStats {
    /// admission-time lookups performed
    pub lookups: u64,
    /// lookups that matched >= 1 block
    pub prefix_hits: u64,
    /// lookups that matched nothing
    pub prefix_misses: u64,
    /// prompt tokens whose prefill was skipped via mapped blocks
    pub prefill_tokens_saved: u64,
    /// blocks newly registered into the trie by inserts
    pub inserted_blocks: u64,
    /// shared tail blocks replaced copy-on-write
    pub cow_copies: u64,
    /// cached blocks reclaimed under pool pressure
    pub evictions: u64,
    /// blocks the trie currently holds a reference to
    pub cached_blocks: usize,
}

impl PrefixStats {
    /// Fraction of lookups that hit (0 when no lookups ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.lookups as f64
        }
    }

    /// Merge counters from another index (per-shard caches roll up into
    /// one cluster-level line).
    pub fn merged(&self, other: &PrefixStats) -> PrefixStats {
        PrefixStats {
            lookups: self.lookups + other.lookups,
            prefix_hits: self.prefix_hits + other.prefix_hits,
            prefix_misses: self.prefix_misses + other.prefix_misses,
            prefill_tokens_saved: self.prefill_tokens_saved + other.prefill_tokens_saved,
            inserted_blocks: self.inserted_blocks + other.inserted_blocks,
            cow_copies: self.cow_copies + other.cow_copies,
            evictions: self.evictions + other.evictions,
            cached_blocks: self.cached_blocks + other.cached_blocks,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lookups", Json::Num(self.lookups as f64)),
            ("prefix_hits", Json::Num(self.prefix_hits as f64)),
            ("prefix_misses", Json::Num(self.prefix_misses as f64)),
            (
                "prefill_tokens_saved",
                Json::Num(self.prefill_tokens_saved as f64),
            ),
            ("inserted_blocks", Json::Num(self.inserted_blocks as f64)),
            ("cow_copies", Json::Num(self.cow_copies as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("cached_blocks", Json::Num(self.cached_blocks as f64)),
            ("hit_rate", Json::Num(self.hit_rate())),
        ])
    }
}

/// The result of a successful lookup: a retained block chain covering
/// the first `tokens` prompt tokens.  The caller owns one reference on
/// every id in `blocks` and must hand them to a row table (whose sync
/// releases them at retirement) or release them itself.
#[derive(Debug)]
pub struct PrefixMatch {
    pub blocks: Vec<u32>,
    pub tokens: usize,
}

/// One trie node: the block it owns, the token content that block
/// caches, and its LRU stamp.  Slab-allocated; `live == false` slots
/// are on the free list for reuse.
#[derive(Debug)]
struct Node {
    key: Vec<i32>,
    block: u32,
    parent: usize,
    children: Vec<usize>,
    stamp: u64,
    live: bool,
}

const ROOT: usize = 0;

/// The prefix index.  It does not own the pool — every mutating call
/// takes the [`BlockManager`] so retain/release/alloc stay in the one
/// accounting domain the leak tests audit.
#[derive(Debug)]
pub struct PrefixCache {
    block_size: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    /// logical LRU clock, bumped once per lookup/insert
    clock: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(block_size: usize) -> PrefixCache {
        assert!(block_size > 0, "prefix cache needs a positive block size");
        PrefixCache {
            block_size,
            nodes: vec![Node {
                key: Vec::new(),
                block: u32::MAX,
                parent: usize::MAX,
                children: Vec::new(),
                stamp: 0,
                live: true,
            }],
            free_nodes: Vec::new(),
            clock: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks currently registered in the trie.
    pub fn cached_blocks(&self) -> usize {
        self.stats.cached_blocks
    }

    /// Longest cached prefix of `tokens` (pass the prompt already capped
    /// to the mappable span — the engine caps at `prompt_len - 1` so at
    /// least one suffix token remains to prefill).  On a hit, every
    /// returned block is retained on behalf of the caller.
    pub fn lookup(&mut self, tokens: &[i32], mgr: &mut BlockManager) -> Option<PrefixMatch> {
        self.clock += 1;
        self.stats.lookups += 1;
        let bs = self.block_size;
        let mut cur = ROOT;
        let mut consumed = 0usize;
        let mut blocks: Vec<u32> = Vec::new();
        loop {
            let rest = &tokens[consumed..];
            if rest.is_empty() {
                break;
            }
            // prefer the full-block child (unique per key: dedup on insert)
            let full = self.nodes[cur].children.iter().copied().find(|&c| {
                let k = &self.nodes[c].key;
                k.len() == bs && rest.len() >= bs && rest[..bs] == k[..]
            });
            if let Some(c) = full {
                self.nodes[c].stamp = self.clock;
                blocks.push(self.nodes[c].block);
                consumed += bs;
                cur = c;
                continue;
            }
            // else the longest partial leaf whose whole key matches
            let mut best_node = None;
            let mut best_len = 0usize;
            for &c in &self.nodes[cur].children {
                let k = &self.nodes[c].key;
                if k.len() < bs
                    && k.len() <= rest.len()
                    && k.len() > best_len
                    && rest[..k.len()] == k[..]
                {
                    best_node = Some(c);
                    best_len = k.len();
                }
            }
            if let Some(c) = best_node {
                self.nodes[c].stamp = self.clock;
                blocks.push(self.nodes[c].block);
                consumed += best_len;
            }
            break;
        }
        if consumed == 0 {
            self.stats.prefix_misses += 1;
            return None;
        }
        for &b in &blocks {
            mgr.retain(b);
        }
        self.stats.prefix_hits += 1;
        self.stats.prefill_tokens_saved += consumed as u64;
        Some(PrefixMatch {
            blocks,
            tokens: consumed,
        })
    }

    /// Register a prompt span whose KV lives in `chain` (the row's block
    /// table, covering at least `blocks_for(tokens.len())` blocks, block
    /// `i` caching `tokens[i*16 .. (i+1)*16]`).  Newly registered blocks
    /// are retained (the trie becomes a co-owner); spans already cached
    /// are deduplicated and only LRU-touched.  A partial tail chunk
    /// becomes a leaf; nothing nests under it.
    pub fn insert(&mut self, tokens: &[i32], chain: &[u32], mgr: &mut BlockManager) {
        if tokens.is_empty() {
            return;
        }
        self.clock += 1;
        let bs = self.block_size;
        let n_blocks = tokens.len().div_ceil(bs);
        debug_assert!(
            chain.len() >= n_blocks,
            "prefix insert: chain of {} blocks cannot cover {} tokens",
            chain.len(),
            tokens.len()
        );
        let mut cur = ROOT;
        for b in 0..n_blocks.min(chain.len()) {
            let chunk = &tokens[b * bs..((b + 1) * bs).min(tokens.len())];
            let existing = self.nodes[cur]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c].key == chunk);
            if let Some(c) = existing {
                self.nodes[c].stamp = self.clock;
                if chunk.len() < bs {
                    return; // partial leaf already cached
                }
                cur = c;
                continue;
            }
            let node = self.new_node(chunk, chain[b], cur);
            mgr.retain(chain[b]);
            self.stats.inserted_blocks += 1;
            self.stats.cached_blocks += 1;
            if chunk.len() < bs {
                return; // partial tails are leaves
            }
            cur = node;
        }
    }

    /// Copy-on-write replacement of a shared, partially filled tail
    /// block: allocate a fresh block (evicting LRU cache entries if the
    /// pool is exhausted), release the caller's reference on `shared`,
    /// and return the fresh id.  On the stub backend the "memcpy" of the
    /// tail's prefix portion is pure bookkeeping — KV content is virtual
    /// — but the refcount choreography is exactly the real one.
    pub fn cow_tail(&mut self, mgr: &mut BlockManager, shared: u32) -> Result<u32> {
        let fresh = loop {
            match mgr.alloc() {
                Ok(id) => break id,
                Err(e) => {
                    if !self.evict_lru(mgr) {
                        return Err(e.context("prefix COW: pool exhausted and cache empty"));
                    }
                }
            }
        };
        mgr.release(shared);
        self.stats.cow_copies += 1;
        Ok(fresh)
    }

    /// Reclaim the least-recently-used leaf (release its block, unlink
    /// it).  Interior nodes become evictable once their subtree is gone.
    /// Returns false when the trie is empty.
    pub fn evict_lru(&mut self, mgr: &mut BlockManager) -> bool {
        let mut best: Option<(usize, u64)> = None;
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            if n.live && n.children.is_empty() {
                let older = match best {
                    None => true,
                    Some((_, s)) => n.stamp < s,
                };
                if older {
                    best = Some((i, n.stamp));
                }
            }
        }
        let Some((i, _)) = best else {
            return false;
        };
        let block = self.nodes[i].block;
        let parent = self.nodes[i].parent;
        self.nodes[parent].children.retain(|&c| c != i);
        self.nodes[i].live = false;
        self.nodes[i].key.clear();
        self.nodes[i].children.clear();
        self.free_nodes.push(i);
        mgr.release(block);
        self.stats.evictions += 1;
        self.stats.cached_blocks -= 1;
        true
    }

    /// Evict LRU entries until the pool has at least `need` free blocks
    /// (the only reclamation trigger: pool pressure).  Returns false if
    /// the cache drained before reaching the target.
    pub fn evict_until_free(&mut self, mgr: &mut BlockManager, need: usize) -> bool {
        while mgr.free_blocks() < need {
            if !self.evict_lru(mgr) {
                return false;
            }
        }
        true
    }

    /// Drop every cached chain (shutdown / leak audit).  Afterwards the
    /// trie holds no block references; once rows release theirs too, the
    /// pool free list is back at capacity.
    pub fn evict_all(&mut self, mgr: &mut BlockManager) {
        while self.evict_lru(mgr) {}
    }

    fn new_node(&mut self, key: &[i32], block: u32, parent: usize) -> usize {
        let node = Node {
            key: key.to_vec(),
            block,
            parent,
            children: Vec::new(),
            stamp: self.clock,
            live: true,
        };
        let idx = match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.nodes[parent].children.push(idx);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BS: usize = 16;

    fn pool(cap: usize) -> BlockManager {
        BlockManager::new(cap, BS)
    }

    /// Simulate a row prefilling `tokens`: allocate the chain the row's
    /// table would hold (the row's own references).
    fn prefill_chain(mgr: &mut BlockManager, tokens: &[i32]) -> Vec<u32> {
        (0..tokens.len().div_ceil(BS))
            .map(|_| mgr.alloc().expect("pool has room"))
            .collect()
    }

    fn toks(start: i32, n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| start + i).collect()
    }

    #[test]
    fn full_block_prefixes_match_and_misses_count() {
        let mut mgr = pool(64);
        let mut cache = PrefixCache::new(BS);
        let prompt = toks(10, 40); // 2 full blocks + 8-token tail
        let chain = prefill_chain(&mut mgr, &prompt);
        cache.insert(&prompt, &chain, &mut mgr);
        assert_eq!(cache.cached_blocks(), 3);

        // identical prompt: 2 full blocks + the whole partial tail
        let m = cache.lookup(&prompt, &mut mgr).expect("hit");
        assert_eq!(m.tokens, 40);
        assert_eq!(m.blocks, chain);

        // shared first block only
        let mut half = toks(10, 16);
        half.extend(toks(500, 10));
        let m2 = cache.lookup(&half, &mut mgr).expect("hit");
        assert_eq!(m2.tokens, 16);
        assert_eq!(m2.blocks, chain[..1]);

        // disjoint prompt: miss
        assert!(cache.lookup(&toks(900, 20), &mut mgr).is_none());
        let s = cache.stats();
        assert_eq!((s.lookups, s.prefix_hits, s.prefix_misses), (3, 2, 1));
        assert_eq!(s.prefill_tokens_saved, 56);

        // release the map references + the row chain + the cache
        for b in m.blocks.iter().chain(m2.blocks.iter()).chain(chain.iter()) {
            mgr.release(*b);
        }
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn partial_tail_requires_the_whole_key() {
        let mut mgr = pool(64);
        let mut cache = PrefixCache::new(BS);
        let mut a = toks(0, 16);
        a.extend(toks(100, 6)); // tail of 6
        let chain = prefill_chain(&mut mgr, &a);
        cache.insert(&a, &chain, &mut mgr);

        // same full block, tail diverges after 3 tokens: only the full
        // block matches (partial keys match whole or not at all)
        let mut b = toks(0, 16);
        b.extend(toks(100, 3));
        b.extend(toks(700, 5));
        let m = cache.lookup(&b, &mut mgr).expect("hit");
        assert_eq!(m.tokens, 16);
        for id in m.blocks.iter().chain(chain.iter()) {
            mgr.release(*id);
        }
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn sibling_partial_leaves_pick_the_longest_match() {
        let mut mgr = pool(64);
        let mut cache = PrefixCache::new(BS);
        let short = toks(40, 4);
        let long = toks(40, 9); // same first 4 tokens, longer tail
        let c_short = prefill_chain(&mut mgr, &short);
        let c_long = prefill_chain(&mut mgr, &long);
        cache.insert(&short, &c_short, &mut mgr);
        cache.insert(&long, &c_long, &mut mgr);
        assert_eq!(cache.cached_blocks(), 2);

        let m = cache.lookup(&toks(40, 12), &mut mgr).expect("hit");
        assert_eq!(m.tokens, 9, "longest matching partial leaf wins");
        assert_eq!(m.blocks, c_long);
        for id in m.blocks.iter().chain(&c_short).chain(&c_long) {
            mgr.release(*id);
        }
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn insert_deduplicates_shared_spans() {
        let mut mgr = pool(64);
        let mut cache = PrefixCache::new(BS);
        let shared = toks(7, 32);
        let mut a = shared.clone();
        a.extend(toks(200, 5));
        let mut b = shared.clone();
        b.extend(toks(300, 5));
        let ca = prefill_chain(&mut mgr, &a);
        let cb = prefill_chain(&mut mgr, &b);
        cache.insert(&a, &ca, &mut mgr);
        let before = cache.stats().inserted_blocks;
        cache.insert(&b, &cb, &mut mgr);
        // b re-walks the two shared full blocks (dedup) and adds only its
        // own 5-token tail
        assert_eq!(cache.stats().inserted_blocks, before + 1);
        assert_eq!(cache.cached_blocks(), 4);
        for id in ca.iter().chain(&cb) {
            mgr.release(*id);
        }
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn cow_tail_swaps_the_reference_and_counts() {
        let mut mgr = pool(8);
        let mut cache = PrefixCache::new(BS);
        let prompt = toks(3, 20); // 1 full + 4-token tail
        let chain = prefill_chain(&mut mgr, &prompt);
        cache.insert(&prompt, &chain, &mut mgr);

        let m = cache.lookup(&prompt, &mut mgr).expect("hit");
        let shared_tail = m.blocks[1];
        let fresh = cache.cow_tail(&mut mgr, shared_tail).expect("pool has room");
        assert_ne!(fresh, shared_tail);
        assert_eq!(cache.stats().cow_copies, 1);

        // the mapped row now owns [shared full, fresh tail]
        mgr.release(m.blocks[0]);
        mgr.release(fresh);
        for id in &chain {
            mgr.release(*id);
        }
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn cow_tail_evicts_under_pressure_instead_of_failing() {
        let mut mgr = pool(4);
        let mut cache = PrefixCache::new(BS);
        let a = toks(0, 30); // 2 blocks
        let ca = prefill_chain(&mut mgr, &a);
        cache.insert(&a, &ca, &mut mgr);
        let b = toks(400, 25); // 2 more: pool now full
        let cb = prefill_chain(&mut mgr, &b);
        cache.insert(&b, &cb, &mut mgr);
        assert_eq!(mgr.free_blocks(), 0);
        // rows retired: only the cache still references the 4 blocks
        for id in ca.iter().chain(&cb) {
            mgr.release(*id);
        }

        let m = cache.lookup(&a, &mut mgr).expect("hit");
        let fresh = cache
            .cow_tail(&mut mgr, m.blocks[1])
            .expect("eviction makes room");
        assert!(cache.stats().evictions >= 1, "pressure reclaimed LRU");
        mgr.release(m.blocks[0]);
        mgr.release(fresh);
        cache.evict_all(&mut mgr);
        assert!(mgr.stats().is_leak_free());
    }

    #[test]
    fn lru_eviction_reclaims_oldest_leaves_first_and_leak_frees() {
        let mut mgr = pool(32);
        let mut cache = PrefixCache::new(BS);
        let old = toks(0, 20);
        let newer = toks(500, 20);
        let c_old = prefill_chain(&mut mgr, &old);
        let c_new = prefill_chain(&mut mgr, &newer);
        cache.insert(&old, &c_old, &mut mgr);
        cache.insert(&newer, &c_new, &mut mgr);
        for id in c_old.iter().chain(&c_new) {
            mgr.release(*id);
        }
        // touch `old` so `newer` becomes LRU
        let m = cache.lookup(&old, &mut mgr).expect("hit");
        for id in &m.blocks {
            mgr.release(*id);
        }

        assert!(cache.evict_lru(&mut mgr));
        assert_eq!(cache.cached_blocks(), 3);
        // the evicted leaf is `newer`'s 4-token tail: a fresh lookup of
        // `newer` now matches only its full block, while `old` still
        // matches end to end
        let m_new = cache.lookup(&newer, &mut mgr).expect("full block remains");
        assert_eq!(m_new.tokens, 16);
        let m2 = cache.lookup(&old, &mut mgr).expect("old chain survives");
        assert_eq!(m2.tokens, 20);
        for id in m_new.blocks.iter().chain(&m2.blocks) {
            mgr.release(*id);
        }
        cache.evict_all(&mut mgr);
        assert_eq!(cache.cached_blocks(), 0);
        let s = mgr.stats();
        assert!(s.is_leak_free(), "free list back to capacity: {s:?}");
    }

    #[test]
    fn evict_until_free_stops_at_the_target() {
        let mut mgr = pool(6);
        let mut cache = PrefixCache::new(BS);
        for start in [0, 1000, 2000] {
            let p = toks(start, 20); // 2 blocks each
            let c = prefill_chain(&mut mgr, &p);
            cache.insert(&p, &c, &mut mgr);
            for id in &c {
                mgr.release(*id);
            }
        }
        assert_eq!(mgr.free_blocks(), 0);
        assert!(cache.evict_until_free(&mut mgr, 2));
        assert!(mgr.free_blocks() >= 2);
        assert!(cache.cached_blocks() <= 4);
        // demanding more than capacity drains the cache and reports it
        assert!(!cache.evict_until_free(&mut mgr, 7));
        assert!(mgr.stats().is_leak_free());
    }
}
