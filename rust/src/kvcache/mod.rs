//! Paged KV-cache accounting: the block manager behind the
//! `KvLayout::Paged` seam.
//!
//! The paper's synergy analysis (Eq. 7) assumes the engine can actually
//! run the large batches where short speculation wins, but the dense KV
//! layout makes epoch reshape O(context): every carried row's context is
//! re-ingested through chunked verify calls (and the SSM re-ingests it
//! two tokens at a time).  The paged layout removes that wall the way
//! vLLM does: each slot's KV lives in fixed-size **blocks** referenced by
//! a per-slot **block table**, so carrying a row into a larger bucket is
//! a block-table remap — O(1) in the context length, zero token
//! re-ingestion.
//!
//! ```text
//!   epoch A (bucket 2)                 epoch B (bucket 4)
//!   slot 0 ─ table [b3, b7]     ──►    slot 0 ─ table [b3, b7]   (remap)
//!   slot 1 ─ table [b1]         ──►    slot 1 ─ table [b1]       (remap)
//!                                      slot 2 ─ table [b9]       (fresh)
//!                                      slot 3 ─ table []         (vacant)
//!            block pool: free list ⟷ ref-counted blocks b0..bN
//! ```
//!
//! [`BlockManager`] is pure bookkeeping over a free list + refcounts (on
//! the stub backend the only per-row KV state is the ingest counter, so
//! remapping a table and setting the counter IS the full KV transfer; on
//! a real runtime the same tables would index device block buffers).
//! Refcounts let a carried row's chain be owned by the exporting epoch
//! and the admitting epoch at once, which is exactly the window an epoch
//! reshape opens.
//!
//! Leak discipline: every block popped from the free list must return to
//! it — `rust/tests/kv_equivalence.rs` asserts `free == capacity` after
//! every end-to-end experiment, and [`BlockManager::release`] panics on a
//! double free.

use anyhow::{bail, Result};

use crate::util::json::Json;

pub mod prefix;

/// How per-slot KV state is organised across epoch reshapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// One dense KV buffer per slot: epoch reshape re-ingests carried
    /// contexts through chunked verify calls (O(context) per reshape).
    Dense,
    /// Fixed-size blocks + per-slot block tables: epoch reshape is a
    /// block-table remap (O(1), zero token re-ingestion).  Stub-only for
    /// now (PJRT KV caches are dense per-row device buffers).
    Paged,
}

impl KvLayout {
    pub fn parse(s: &str) -> Result<KvLayout> {
        match s {
            "dense" => Ok(KvLayout::Dense),
            "paged" => Ok(KvLayout::Paged),
            other => bail!("bad kv layout {other:?}: expected dense | paged"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            KvLayout::Dense => "dense",
            KvLayout::Paged => "paged",
        }
    }

    /// The `SPECBATCH_KV_LAYOUT` environment override, if set.  CI runs
    /// the test suite as a two-way matrix over it, so an invalid value
    /// fails loudly — silently falling back to dense would turn the
    /// paged matrix leg into a second dense run.
    pub fn from_env() -> Option<KvLayout> {
        let v = std::env::var("SPECBATCH_KV_LAYOUT").ok()?;
        Some(KvLayout::parse(&v).unwrap_or_else(|e| panic!("SPECBATCH_KV_LAYOUT: {e}")))
    }

    /// Default engine layout: the env override when present, else
    /// [`KvLayout::Dense`] (the seed behaviour).
    pub fn default_layout() -> KvLayout {
        KvLayout::from_env().unwrap_or(KvLayout::Dense)
    }
}

/// Tokens-per-block of the paged layout (vLLM's default block size).
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// Snapshot of one block pool's accounting (or several pools merged):
/// the block-utilization / fragmentation counters recorded into
/// `server::ExperimentOutcome` and printed by the CLI.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvBlockStats {
    pub block_size: usize,
    /// total blocks the pool(s) own
    pub capacity: usize,
    /// blocks currently allocated (capacity - free-list cardinality)
    pub in_use: usize,
    /// free-list cardinality; leak-free shutdown means `free == capacity`
    pub free: usize,
    /// high-water mark of `in_use` over the pool's lifetime
    pub peak_in_use: usize,
    /// lifetime alloc / free call counts (must match at shutdown)
    pub allocs: u64,
    pub frees: u64,
    /// mean internal fragmentation over the recorded sync points: the
    /// fraction of allocated block space not covered by live KV entries
    pub mean_internal_frag: f64,
}

impl KvBlockStats {
    /// True when every block is back on the free list.
    pub fn is_leak_free(&self) -> bool {
        self.free == self.capacity && self.in_use == 0 && self.allocs == self.frees
    }

    /// Pool utilization at the snapshot (allocated / capacity).
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            return 0.0;
        }
        self.in_use as f64 / self.capacity as f64
    }

    /// Merge two pools' stats (e.g. the LLM and SSM pools, or per-shard
    /// pools of a cluster run).  Fragmentation is weighted by each side's
    /// lifetime allocations.
    pub fn merged(&self, other: &KvBlockStats) -> KvBlockStats {
        let wa = self.allocs as f64;
        let wb = other.allocs as f64;
        let frag = if wa + wb > 0.0 {
            (self.mean_internal_frag * wa + other.mean_internal_frag * wb) / (wa + wb)
        } else {
            0.0
        };
        KvBlockStats {
            block_size: self.block_size.max(other.block_size),
            capacity: self.capacity + other.capacity,
            in_use: self.in_use + other.in_use,
            free: self.free + other.free,
            peak_in_use: self.peak_in_use + other.peak_in_use,
            allocs: self.allocs + other.allocs,
            frees: self.frees + other.frees,
            mean_internal_frag: frag,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("block_size", Json::Num(self.block_size as f64)),
            ("capacity", Json::Num(self.capacity as f64)),
            ("in_use", Json::Num(self.in_use as f64)),
            ("free", Json::Num(self.free as f64)),
            ("peak_in_use", Json::Num(self.peak_in_use as f64)),
            ("allocs", Json::Num(self.allocs as f64)),
            ("frees", Json::Num(self.frees as f64)),
            ("utilization", Json::Num(self.utilization())),
            ("internal_frag", Json::Num(self.mean_internal_frag)),
        ])
    }
}

/// One ref-counted chain of blocks plus the KV ingest counter it covers —
/// the transferable handle of a carried row's cache state.
#[derive(Debug)]
pub struct BlockChain {
    pub blocks: Vec<u32>,
    /// KV entries the chain covers (the row's ingest counter at export)
    pub ingested: u32,
}

/// A carried row's per-model KV handle (LLM chain + optional SSM chain).
/// Refcounts on every block are held by the handle from export until the
/// admitting epoch installs the chains — or the engine releases them.
#[derive(Debug)]
pub struct KvHandle {
    pub llm: BlockChain,
    pub ssm: Option<BlockChain>,
}

/// How a re-admitted (carried) row transfers its KV across an epoch
/// reshape.  Fresh admissions carry `None` — their context was never in
/// any cache and is ingested for the first time either way.
#[derive(Debug)]
pub enum CarriedKv {
    /// Dense layout: no transferable state; the context is re-ingested
    /// through chunked verify calls (counted as re-prefilled tokens).
    Reingest,
    /// Paged layout: block chains + ingest counters; admission installs
    /// them into the target slot's tables (zero token re-ingestion).
    Blocks(KvHandle),
}

/// Flat block-table storage: every slot's table lives in one contiguous
/// `Vec<u32>` at a fixed stride, with a per-slot length.  This is the
/// hot-path layout (arena / `u32`-index idiom): growing or shrinking a
/// table is a length bump, an epoch-reshape remap is a `copy_from_slice`
/// memmove, and the steady state allocates nothing — the backing vectors
/// are sized once at `stride = blocks_for(max_seq)` per slot.
///
/// The per-slot `Vec<Vec<u32>>` API on [`BlockManager`] remains for
/// callers that want owned chains (export handles, unit tests); the
/// engine's `BatchState` uses `FlatTables` + [`BlockManager::sync_flat`].
#[derive(Debug, Clone)]
pub struct FlatTables {
    /// `rows * stride` block ids; slot `i` owns `ids[i*stride..][..len[i]]`
    ids: Vec<u32>,
    len: Vec<u32>,
    stride: usize,
}

impl FlatTables {
    /// Table storage for `rows` slots of at most `stride` blocks each.
    pub fn new(rows: usize, stride: usize) -> FlatTables {
        assert!(stride > 0, "flat table stride must be positive");
        FlatTables {
            ids: vec![0; rows * stride],
            len: vec![0; rows],
            stride,
        }
    }

    pub fn rows(&self) -> usize {
        self.len.len()
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Slot `i`'s live block ids.
    pub fn row(&self, i: usize) -> &[u32] {
        &self.ids[i * self.stride..][..self.len[i] as usize]
    }

    /// Install a chain into slot `i` (reshape remap: a bounds check and a
    /// memmove).  The caller owns refcount accounting for both the old
    /// and the new ids.
    pub fn set_row(&mut self, i: usize, blocks: &[u32]) {
        assert!(
            blocks.len() <= self.stride,
            "chain of {} blocks exceeds table stride {}",
            blocks.len(),
            self.stride
        );
        self.ids[i * self.stride..][..blocks.len()].copy_from_slice(blocks);
        self.len[i] = blocks.len() as u32;
    }

    /// Blocks currently referenced across all slots.
    pub fn total_blocks(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }
}

/// Fixed-size KV block pool: free-list allocation, per-block refcounts,
/// utilization/fragmentation accounting.  Blocks are identified by dense
/// `u32` ids; per-slot block tables are plain `Vec<u32>` owned by the
/// engine's `BatchState`.
#[derive(Debug)]
pub struct BlockManager {
    block_size: usize,
    free: Vec<u32>,
    refcount: Vec<u16>,
    peak_in_use: usize,
    allocs: u64,
    frees: u64,
    /// internal-fragmentation accumulators over sync points
    frag_num: f64,
    frag_den: f64,
}

impl BlockManager {
    pub fn new(capacity: usize, block_size: usize) -> BlockManager {
        assert!(block_size > 0, "block size must be positive");
        assert!(capacity > 0, "block pool needs at least one block");
        BlockManager {
            block_size,
            // LIFO free list: low ids pop first, which keeps tests readable
            free: (0..capacity as u32).rev().collect(),
            refcount: vec![0; capacity],
            peak_in_use: 0,
            allocs: 0,
            frees: 0,
            frag_num: 0.0,
            frag_den: 0.0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Blocks needed to cover `tokens` KV entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Pop a free block (refcount 1).
    pub fn alloc(&mut self) -> Result<u32> {
        let Some(id) = self.free.pop() else {
            bail!(
                "KV block pool exhausted ({} blocks of {} tokens) — a state \
                 was dropped without Engine::release_state, or max_batch × \
                 max_seq outgrew the pool",
                self.capacity(),
                self.block_size
            );
        };
        debug_assert_eq!(self.refcount[id as usize], 0);
        self.refcount[id as usize] = 1;
        self.allocs += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Ok(id)
    }

    /// Add a reference to an allocated block (a carried chain being
    /// exported shares its blocks with the old epoch).
    pub fn retain(&mut self, id: u32) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "retain of a free block {id}");
        *rc += 1;
    }

    /// Drop a reference; the block returns to the free list at zero.
    /// Panics on a double free — the leak tests rely on that.
    pub fn release(&mut self, id: u32) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "double free of block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            self.frees += 1;
        }
    }

    /// Grow/shrink per-slot block tables to cover each slot's ingest
    /// counter, then record a fragmentation sample.  The single sync
    /// point the engine calls after every state-mutating operation.
    pub fn sync_tables(&mut self, tables: &mut [Vec<u32>], ingested: &[u32]) -> Result<()> {
        debug_assert_eq!(tables.len(), ingested.len());
        let mut tokens = 0usize;
        let mut blocks = 0usize;
        for (table, &ing) in tables.iter_mut().zip(ingested) {
            let want = self.blocks_for(ing as usize);
            while table.len() < want {
                let id = self.alloc()?;
                table.push(id);
            }
            while table.len() > want {
                let id = table.pop().expect("len > want >= 0");
                self.release(id);
            }
            tokens += ing as usize;
            blocks += table.len();
        }
        // fragmentation is sampled over the synced tables' own space (not
        // pool-wide in_use, which transiently includes carried handles'
        // blocks during a reshape and would overstate waste)
        let space = (blocks * self.block_size) as f64;
        if space > 0.0 {
            self.frag_num += space - tokens as f64;
            self.frag_den += space;
        }
        Ok(())
    }

    /// Release every block of every table (end of an epoch's life).
    pub fn release_tables(&mut self, tables: &mut [Vec<u32>]) {
        for table in tables.iter_mut() {
            for id in table.drain(..) {
                self.release(id);
            }
        }
    }

    /// [`BlockManager::sync_tables`] over the flat layout: grow/shrink
    /// each slot's span to cover its ingest counter, then record a
    /// fragmentation sample.  Zero allocations — the span storage is
    /// pre-sized and the free list never outgrows its initial capacity.
    pub fn sync_flat(&mut self, tables: &mut FlatTables, ingested: &[u32]) -> Result<()> {
        debug_assert_eq!(tables.rows(), ingested.len());
        let mut tokens = 0usize;
        let mut blocks = 0usize;
        let stride = tables.stride;
        for (i, &ing) in ingested.iter().enumerate() {
            let want = self.blocks_for(ing as usize);
            debug_assert!(want <= stride, "ingest outgrew the table stride");
            let base = i * stride;
            let mut n = tables.len[i] as usize;
            while n < want {
                // commit partial growth before propagating exhaustion, so
                // a caller that frees pool space (prefix-cache eviction)
                // can re-invoke the sync without leaking the blocks this
                // pass already allocated
                match self.alloc() {
                    Ok(id) => {
                        tables.ids[base + n] = id;
                        n += 1;
                    }
                    Err(e) => {
                        tables.len[i] = n as u32;
                        return Err(e);
                    }
                }
            }
            while n > want {
                n -= 1;
                self.release(tables.ids[base + n]);
            }
            tables.len[i] = n as u32;
            tokens += ing as usize;
            blocks += n;
        }
        // same sampling rule as sync_tables: over the synced tables' own
        // space, so carried handles' blocks don't overstate waste
        let space = (blocks * self.block_size) as f64;
        if space > 0.0 {
            self.frag_num += space - tokens as f64;
            self.frag_den += space;
        }
        Ok(())
    }

    /// Release every block of a flat table set (end of an epoch's life).
    pub fn release_flat(&mut self, tables: &mut FlatTables) {
        for i in 0..tables.rows() {
            let base = i * tables.stride;
            for k in 0..tables.len[i] as usize {
                self.release(tables.ids[base + k]);
            }
            tables.len[i] = 0;
        }
    }

    pub fn stats(&self) -> KvBlockStats {
        KvBlockStats {
            block_size: self.block_size,
            capacity: self.capacity(),
            in_use: self.in_use(),
            free: self.free_blocks(),
            peak_in_use: self.peak_in_use,
            allocs: self.allocs,
            frees: self.frees,
            mean_internal_frag: if self.frag_den > 0.0 {
                self.frag_num / self.frag_den
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_parses_and_labels() {
        assert_eq!(KvLayout::parse("dense").unwrap(), KvLayout::Dense);
        assert_eq!(KvLayout::parse("paged").unwrap(), KvLayout::Paged);
        assert!(KvLayout::parse("blocky").is_err());
        for l in [KvLayout::Dense, KvLayout::Paged] {
            assert_eq!(KvLayout::parse(l.label()).unwrap(), l);
        }
    }

    #[test]
    fn alloc_release_conserves_the_free_list() {
        let mut m = BlockManager::new(4, 16);
        assert_eq!(m.free_blocks(), 4);
        let a = m.alloc().unwrap();
        let b = m.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(m.in_use(), 2);
        m.release(a);
        m.release(b);
        assert_eq!(m.free_blocks(), 4);
        assert!(m.stats().is_leak_free());
        assert_eq!(m.stats().peak_in_use, 2);
    }

    #[test]
    fn refcounts_defer_the_free() {
        let mut m = BlockManager::new(2, 16);
        let a = m.alloc().unwrap();
        m.retain(a);
        m.release(a);
        assert_eq!(m.in_use(), 1, "one reference still holds the block");
        m.release(a);
        assert_eq!(m.free_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut m = BlockManager::new(2, 16);
        let a = m.alloc().unwrap();
        m.release(a);
        m.release(a);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let mut m = BlockManager::new(1, 16);
        let _a = m.alloc().unwrap();
        assert!(m.alloc().is_err());
    }

    #[test]
    fn sync_tables_tracks_ingest_counters() {
        let mut m = BlockManager::new(8, 4);
        let mut tables = vec![Vec::new(), Vec::new()];
        // row 0 covers 5 tokens (2 blocks of 4), row 1 covers 4 (1 block)
        m.sync_tables(&mut tables, &[5, 4]).unwrap();
        assert_eq!(tables[0].len(), 2);
        assert_eq!(tables[1].len(), 1);
        assert_eq!(m.in_use(), 3);
        // shrink row 0 back to 1 token
        m.sync_tables(&mut tables, &[1, 4]).unwrap();
        assert_eq!(tables[0].len(), 1);
        assert_eq!(m.in_use(), 2);
        // fragmentation accumulated: allocated space always >= tokens
        let s = m.stats();
        assert!(s.mean_internal_frag >= 0.0 && s.mean_internal_frag < 1.0);
        m.release_tables(&mut tables);
        assert!(m.stats().is_leak_free());
    }

    #[test]
    fn sync_flat_matches_sync_tables() {
        // the flat layout must make identical alloc/release decisions to
        // the Vec-of-Vec layout (same free list, same ids, same frag)
        let mut a = BlockManager::new(8, 4);
        let mut b = BlockManager::new(8, 4);
        let mut vecs = vec![Vec::new(), Vec::new()];
        let mut flat = FlatTables::new(2, 4);
        for ing in [[5u32, 4], [9, 4], [1, 4], [0, 0]] {
            a.sync_tables(&mut vecs, &ing).unwrap();
            b.sync_flat(&mut flat, &ing).unwrap();
            for i in 0..2 {
                assert_eq!(vecs[i].as_slice(), flat.row(i), "row {i} at {ing:?}");
            }
            assert_eq!(a.in_use(), b.in_use());
        }
        assert_eq!(a.stats(), b.stats());
        a.release_tables(&mut vecs);
        b.release_flat(&mut flat);
        assert!(b.stats().is_leak_free());
        assert_eq!(flat.total_blocks(), 0);
    }

    #[test]
    fn flat_set_row_is_a_remap() {
        let mut m = BlockManager::new(8, 4);
        let mut flat = FlatTables::new(2, 4);
        m.sync_flat(&mut flat, &[9, 4]).unwrap();
        let chain: Vec<u32> = flat.row(0).to_vec();
        // move row 0's chain into row 1: retain, install, release old
        for &id in &chain {
            m.retain(id);
        }
        for &id in flat.row(1) {
            m.release(id);
        }
        flat.set_row(1, &chain);
        assert_eq!(flat.row(0), flat.row(1));
        assert_eq!(flat.total_blocks(), 6);
        m.release_flat(&mut flat);
        // row 0 released each shared block once, row 1 the second time
        assert!(m.stats().is_leak_free());
    }

    #[test]
    fn stats_merge_adds_pools() {
        let mut a = BlockManager::new(4, 16);
        let b = BlockManager::new(6, 16);
        let id = a.alloc().unwrap();
        let merged = a.stats().merged(&b.stats());
        assert_eq!(merged.capacity, 10);
        assert_eq!(merged.in_use, 1);
        assert_eq!(merged.free, 9);
        assert!(!merged.is_leak_free());
        a.release(id);
        assert!(a.stats().merged(&b.stats()).is_leak_free());
    }
}
