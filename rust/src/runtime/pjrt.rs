//! The PJRT-backed [`Runtime`]: loads AOT artifacts and executes them on
//! the CPU client (compiled only with `--features pjrt`).
//!
//! Responsibilities:
//!
//! * upload each model's weight blob to **persistent device buffers** once
//!   at startup (weights never cross host<->device again);
//! * lazily compile HLO-text modules on first use and cache the
//!   [`xla::PjRtLoadedExecutable`]s (`specbatch warmup`/`Runtime::warmup`
//!   precompiles the common set so serving never compiles on the request
//!   path);
//! * provide small host<->device staging helpers for token/length tensors.
//!
//! Threading: PJRT handles in the `xla` crate are not `Send`; a `Runtime`
//! lives on the thread that created it (the server spawns its worker
//! thread first and builds the `Runtime` inside it).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::ExeKey;
use super::{ExeKind, Manifest, ModelSpec};
use crate::dataset::Dataset;
use crate::log_info;

/// Loaded runtime: client + manifest + device-resident weights + exe cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// model name -> device weight buffers in manifest.weight_order
    weights: HashMap<String, Vec<xla::PjRtBuffer>>,
    exe_cache: RefCell<HashMap<ExeKey, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative (compiles, compile_seconds) for observability
    compile_stats: RefCell<(usize, f64)>,
}

impl Runtime {
    /// Load artifacts from `dir` (produced by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let t0 = Instant::now();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        let mut weights = HashMap::new();
        for (name, m) in &manifest.models {
            let path = manifest.dir.join(&m.weights_file);
            let blob = std::fs::read(&path)
                .with_context(|| format!("reading weights {}", path.display()))?;
            if blob.len() != m.weights_bytes {
                bail!(
                    "weight blob {} is {} bytes, manifest declares {}",
                    path.display(),
                    blob.len(),
                    m.weights_bytes
                );
            }
            let mut bufs = Vec::with_capacity(m.weights.len());
            for w in &m.weights {
                let bytes = &blob[w.offset..w.offset + w.numel * 4];
                // NOTE: not buffer_from_host_raw_bytes — xla 0.1.6 passes the
                // ElementType discriminant where a PrimitiveType is expected
                // (F32 -> F16), silently halving the buffer.  The typed API
                // converts correctly.
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let buf = client
                    .buffer_from_host_buffer(&data, &w.shape, None)
                    .map_err(|e| anyhow::anyhow!("uploading {}/{}: {e}", name, w.name))?;
                bufs.push(buf);
            }
            weights.insert(name.clone(), bufs);
        }
        log_info!(
            "runtime loaded: {} executables declared, {} models, {:.2}s",
            manifest.executables.len(),
            manifest.models.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(Runtime {
            client,
            manifest,
            weights,
            exe_cache: RefCell::new(HashMap::new()),
            compile_stats: RefCell::new((0, 0.0)),
        })
    }

    pub fn model_spec(&self, model: &str) -> Result<&ModelSpec> {
        self.manifest
            .models
            .get(model)
            .map(|m| &m.spec)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))
    }

    /// Device weight buffers of a model, in calling-convention order.
    pub fn weights(&self, model: &str) -> Result<&[xla::PjRtBuffer]> {
        self.weights
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))
    }

    /// Lazily compile (and cache) an executable.
    pub fn executable(
        &self,
        model: &str,
        kind: ExeKind,
        batch: usize,
        s: usize,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = ExeKey {
            model: model.to_string(),
            kind,
            batch,
            s,
        };
        if let Some(exe) = self.exe_cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.exe(model, kind, batch, s)?;
        let path = self.manifest.dir.join(&entry.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", entry.name))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = self.compile_stats.borrow_mut();
            st.0 += 1;
            st.1 += dt;
        }
        log_info!("compiled {} in {dt:.2}s", entry.name);
        let exe = Rc::new(exe);
        self.exe_cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Precompile every executable needed to serve batches up to
    /// `max_bucket` with speculation lengths up to `max_s` — called before
    /// the server goes live so nothing compiles on the request path.
    pub fn warmup(&self, max_bucket: usize, max_s: usize) -> Result<usize> {
        let mut n = 0;
        let buckets: Vec<usize> = self
            .manifest
            .batch_buckets
            .iter()
            .copied()
            .filter(|&b| b <= max_bucket)
            .collect();
        for &b in &buckets {
            self.executable("llm", ExeKind::Prefill, b, 0)?;
            self.executable("ssm", ExeKind::Prefill, b, 0)?;
            n += 2;
            for &s in &self.manifest.verify_lengths {
                if s <= max_s {
                    self.executable("llm", ExeKind::Verify, b, s)?;
                    n += 1;
                }
            }
            for &s in &self.manifest.speculate_lengths {
                if s <= max_s {
                    self.executable("ssm", ExeKind::Speculate, b, s)?;
                    n += 1;
                }
            }
        }
        let st = self.compile_stats.borrow();
        log_info!(
            "warmup: {n} executables ready ({} compiled, {:.1}s total)",
            st.0,
            st.1
        );
        Ok(n)
    }

    /// (compiled count, total compile seconds) so far.
    pub fn compile_stats(&self) -> (usize, f64) {
        *self.compile_stats.borrow()
    }

    /// Upload an i32 tensor.
    pub fn i32_buffer(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("staging i32{dims:?}: {e}"))
    }

    /// Upload an f32 tensor.
    pub fn f32_buffer(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("staging f32{dims:?}: {e}"))
    }

    /// Zero-initialized f32 device tensor (fresh KV caches).
    pub fn f32_zeros(&self, dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let numel: usize = dims.iter().product();
        let zeros = vec![0f32; numel];
        self.client
            .buffer_from_host_buffer(&zeros, dims, None)
            .map_err(|e| anyhow::anyhow!("allocating zeros f32{dims:?}: {e}"))
    }

    /// Download an i32 tensor.
    pub fn read_i32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("reading i32 buffer: {e}"))?;
        lit.to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("converting literal: {e}"))
    }

    /// Run an executable on device buffers, expecting `n_out` outputs.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
        n_out: usize,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing: {e}"))?;
        if out.len() != 1 {
            bail!("expected single-replica output, got {}", out.len());
        }
        let outputs = out.pop().unwrap();
        if outputs.len() != n_out {
            bail!("expected {n_out} outputs, got {}", outputs.len());
        }
        Ok(outputs)
    }

    /// Load the dataset referenced by the manifest.
    pub fn dataset(&self) -> Result<Dataset> {
        Dataset::load(self.manifest.dir.join(&self.manifest.dataset_file))
    }
}
