//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Parses `artifacts/manifest.json` into typed structs and
//! validates the invariants the engine depends on (weight table covers the
//! declared byte span, executable matrix is well-formed, shared vocab).

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Architecture of one model (mirrors `python/compile/configs.ModelConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub max_prompt: usize,
}

impl ModelSpec {
    fn from_json(j: &Json) -> Result<ModelSpec> {
        Ok(ModelSpec {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            max_prompt: j.get("max_prompt")?.as_usize()?,
        })
    }

    /// KV-cache element count for a given batch:
    /// `[L, 2, B, H, S_max, d_head]`.
    pub fn kv_numel(&self, batch: usize) -> usize {
        self.n_layers * 2 * batch * self.n_heads * self.max_seq * self.d_head
    }

    pub fn kv_dims(&self, batch: usize) -> Vec<usize> {
        vec![
            self.n_layers,
            2,
            batch,
            self.n_heads,
            self.max_seq,
            self.d_head,
        ]
    }

    /// Dense FLOPs of one forward pass over `t` tokens (per batch row),
    /// used by the simulator's roofline model.
    pub fn flops_per_token(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let l = self.n_layers as f64;
        let v = self.vocab as f64;
        // qkv/o projections + MLP (x2 for mul+add) + lm head
        2.0 * (l * (4.0 * d * d + 2.0 * d * f) + d * v)
    }

    /// Parameter bytes (f32), used by the simulator's memory-bound model.
    pub fn param_bytes(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let l = self.n_layers as f64;
        let v = self.vocab as f64;
        4.0 * (v * d + self.max_seq as f64 * d + l * (4.0 * d * d + 2.0 * d * f))
    }
}

/// One tensor slice in a `weights_*.bin` blob.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

/// Per-model artifact bundle.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub spec: ModelSpec,
    pub weights_file: String,
    pub weights_bytes: usize,
    pub weights: Vec<WeightEntry>,
    pub n_params: usize,
}

/// Kind of AOT executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ExeKind {
    Prefill,
    Verify,
    Speculate,
}

impl ExeKind {
    fn parse(s: &str) -> Result<ExeKind> {
        Ok(match s {
            "prefill" => ExeKind::Prefill,
            "verify" => ExeKind::Verify,
            "speculate" => ExeKind::Speculate,
            other => bail!("unknown executable kind {other:?}"),
        })
    }
}

impl fmt::Display for ExeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ExeKind::Prefill => "prefill",
            ExeKind::Verify => "verify",
            ExeKind::Speculate => "speculate",
        })
    }
}

/// One entry of the executable matrix.
#[derive(Debug, Clone)]
pub struct ExeEntry {
    pub name: String,
    pub file: String,
    pub model: String,
    pub kind: ExeKind,
    pub batch: usize,
    pub s: usize,
}

/// Key used to look an executable up at runtime.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExeKey {
    pub model: String,
    pub kind: ExeKind,
    pub batch: usize,
    pub s: usize,
}

/// The full parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub profile: String,
    pub weight_order: Vec<String>,
    pub models: BTreeMap<String, ModelManifest>,
    pub executables: BTreeMap<ExeKey, ExeEntry>,
    pub batch_buckets: Vec<usize>,
    pub verify_lengths: Vec<usize>,
    pub speculate_lengths: Vec<usize>,
    pub dataset_file: String,
    pub goldens_file: String,
    pub agreement_rate: f64,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let json = Json::parse_file(&path)
            .with_context(|| format!("loading manifest {}", path.display()))?;
        Self::from_json(&json, dir)
    }

    pub fn from_json(json: &Json, dir: PathBuf) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        for (name, m) in json.get("models")?.as_obj()? {
            let spec = ModelSpec::from_json(m.get("config")?)?;
            let weights_bytes = m.get("weights_bytes")?.as_usize()?;
            let mut weights = Vec::new();
            let mut expect_offset = 0usize;
            for w in m.get("weights")?.as_arr()? {
                let e = WeightEntry {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: w.get_usize_vec("shape")?,
                    offset: w.get("offset")?.as_usize()?,
                    numel: w.get("numel")?.as_usize()?,
                };
                if e.offset != expect_offset {
                    bail!("weight table of {name} has a gap at {}", e.name);
                }
                if e.shape.iter().product::<usize>() != e.numel {
                    bail!("weight {} shape/numel mismatch", e.name);
                }
                expect_offset += e.numel * 4;
                weights.push(e);
            }
            if expect_offset != weights_bytes {
                bail!(
                    "weight table of {name} covers {expect_offset} bytes, \
                     blob declares {weights_bytes}"
                );
            }
            models.insert(
                name.clone(),
                ModelManifest {
                    spec,
                    weights_file: m.get("weights_file")?.as_str()?.to_string(),
                    weights_bytes,
                    weights,
                    n_params: m.get("n_params")?.as_usize()?,
                },
            );
        }
        if !models.contains_key("llm") || !models.contains_key("ssm") {
            bail!("manifest must declare both llm and ssm models");
        }
        let (vl, vs) = (
            models["llm"].spec.vocab,
            models["ssm"].spec.vocab,
        );
        if vl != vs {
            bail!("speculative decoding requires a shared vocab ({vl} != {vs})");
        }

        let mut executables = BTreeMap::new();
        for e in json.get("executables")?.as_arr()? {
            let entry = ExeEntry {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                model: e.get("model")?.as_str()?.to_string(),
                kind: ExeKind::parse(e.get("kind")?.as_str()?)?,
                batch: e.get("batch")?.as_usize()?,
                s: e.get("s")?.as_usize()?,
            };
            if !models.contains_key(&entry.model) {
                bail!("executable {} references unknown model", entry.name);
            }
            let key = ExeKey {
                model: entry.model.clone(),
                kind: entry.kind,
                batch: entry.batch,
                s: entry.s,
            };
            executables.insert(key, entry);
        }
        if executables.is_empty() {
            bail!("manifest declares no executables");
        }

        let weight_order: Vec<String> = json
            .get("weight_order")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        for m in models.values() {
            let names: Vec<&str> = m.weights.iter().map(|w| w.name.as_str()).collect();
            if names != weight_order.iter().map(|s| s.as_str()).collect::<Vec<_>>() {
                bail!("weight table order diverges from weight_order");
            }
        }

        Ok(Manifest {
            dir,
            fingerprint: json.get("fingerprint")?.as_str()?.to_string(),
            profile: json.get("profile")?.as_str()?.to_string(),
            weight_order,
            models,
            executables,
            batch_buckets: json.get_usize_vec("batch_buckets")?,
            verify_lengths: json.get_usize_vec("verify_lengths")?,
            speculate_lengths: json.get_usize_vec("speculate_lengths")?,
            dataset_file: json.get("dataset")?.as_str()?.to_string(),
            goldens_file: json.get("goldens")?.as_str()?.to_string(),
            agreement_rate: json.get("agreement_rate")?.as_f64()?,
        })
    }

    pub fn exe(&self, model: &str, kind: ExeKind, batch: usize, s: usize) -> Result<&ExeEntry> {
        let key = ExeKey {
            model: model.to_string(),
            kind,
            batch,
            s,
        };
        self.executables.get(&key).ok_or_else(|| {
            anyhow::anyhow!(
                "no executable for model={model} kind={kind} batch={batch} s={s} \
                 (available buckets {:?}, verify s {:?}) — re-run `make artifacts` \
                 with a profile that covers it",
                self.batch_buckets,
                self.verify_lengths
            )
        })
    }

    pub fn has_exe(&self, model: &str, kind: ExeKind, batch: usize, s: usize) -> bool {
        self.executables.contains_key(&ExeKey {
            model: model.to_string(),
            kind,
            batch,
            s,
        })
    }

    /// Smallest compiled batch bucket that can hold `n` rows.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "batch of {n} exceeds the largest compiled bucket {:?}",
                    self.batch_buckets.iter().max()
                )
            })
    }

    /// Largest speculation length with both verify and speculate
    /// executables at this bucket.
    pub fn max_spec_len(&self, bucket: usize) -> usize {
        (1..=16)
            .take_while(|&s| {
                self.has_exe("llm", ExeKind::Verify, bucket, s)
                    && self.has_exe("ssm", ExeKind::Speculate, bucket, s)
            })
            .last()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn toy_manifest_json() -> Json {
        // minimal but internally consistent manifest for parser tests
        let weight = |name: &str, numel: usize, offset: usize| {
            Json::obj(vec![
                ("name", Json::Str(name.into())),
                ("shape", Json::from_usize_slice(&[numel])),
                ("offset", Json::Num(offset as f64)),
                ("numel", Json::Num(numel as f64)),
            ])
        };
        let model = |name: &str| {
            Json::obj(vec![
                (
                    "config",
                    Json::obj(vec![
                        ("name", Json::Str(name.into())),
                        ("vocab", Json::Num(16.0)),
                        ("d_model", Json::Num(8.0)),
                        ("n_layers", Json::Num(1.0)),
                        ("n_heads", Json::Num(2.0)),
                        ("d_head", Json::Num(4.0)),
                        ("d_ff", Json::Num(16.0)),
                        ("max_seq", Json::Num(32.0)),
                        ("max_prompt", Json::Num(8.0)),
                    ]),
                ),
                ("weights_file", Json::Str(format!("weights_{name}.bin"))),
                ("weights_bytes", Json::Num(48.0)),
                (
                    "weights",
                    Json::Arr(vec![weight("embed", 8, 0), weight("lnf_scale", 4, 32)]),
                ),
                ("n_params", Json::Num(12.0)),
            ])
        };
        let exe = Json::obj(vec![
            ("name", Json::Str("llm_verify_b1_s1".into())),
            ("file", Json::Str("llm_verify_b1_s1.hlo.txt".into())),
            ("model", Json::Str("llm".into())),
            ("kind", Json::Str("verify".into())),
            ("batch", Json::Num(1.0)),
            ("s", Json::Num(1.0)),
        ]);
        Json::obj(vec![
            ("fingerprint", Json::Str("abc".into())),
            ("profile", Json::Str("test".into())),
            (
                "weight_order",
                Json::Arr(vec![
                    Json::Str("embed".into()),
                    Json::Str("lnf_scale".into()),
                ]),
            ),
            (
                "models",
                Json::obj(vec![("llm", model("llm")), ("ssm", model("ssm"))]),
            ),
            ("executables", Json::Arr(vec![exe])),
            ("batch_buckets", Json::from_usize_slice(&[1, 2, 4])),
            ("verify_lengths", Json::from_usize_slice(&[0, 1, 2])),
            ("speculate_lengths", Json::from_usize_slice(&[1, 2])),
            ("dataset", Json::Str("dataset.json".into())),
            ("goldens", Json::Str("goldens.json".into())),
            ("agreement_rate", Json::Num(0.7)),
        ])
    }

    #[test]
    fn parses_toy_manifest() {
        let m = Manifest::from_json(&toy_manifest_json(), PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.models["llm"].spec.d_model, 8);
        assert!(m.has_exe("llm", ExeKind::Verify, 1, 1));
        assert!(!m.has_exe("llm", ExeKind::Verify, 2, 1));
        assert_eq!(m.bucket_for(3).unwrap(), 4);
        assert_eq!(m.bucket_for(1).unwrap(), 1);
        assert!(m.bucket_for(5).is_err());
    }

    #[test]
    fn kv_dims_match_python_layout() {
        let m = Manifest::from_json(&toy_manifest_json(), PathBuf::from("/tmp")).unwrap();
        let spec = &m.models["llm"].spec;
        assert_eq!(spec.kv_dims(4), vec![1, 2, 4, 2, 32, 4]);
        assert_eq!(spec.kv_numel(4), 1 * 2 * 4 * 2 * 32 * 4);
    }

    #[test]
    fn rejects_gapped_weight_table() {
        let mut j = toy_manifest_json();
        if let Json::Obj(o) = &mut j {
            let m = o.get_mut("models").unwrap();
            if let Json::Obj(mo) = m {
                let llm = mo.get_mut("llm").unwrap();
                if let Json::Obj(l) = llm {
                    if let Some(Json::Arr(ws)) = l.get_mut("weights") {
                        if let Json::Obj(w1) = &mut ws[1] {
                            w1.insert("offset".into(), Json::Num(40.0));
                        }
                    }
                }
            }
        }
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn exe_error_message_is_actionable() {
        let m = Manifest::from_json(&toy_manifest_json(), PathBuf::from("/tmp")).unwrap();
        let err = m.exe("llm", ExeKind::Verify, 8, 3).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
