//! Artifact runtime layer.
//!
//! * [`manifest`] — always compiled: the typed contract between
//!   `python/compile/aot.py` and the Rust coordinator (executable matrix,
//!   weight tables, bucket sets).  The engine's batch limits derive from
//!   it even when no PJRT client is linked.
//! * [`Runtime`] — the PJRT executor, compiled only with
//!   `--features pjrt` (it needs the offline `xla` crate closure and
//!   `make artifacts`).  The default build substitutes the testkit
//!   stub-model pair (see `testkit::stub` and DESIGN.md §Feature flags).

pub mod manifest;

pub use manifest::{ExeKind, Manifest, ModelSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
