//! SLO-aware request admission — the first-class lifecycle layer between
//! arrival and the batch.
//!
//! The paper closes the loop from *round feedback* to the *speculation
//! length*: [`crate::policy::ModelBased`] fits the Eq. 4/5/7 latency
//! model online and re-solves `s_opt(live)`.  But admission stayed blind
//! FIFO: a burst pushes every in-flight request past its latency target
//! even while the policy is choosing the "optimal" `s`.  This module
//! turns admission into the same kind of feedback consumer — the fitted
//! model now decides not just *how far to speculate* but *who runs*:
//!
//! * [`Fifo`] — arrival order, admit everything: bit-for-bit the
//!   pre-admission-subsystem behaviour (pinned by
//!   `tests/slo_admission.rs`);
//! * [`Edf`] — earliest-deadline-first: the queue is reordered by
//!   deadline (deadline-less requests keep arrival order behind every
//!   deadlined one), nothing is deferred or shed.  Classic
//!   deadline-driven scheduling, model-free;
//! * [`SloAware`] — EDF ordering plus model-predicted feasibility: each
//!   candidate's completion is predicted from
//!   [`SpeculationPolicy::predict_token_time`] at the post-admission
//!   batch width.  A candidate predicted to miss its deadline at that
//!   width is **deferred** (it re-enters consideration at the next round
//!   boundary, when load may have dropped) — unless it could not meet the
//!   deadline even running alone, in which case it is **shed** so its
//!   rounds go to requests that can still make their SLOs.  A
//!   [`SloAwareConfig::hysteresis`] slack band keeps marginal candidates
//!   from flapping between admit and defer, and while the policy's fits
//!   are cold (`predict_token_time` returns `None`) the controller
//!   degrades to exactly [`Edf`].
//!
//! All three drivers share the layer: [`crate::batcher`] plans admission
//! at every round boundary on the real engine, the DES mirrors it in
//! virtual time (`crate::simulator::des`, `crate::cluster::sim`), and the
//! threaded server resolves the controller from
//! [`AdmissionSpec`](crate::config::AdmissionSpec) (`serve --admission`).
//!
//! ## The controller contract
//!
//! [`AdmissionController::plan`] sees the whole queue as [`Candidate`]s
//! and returns one verdict per candidate, in admission priority order:
//!
//! * the verdict list must be a **permutation** of the queue indices
//!   (every candidate judged exactly once — the property tests pin it);
//! * `Admit` verdicts beyond the free capacity are simply queued ahead
//!   (the driver admits the longest feasible prefix of the `Admit`s);
//! * when `view.live == 0` the plan must admit at least one candidate
//!   unless it sheds every one of them — an idle worker sitting on a
//!   fully-deferred queue would never advance time.  Drivers additionally
//!   enforce this by force-admitting the highest-priority deferred
//!   candidate, so a misbehaving controller cannot wedge the loop;
//! * controllers must be deterministic given their construction
//!   parameters (the DES replays are bit-reproducible).

use crate::config::AdmissionSpec;
use crate::policy::SpeculationPolicy;

/// What the controller sees of one queued request at a round boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub id: u64,
    /// client send time on the experiment clock
    pub sent_at: f64,
    /// absolute deadline on the experiment clock (None = no SLO)
    pub deadline: Option<f64>,
    /// prompt tokens to prefill if admitted
    pub prompt_len: usize,
    /// generation budget still owed if admitted
    pub tokens_left: usize,
    /// round boundaries this candidate has already been deferred at
    pub deferred: usize,
}

/// One admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// eligible now; admitted if a free row exists
    Admit,
    /// held back this boundary, reconsidered at the next one
    Defer,
    /// rejected: leaves the queue without ever occupying a batch row
    Shed,
}

/// The driver-side context a plan is made against.
pub struct AdmissionView<'a> {
    /// experiment-clock seconds of the round boundary
    pub now: f64,
    /// rows currently decoding
    pub live: usize,
    /// concurrency cap (live + admissions never exceed it)
    pub max_batch: usize,
    /// the worker's speculation policy — [`SloAware`] reads its fitted
    /// per-bucket latency model through `predict_token_time`
    pub policy: &'a dyn SpeculationPolicy,
}

/// A queue-ordering / defer / shed strategy consulted at every round
/// boundary (see the module docs for the contract).
pub trait AdmissionController: Send {
    /// Judge the queue: one `(queue_index, verdict)` per candidate, in
    /// admission priority order.
    fn plan(&mut self, queue: &[Candidate], view: &AdmissionView<'_>) -> Vec<(usize, Verdict)>;

    fn label(&self) -> String;
}

/// Arrival-order admit-everything: the pre-subsystem behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl AdmissionController for Fifo {
    fn plan(&mut self, queue: &[Candidate], _view: &AdmissionView<'_>) -> Vec<(usize, Verdict)> {
        (0..queue.len()).map(|i| (i, Verdict::Admit)).collect()
    }

    fn label(&self) -> String {
        "fifo".into()
    }
}

/// Stable earliest-deadline-first priority order over the queue:
/// deadlined candidates ascending by deadline, then every deadline-less
/// candidate in arrival order.  Ties (equal deadlines) keep arrival
/// order, so a deadline-free workload is ordered exactly like FIFO.
fn edf_order(queue: &[Candidate]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..queue.len()).collect();
    idx.sort_by(|&a, &b| {
        let ka = queue[a].deadline.unwrap_or(f64::INFINITY);
        let kb = queue[b].deadline.unwrap_or(f64::INFINITY);
        ka.partial_cmp(&kb)
            .expect("deadlines are finite")
            .then(a.cmp(&b))
    });
    idx
}

/// Earliest-deadline-first admission: reorder, never defer or shed.
#[derive(Debug, Clone, Copy, Default)]
pub struct Edf;

impl AdmissionController for Edf {
    fn plan(&mut self, queue: &[Candidate], _view: &AdmissionView<'_>) -> Vec<(usize, Verdict)> {
        edf_order(queue)
            .into_iter()
            .map(|i| (i, Verdict::Admit))
            .collect()
    }

    fn label(&self) -> String {
        "edf".into()
    }
}

/// Knobs of the [`SloAware`] controller.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloAwareConfig {
    /// slack band, as a fraction of the request's total latency budget
    /// (`deadline - sent_at`): a candidate is only deferred/shed when its
    /// predicted finish misses the deadline by more than this.  The
    /// hysteresis keeps marginal candidates from flapping between admit
    /// and defer as the fitted model jitters round to round.
    pub hysteresis: f64,
    /// round boundaries a candidate may be deferred before it is
    /// force-admitted (starvation bound)
    pub max_defer_rounds: usize,
}

impl Default for SloAwareConfig {
    fn default() -> Self {
        SloAwareConfig {
            hysteresis: 0.10,
            max_defer_rounds: 64,
        }
    }
}

/// Model-predicted feasibility admission (see the module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct SloAware {
    pub cfg: SloAwareConfig,
}

impl SloAware {
    pub fn with_config(cfg: SloAwareConfig) -> SloAware {
        SloAware { cfg }
    }
}

/// Effective per-token time a worker already holding `load` requests
/// would serve at, from the policy's fitted model: the bucket prediction
/// at `min(load, max_batch)`, time-shared by `load / max_batch` beyond
/// the cap (queued requests wait their turn, so their tokens arrive that
/// much slower).  `None` while the fits are cold.
pub fn predicted_token_time(
    policy: &dyn SpeculationPolicy,
    load: usize,
    max_batch: usize,
) -> Option<f64> {
    let max_batch = max_batch.max(1);
    let t = policy.predict_token_time(load.clamp(1, max_batch))?;
    Some(t * (load as f64 / max_batch as f64).max(1.0))
}

/// Predicted completion time of a candidate joining a worker at total
/// load `load` (itself included), per [`predicted_token_time`].
pub fn predicted_finish(
    policy: &dyn SpeculationPolicy,
    now: f64,
    tokens_left: usize,
    load: usize,
    max_batch: usize,
) -> Option<f64> {
    let t = predicted_token_time(policy, load, max_batch)?;
    Some(now + tokens_left as f64 * t)
}

impl AdmissionController for SloAware {
    fn plan(&mut self, queue: &[Candidate], view: &AdmissionView<'_>) -> Vec<(usize, Verdict)> {
        let order = edf_order(queue);
        // cold fits degrade to EDF: comparing predictions that do not
        // exist would either admit or shed everything blindly
        if view.policy.predict_token_time(1).is_none() {
            return order.into_iter().map(|i| (i, Verdict::Admit)).collect();
        }
        let mut plan = Vec::with_capacity(queue.len());
        let mut admitted = 0usize;
        for i in order {
            let c = &queue[i];
            let Some(deadline) = c.deadline else {
                // no SLO: best-effort, never deferred or shed
                plan.push((i, Verdict::Admit));
                admitted += 1;
                continue;
            };
            let budget = (deadline - c.sent_at).max(0.0);
            let grace = self.cfg.hysteresis * budget;
            let width = view.live + admitted + 1;
            let predicted = |load: usize| {
                predicted_finish(view.policy, view.now, c.tokens_left, load, view.max_batch)
            };
            // a policy that predicts at width 1 but not here is treated
            // as cold for this candidate: admit (EDF behaviour)
            let (Some(finish), Some(solo)) = (predicted(width), predicted(1)) else {
                plan.push((i, Verdict::Admit));
                admitted += 1;
                continue;
            };
            let verdict = if finish <= deadline + grace {
                Verdict::Admit
            } else if solo > deadline + grace {
                // cannot meet the SLO even running alone: spending
                // rounds on it only drags feasible requests past their
                // own deadlines
                Verdict::Shed
            } else if view.live + admitted == 0 {
                // nothing ahead of it — deferring gains nothing and an
                // idle worker must make progress
                Verdict::Admit
            } else if c.deferred >= self.cfg.max_defer_rounds {
                // starvation bound
                Verdict::Admit
            } else {
                Verdict::Defer
            };
            if verdict == Verdict::Admit {
                admitted += 1;
            }
            plan.push((i, verdict));
        }
        plan
    }

    fn label(&self) -> String {
        "slo-aware".into()
    }
}

/// Resolve a parsed [`AdmissionSpec`] into a live controller.
pub fn build_controller(spec: AdmissionSpec) -> Box<dyn AdmissionController> {
    match spec {
        AdmissionSpec::Fifo => Box::new(Fifo),
        AdmissionSpec::Edf => Box::new(Edf),
        AdmissionSpec::SloAware => Box::new(SloAware::default()),
    }
}

/// One controller instance per shard (deferral counters and hysteresis
/// state must not be shared across shards).
pub fn replicate_controllers(
    spec: AdmissionSpec,
    workers: usize,
) -> Vec<Box<dyn AdmissionController>> {
    (0..workers).map(|_| build_controller(spec)).collect()
}

/// A plan split into its applied form: queue indices to admit (in
/// priority order), to keep queued (in priority order), and to shed.
/// Shared by the batcher and both DES mirrors so every driver applies a
/// plan identically — including the idle-worker force-admit rule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AppliedPlan {
    pub admit: Vec<usize>,
    pub defer: Vec<usize>,
    pub shed: Vec<usize>,
}

/// A plan applied to an owned queue (see [`apply_plan_to_queue`]).
pub struct QueuePlan<T> {
    /// the queue, admits first (in plan priority order), then defers
    pub queue: Vec<T>,
    /// shed entries, in plan priority order
    pub shed: Vec<T>,
    /// Admit verdicts — the admissible prefix of `queue`
    pub admit_n: usize,
    /// Defer verdicts applied at this boundary
    pub deferred: usize,
}

/// Apply a controller's plan to an owned queue: sheds split out, the
/// rest reordered to admits-then-defers with each defer's counter bumped
/// via `bump_defer`, and the idle-worker progress rule enforced (via
/// [`apply_plan`]).  A pure-FIFO plan (identity order, all Admit)
/// returns the queue untouched, so FIFO drivers stay bit-identical.
/// Every driver — batcher, static server, all DES mirrors — routes
/// through this, so a plan is applied identically everywhere.
pub fn apply_plan_to_queue<T>(
    plan: Vec<(usize, Verdict)>,
    queue: Vec<T>,
    live: usize,
    mut bump_defer: impl FnMut(&mut T),
) -> QueuePlan<T> {
    let n = queue.len();
    let applied = apply_plan(plan, n, live);
    let fifo_like = applied.shed.is_empty()
        && applied.defer.is_empty()
        && applied.admit.iter().copied().eq(0..n);
    if fifo_like {
        return QueuePlan {
            queue,
            shed: Vec::new(),
            admit_n: n,
            deferred: 0,
        };
    }
    let mut items: Vec<Option<T>> = queue.into_iter().map(Some).collect();
    let mut take = |i: usize| -> T {
        items[i].take().expect("plan indices are unique")
    };
    let mut out = Vec::with_capacity(n);
    for &i in &applied.admit {
        out.push(take(i));
    }
    for &i in &applied.defer {
        let mut t = take(i);
        bump_defer(&mut t);
        out.push(t);
    }
    let shed: Vec<T> = applied.shed.iter().map(|&i| take(i)).collect();
    QueuePlan {
        queue: out,
        shed,
        admit_n: applied.admit.len(),
        deferred: applied.defer.len(),
    }
}

/// Validate and split a plan (debug-asserting the permutation contract),
/// applying the idle-worker progress rule: with no live rows, no admits
/// and at least one deferred candidate, the highest-priority deferred
/// candidate is promoted to admit.
pub fn apply_plan(plan: Vec<(usize, Verdict)>, n_queue: usize, live: usize) -> AppliedPlan {
    debug_assert_eq!(plan.len(), n_queue, "plan must judge every candidate");
    debug_assert!(
        {
            let mut seen = vec![false; n_queue];
            plan.iter().all(|&(i, _)| {
                i < n_queue && !std::mem::replace(&mut seen[i], true)
            })
        },
        "plan must be a permutation of the queue"
    );
    let mut out = AppliedPlan::default();
    for (i, v) in plan {
        match v {
            Verdict::Admit => out.admit.push(i),
            Verdict::Defer => out.defer.push(i),
            Verdict::Shed => out.shed.push(i),
        }
    }
    if live == 0 && out.admit.is_empty() && !out.defer.is_empty() {
        out.admit.push(out.defer.remove(0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::{AcceptanceModel, StepCostModel};
    use crate::policy::{Fixed, ModelBased};
    use crate::scheduler::Lut;

    fn cand(id: u64, sent_at: f64, deadline: Option<f64>) -> Candidate {
        Candidate {
            id,
            sent_at,
            deadline,
            prompt_len: 8,
            tokens_left: 32,
            deferred: 0,
        }
    }

    /// A ModelBased policy with warm fits (predicts at every width).
    fn warm_policy() -> ModelBased {
        let acceptance = AcceptanceModel {
            c: 0.9,
            gamma: 0.548,
            r2: 1.0,
        };
        let costs = [
            StepCostModel {
                batch: 1,
                alpha: 0.0004,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
            StepCostModel {
                batch: 16,
                alpha: 0.02,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
        ];
        let lut = Lut::new([(1usize, 3usize)].into_iter().collect()).unwrap();
        ModelBased::with_models(lut, acceptance, &costs)
    }

    fn view<'a>(policy: &'a dyn SpeculationPolicy, now: f64, live: usize) -> AdmissionView<'a> {
        AdmissionView {
            now,
            live,
            max_batch: 16,
            policy,
        }
    }

    #[test]
    fn fifo_admits_everything_in_arrival_order() {
        let q = vec![cand(0, 0.0, Some(1.0)), cand(1, 0.1, Some(0.5)), cand(2, 0.2, None)];
        let plan = Fifo.plan(&q, &view(&Fixed(2), 0.3, 0));
        assert_eq!(
            plan,
            vec![(0, Verdict::Admit), (1, Verdict::Admit), (2, Verdict::Admit)]
        );
    }

    #[test]
    fn edf_orders_by_deadline_with_stable_ties_and_deadline_less_last() {
        let q = vec![
            cand(0, 0.0, Some(9.0)),
            cand(1, 0.1, None),
            cand(2, 0.2, Some(2.0)),
            cand(3, 0.3, Some(2.0)),
            cand(4, 0.4, None),
        ];
        let plan = Edf.plan(&q, &view(&Fixed(2), 0.5, 0));
        let order: Vec<usize> = plan.iter().map(|&(i, _)| i).collect();
        assert_eq!(order, vec![2, 3, 0, 1, 4]);
        assert!(plan.iter().all(|&(_, v)| v == Verdict::Admit));
        // no deadlines at all -> pure arrival order (FIFO-equivalent)
        let free = vec![cand(0, 0.0, None), cand(1, 0.1, None)];
        let plan = Edf.plan(&free, &view(&Fixed(2), 0.2, 0));
        assert_eq!(plan.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn slo_aware_degrades_to_edf_while_the_policy_is_cold() {
        let q = vec![cand(0, 0.0, Some(5.0)), cand(1, 0.1, Some(1.0))];
        // Fixed policies never predict -> cold
        let plan = SloAware::default().plan(&q, &view(&Fixed(2), 0.2, 4));
        let edf = Edf.plan(&q, &view(&Fixed(2), 0.2, 4));
        assert_eq!(plan, edf);
    }

    #[test]
    fn slo_aware_admits_feasible_defers_loaded_and_sheds_hopeless() {
        let p = warm_policy();
        let t1 = p.predict_token_time(1).unwrap();
        // generous deadline: feasible even at a loaded width -> admit
        let feasible = cand(0, 0.0, Some(1e3));
        // hopeless: cannot finish even alone (deadline already passed
        // relative to the solo service time) -> shed
        let hopeless = cand(1, 0.0, Some(32.0 * t1 * 0.2));
        let q = vec![feasible, hopeless];
        let plan = SloAware::default().plan(&q, &view(&p, 0.0, 2));
        let verdict = |id: usize| plan.iter().find(|&&(i, _)| i == id).unwrap().1;
        assert_eq!(verdict(0), Verdict::Admit);
        assert_eq!(verdict(1), Verdict::Shed);

        // a candidate that misses at the crowded width but would meet
        // alone is deferred while rows are live...
        let t16 = predicted_token_time(&p, 16, 16).unwrap();
        let marginal = cand(2, 0.0, Some(32.0 * (t1 + t16) / 2.0));
        let plan = SloAware::default().plan(&[marginal], &view(&p, 0.0, 15));
        assert_eq!(plan, vec![(0, Verdict::Defer)]);
        // ...but admitted when the worker is idle (progress rule)
        let plan = SloAware::default().plan(&[marginal], &view(&p, 0.0, 0));
        assert_eq!(plan, vec![(0, Verdict::Admit)]);
        // ...and force-admitted once the starvation bound is hit
        let mut starved = marginal;
        starved.deferred = SloAwareConfig::default().max_defer_rounds;
        let plan = SloAware::default().plan(&[starved], &view(&p, 0.0, 15));
        assert_eq!(plan, vec![(0, Verdict::Admit)]);
    }

    #[test]
    fn slo_aware_never_defers_or_sheds_deadline_less_requests() {
        let p = warm_policy();
        let q: Vec<Candidate> = (0..20).map(|i| cand(i, 0.0, None)).collect();
        let plan = SloAware::default().plan(&q, &view(&p, 0.0, 15));
        assert!(plan.iter().all(|&(_, v)| v == Verdict::Admit));
        // and with no deadlines the order is pure arrival order, so a
        // deadline-free workload behaves exactly like FIFO
        assert_eq!(
            plan.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
            (0..20).collect::<Vec<_>>()
        );
    }

    #[test]
    fn hysteresis_widens_the_admit_band() {
        let p = warm_policy();
        // pick a deadline that the crowded-width prediction misses by
        // less than 50% of the budget: strict config defers, loose admits
        let t_wide = predicted_token_time(&p, 16, 16).unwrap();
        let budget = 32.0 * t_wide / 1.2; // ~17% past the deadline
        let c = cand(0, 0.0, Some(budget));
        let strict = SloAware::with_config(SloAwareConfig {
            hysteresis: 0.0,
            ..SloAwareConfig::default()
        });
        let loose = SloAware::with_config(SloAwareConfig {
            hysteresis: 0.5,
            ..SloAwareConfig::default()
        });
        let v = view(&p, 0.0, 15);
        assert_eq!(strict.clone().plan(&[c], &v), vec![(0, Verdict::Defer)]);
        assert_eq!(loose.clone().plan(&[c], &v), vec![(0, Verdict::Admit)]);
    }

    #[test]
    fn apply_plan_splits_and_enforces_idle_progress() {
        let plan = vec![(1, Verdict::Defer), (0, Verdict::Shed), (2, Verdict::Defer)];
        // live worker: defers stay defers
        let a = apply_plan(plan.clone(), 3, 2);
        assert_eq!(a.admit, Vec::<usize>::new());
        assert_eq!(a.defer, vec![1, 2]);
        assert_eq!(a.shed, vec![0]);
        // idle worker: the highest-priority defer is promoted
        let a = apply_plan(plan, 3, 0);
        assert_eq!(a.admit, vec![1]);
        assert_eq!(a.defer, vec![2]);
        assert_eq!(a.shed, vec![0]);
    }

    #[test]
    fn apply_plan_to_queue_rebuilds_and_keeps_fifo_untouched() {
        // FIFO plan: the queue comes back untouched, nothing shed
        let q = vec!["a", "b", "c"];
        let plan = vec![(0, Verdict::Admit), (1, Verdict::Admit), (2, Verdict::Admit)];
        let out = apply_plan_to_queue(plan, q.clone(), 1, |_| panic!("no defers"));
        assert_eq!(out.queue, q);
        assert!(out.shed.is_empty());
        assert_eq!((out.admit_n, out.deferred), (3, 0));

        // mixed plan: admits first in priority order, defers bumped,
        // sheds split out
        let mut queue = vec![(0u64, 0usize), (1, 0), (2, 0), (3, 0)];
        queue[3].1 = 7; // pre-existing defer count survives the bump
        let plan = vec![
            (2, Verdict::Admit),
            (0, Verdict::Shed),
            (3, Verdict::Defer),
            (1, Verdict::Admit),
        ];
        let out = apply_plan_to_queue(plan, queue, 2, |e| e.1 += 1);
        assert_eq!(out.queue, vec![(2, 0), (1, 0), (3, 8)]);
        assert_eq!(out.shed, vec![(0, 0)]);
        assert_eq!((out.admit_n, out.deferred), (2, 1));
    }

    #[test]
    fn build_controller_matches_spec_labels() {
        for spec in AdmissionSpec::all() {
            assert_eq!(build_controller(spec).label(), spec.label());
        }
        assert_eq!(replicate_controllers(AdmissionSpec::Edf, 3).len(), 3);
    }

    #[test]
    fn predicted_token_time_scales_past_the_cap() {
        let p = warm_policy();
        let at_cap = predicted_token_time(&p, 16, 16).unwrap();
        let over = predicted_token_time(&p, 32, 16).unwrap();
        assert!((over - 2.0 * at_cap).abs() < 1e-12, "{over} vs {at_cap}");
        assert!(predicted_token_time(&Fixed(2), 4, 16).is_none());
    }
}
