//! Deterministic stub model pair: the artifact-free engine backend.
//!
//! The default build carries no PJRT runtime and no Python-built
//! artifacts, yet the engine, batcher and server still need a model pair
//! that honours the full calling convention (`prefill` / `verify` /
//! `speculate` with per-row KV ingest counters).  [`StubModel`] provides
//! one: a hash-chain language model whose next token depends only on the
//! last fed token, so plain greedy decoding is the chain
//! `t_{k+1} = H(t_k)` and *losslessness* of speculative decoding is
//! checkable exactly.  The stub SSM agrees with the stub LLM on a
//! configurable fraction of the token space, producing realistic partial
//! draft acceptance.
//!
//! The stub honours the same state-machine contract as the real
//! executables: ingest counters advance by the executable's full span and
//! the caller clamps them back after acceptance; entries above
//! `ingested` are never read, so rollback works identically.

use anyhow::{bail, Result};

/// Shape and limit description of the stub model pair (the stub-world
/// analogue of the artifact manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StubSpec {
    /// vocabulary size; ids 0..=3 are reserved specials (pad/bos/eos/unk)
    /// and are never generated
    pub vocab: usize,
    /// maximum prompt length accepted by the prefill path
    pub max_prompt: usize,
    /// KV-cache capacity per row
    pub max_seq: usize,
    /// batch buckets the stub "compiles" for (sorted ascending)
    pub batch_buckets: Vec<usize>,
    /// largest speculation length available at every bucket
    pub max_spec: usize,
    /// percent of the token space on which the SSM agrees with the LLM
    pub agreement_pct: u32,
    /// seed shaping the SSM's disagreement pattern
    pub seed: u64,
}

impl Default for StubSpec {
    fn default() -> Self {
        StubSpec {
            vocab: 64,
            max_prompt: 16,
            max_seq: 320,
            batch_buckets: vec![1, 2, 4, 8, 16],
            max_spec: 8,
            agreement_pct: 80,
            seed: 0xB007,
        }
    }
}

/// Which side of the draft/target pair a [`StubModel`] plays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StubRole {
    Llm,
    Ssm,
}

/// Stub KV cache: only the per-row ingest counters carry state (the
/// stub's predictions depend on the fed token alone, mirroring how real
/// entries above `ingested` are never attended).
#[derive(Debug, Clone)]
pub struct StubKv {
    pub batch: usize,
    pub ingested: Vec<u32>,
}

impl StubKv {
    /// Roll ingest counters back to `committed_len - 1` per row (same
    /// contract as the real `KvCache::clamp_to`).
    pub fn clamp_to(&mut self, committed_minus_one: &[u32]) {
        assert_eq!(committed_minus_one.len(), self.batch);
        for (ing, &c) in self.ingested.iter_mut().zip(committed_minus_one) {
            *ing = (*ing).min(c);
        }
    }

    /// Forget a row entirely (continuous batching re-admits into it).
    pub fn reset_row(&mut self, row: usize) {
        self.ingested[row] = 0;
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// One deterministic stub model bound to a role.
#[derive(Debug, Clone)]
pub struct StubModel {
    pub spec: StubSpec,
    pub role: StubRole,
}

impl StubModel {
    pub fn new(spec: StubSpec, role: StubRole) -> StubModel {
        StubModel { spec, role }
    }

    /// The target (LLM) chain: next token after `t`, always in
    /// `[4, vocab)` so specials are never generated.
    pub fn llm_next(&self, t: i32) -> i32 {
        let span = (self.spec.vocab - 4) as u64;
        4 + (splitmix64(t as u64 ^ 0x5eed_11) % span) as i32
    }

    /// This model's own next-token function (the SSM diverges from the
    /// LLM on a deterministic `100 - agreement_pct` percent slice of the
    /// token space).
    pub fn next(&self, t: i32) -> i32 {
        let llm = self.llm_next(t);
        match self.role {
            StubRole::Llm => llm,
            StubRole::Ssm => {
                let agree =
                    splitmix64(t as u64 ^ self.spec.seed) % 100 < self.spec.agreement_pct as u64;
                if agree {
                    llm
                } else {
                    let span = (self.spec.vocab - 4) as i32;
                    4 + (llm - 4 + 1) % span
                }
            }
        }
    }

    pub fn new_kv(&self, batch: usize) -> StubKv {
        StubKv {
            batch,
            ingested: vec![0; batch],
        }
    }

    /// Prefill the padded prompts; returns the prediction at each row's
    /// last real prompt token and marks `plen` entries ingested.
    pub fn prefill(
        &self,
        tokens: &[i32],
        plens: &[i32],
        batch: usize,
        kv: &mut StubKv,
    ) -> Result<Vec<i32>> {
        let p = self.spec.max_prompt;
        if tokens.len() != batch * p || plens.len() != batch {
            bail!(
                "stub {:?} prefill: tokens len {} / plens len {} do not match \
                 batch {batch} x max_prompt {p}",
                self.role,
                tokens.len(),
                plens.len()
            );
        }
        if kv.batch != batch {
            bail!("stub {:?} prefill: KV batch mismatch", self.role);
        }
        if kv.ingested.iter().any(|&i| i != 0) {
            bail!("stub {:?} prefill: KV cache already used", self.role);
        }
        let mut out = Vec::with_capacity(batch);
        for (r, (ing, &plen)) in kv.ingested.iter_mut().zip(plens).enumerate() {
            let plen = plen as usize;
            if plen == 0 || plen > p {
                bail!("stub {:?} prefill: prompt length out of range 1..={p}", self.role);
            }
            out.push(self.next(tokens[r * p + plen - 1]));
            *ing = plen as u32;
        }
        Ok(out)
    }

    /// Verify step: feed `[B, s+1]` tokens, get the prediction at every
    /// position; ingest counters advance by `s + 1` (caller clamps).
    pub fn verify(
        &self,
        feed: &[i32],
        s: usize,
        batch: usize,
        kv: &mut StubKv,
    ) -> Result<Vec<i32>> {
        let t = s + 1;
        if feed.len() != batch * t {
            bail!(
                "stub {:?} verify(s={s}): feed len {} != batch {batch} x {t}",
                self.role,
                feed.len()
            );
        }
        if kv.batch != batch {
            bail!("stub {:?} verify: KV batch mismatch", self.role);
        }
        self.check_capacity(kv, t)?;
        let pred = feed.iter().map(|&x| self.next(x)).collect();
        for ing in kv.ingested.iter_mut() {
            *ing += t as u32;
        }
        Ok(pred)
    }

    /// [`StubModel::verify`] into a caller-owned buffer (hot-path twin:
    /// same validation, same counter advance, zero allocations once `out`
    /// reached its high-water mark).
    pub fn verify_into(
        &self,
        feed: &[i32],
        s: usize,
        batch: usize,
        kv: &mut StubKv,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        let t = s + 1;
        if feed.len() != batch * t {
            bail!(
                "stub {:?} verify(s={s}): feed len {} != batch {batch} x {t}",
                self.role,
                feed.len()
            );
        }
        if kv.batch != batch {
            bail!("stub {:?} verify: KV batch mismatch", self.role);
        }
        self.check_capacity(kv, t)?;
        out.clear();
        out.extend(feed.iter().map(|&x| self.next(x)));
        for ing in kv.ingested.iter_mut() {
            *ing += t as u32;
        }
        Ok(())
    }

    /// Speculate step: ingest the 1..=2-token delta, then draft `s`
    /// tokens by chaining the SSM; counters advance by `dlen + s - 1`.
    pub fn speculate(
        &self,
        delta: &[i32],
        dlens: &[i32],
        s: usize,
        batch: usize,
        kv: &mut StubKv,
    ) -> Result<Vec<i32>> {
        if s == 0 {
            bail!("stub {:?} speculate: s must be >= 1", self.role);
        }
        if delta.len() != batch * 2 || dlens.len() != batch {
            bail!("stub {:?} speculate: delta/dlens shape mismatch", self.role);
        }
        if dlens.iter().any(|&d| !(1..=2).contains(&d)) {
            bail!(
                "stub {:?} speculate: delta invariant violated \
                 (dlens must be 1..=2, got {dlens:?})",
                self.role
            );
        }
        if kv.batch != batch {
            bail!("stub {:?} speculate: KV batch mismatch", self.role);
        }
        self.check_capacity(kv, 2 + s)?;
        let mut draft = Vec::with_capacity(batch * s);
        for (r, (ing, &d)) in kv.ingested.iter_mut().zip(dlens).enumerate() {
            let d = d as usize;
            let mut cur = delta[r * 2 + d - 1];
            for _ in 0..s {
                cur = self.next(cur);
                draft.push(cur);
            }
            *ing += d as u32 + s as u32 - 1;
        }
        Ok(draft)
    }

    /// [`StubModel::speculate`] into a caller-owned buffer (hot-path
    /// twin: same validation, same counter advance, zero allocations once
    /// `out` reached its high-water mark).
    pub fn speculate_into(
        &self,
        delta: &[i32],
        dlens: &[i32],
        s: usize,
        batch: usize,
        kv: &mut StubKv,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        if s == 0 {
            bail!("stub {:?} speculate: s must be >= 1", self.role);
        }
        if delta.len() != batch * 2 || dlens.len() != batch {
            bail!("stub {:?} speculate: delta/dlens shape mismatch", self.role);
        }
        if dlens.iter().any(|&d| !(1..=2).contains(&d)) {
            bail!(
                "stub {:?} speculate: delta invariant violated \
                 (dlens must be 1..=2, got {dlens:?})",
                self.role
            );
        }
        if kv.batch != batch {
            bail!("stub {:?} speculate: KV batch mismatch", self.role);
        }
        self.check_capacity(kv, 2 + s)?;
        out.clear();
        for (r, (ing, &d)) in kv.ingested.iter_mut().zip(dlens).enumerate() {
            let d = d as usize;
            let mut cur = delta[r * 2 + d - 1];
            for _ in 0..s {
                cur = self.next(cur);
                out.push(cur);
            }
            *ing += d as u32 + s as u32 - 1;
        }
        Ok(())
    }

    fn check_capacity(&self, kv: &StubKv, t: usize) -> Result<()> {
        let cap = self.spec.max_seq;
        if let Some(&max_ing) = kv.ingested.iter().max() {
            if max_ing as usize + t > cap {
                bail!(
                    "stub {:?}: KV cache overflow (ingested {max_ing} + {t} > capacity {cap}) — \
                     lower max_new_tokens or use a larger StubSpec::max_seq",
                    self.role
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llm() -> StubModel {
        StubModel::new(StubSpec::default(), StubRole::Llm)
    }

    fn ssm() -> StubModel {
        StubModel::new(StubSpec::default(), StubRole::Ssm)
    }

    #[test]
    fn chain_is_deterministic_and_avoids_specials() {
        let m = llm();
        let mut t = 5i32;
        for _ in 0..200 {
            let n = m.next(t);
            assert_eq!(n, m.next(t), "determinism");
            assert!((4..m.spec.vocab as i32).contains(&n), "token {n} in range");
            t = n;
        }
    }

    #[test]
    fn ssm_agreement_is_partial() {
        let (l, s) = (llm(), ssm());
        let total = l.spec.vocab as i32 - 4;
        let agree = (4..l.spec.vocab as i32)
            .filter(|&t| l.next(t) == s.next(t))
            .count() as i32;
        assert!(agree > 0, "SSM never agrees");
        assert!(agree < total, "SSM always agrees");
    }

    #[test]
    fn prefill_sets_counters_and_predicts_from_last_token() {
        let m = llm();
        let p = m.spec.max_prompt;
        let mut kv = m.new_kv(2);
        let mut tokens = vec![0i32; 2 * p];
        tokens[0] = 5;
        tokens[1] = 9;
        tokens[p] = 7;
        let first = m.prefill(&tokens, &[2, 1], 2, &mut kv).unwrap();
        assert_eq!(first, vec![m.next(9), m.next(7)]);
        assert_eq!(kv.ingested, vec![2, 1]);
        // a second prefill on a used cache is rejected
        assert!(m.prefill(&tokens, &[2, 1], 2, &mut kv).is_err());
    }

    #[test]
    fn verify_advances_and_clamp_rolls_back() {
        let m = llm();
        let mut kv = m.new_kv(1);
        kv.ingested[0] = 4;
        let pred = m.verify(&[5, 6, 7], 2, 1, &mut kv).unwrap();
        assert_eq!(pred, vec![m.next(5), m.next(6), m.next(7)]);
        assert_eq!(kv.ingested, vec![7]);
        kv.clamp_to(&[5]);
        assert_eq!(kv.ingested, vec![5]);
    }

    #[test]
    fn speculate_chains_drafts() {
        let m = ssm();
        let mut kv = m.new_kv(1);
        kv.ingested[0] = 3;
        let draft = m.speculate(&[8, 9], &[2], 3, 1, &mut kv).unwrap();
        let d1 = m.next(9);
        let d2 = m.next(d1);
        let d3 = m.next(d2);
        assert_eq!(draft, vec![d1, d2, d3]);
        // counters advance by dlen + s - 1 = 2 + 3 - 1
        assert_eq!(kv.ingested, vec![7]);
        // bad dlens rejected
        let mut kv2 = m.new_kv(1);
        assert!(m.speculate(&[8, 9], &[3], 1, 1, &mut kv2).is_err());
    }

    #[test]
    fn into_variants_match_allocating_calls() {
        let m = ssm();
        let mut kv_a = m.new_kv(2);
        let mut kv_b = m.new_kv(2);
        kv_a.ingested = vec![3, 5];
        kv_b.ingested = vec![3, 5];
        let mut out = vec![99i32; 1]; // stale contents must be overwritten
        let feed = [5, 6, 7, 8, 9, 10];
        let pred = m.verify(&feed, 2, 2, &mut kv_a).unwrap();
        m.verify_into(&feed, 2, 2, &mut kv_b, &mut out).unwrap();
        assert_eq!(pred, out);
        assert_eq!(kv_a.ingested, kv_b.ingested);
        let delta = [8, 9, 10, 11];
        let draft = m.speculate(&delta, &[2, 1], 3, 2, &mut kv_a).unwrap();
        m.speculate_into(&delta, &[2, 1], 3, 2, &mut kv_b, &mut out).unwrap();
        assert_eq!(draft, out);
        assert_eq!(kv_a.ingested, kv_b.ingested);
    }

    #[test]
    fn capacity_overflow_is_detected() {
        let spec = StubSpec {
            max_seq: 8,
            ..StubSpec::default()
        };
        let m = StubModel::new(spec, StubRole::Llm);
        let mut kv = m.new_kv(1);
        kv.ingested[0] = 7;
        let err = m.verify(&[5, 6], 1, 1, &mut kv).unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
    }
}
