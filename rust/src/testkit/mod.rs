//! Property-based testing mini-framework (proptest is unavailable
//! offline).
//!
//! Deterministic, seeded, with iteration budgets and greedy input
//! shrinking for the most common generator shapes.  Usage:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla_extension rpath)
//! use specbatch::testkit::{Gen, check};
//! check("sum is commutative", 200, |g| {
//!     let a = g.int(0, 1000) as i64;
//!     let b = g.int(0, 1000) as i64;
//!     a + b == b + a
//! });
//! ```
//!
//! On failure the harness re-runs the failing case with the recorded seed
//! and reports it, so `SPECBATCH_PT_SEED=<seed>` reproduces it exactly.

pub mod harness;
pub mod stub;

use crate::util::prng::Pcg64;

/// Random input generator handed to each property iteration.
pub struct Gen {
    rng: Pcg64,
    /// trace of drawn values for the failure report
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen {
            rng: Pcg64::new(seed),
            trace: Vec::new(),
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.next_range(lo, hi);
        self.trace.push(format!("int({lo},{hi})={v}"));
        v
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo);
        self.trace.push(format!("f64({lo},{hi})={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Vector of integers with random length in [min_len, max_len].
    pub fn int_vec(&mut self, min_len: usize, max_len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.rng.next_range(min_len, max_len);
        let v: Vec<usize> = (0..n).map(|_| self.rng.next_range(lo, hi)).collect();
        self.trace.push(format!("int_vec(len={n})={v:?}"));
        v
    }

    /// Vector of i32 tokens.
    pub fn tokens(&mut self, min_len: usize, max_len: usize, vocab: usize) -> Vec<i32> {
        self.int_vec(min_len, max_len, 0, vocab - 1)
            .into_iter()
            .map(|t| t as i32)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len());
        self.trace.push(format!("choose(idx={i})"));
        &xs[i]
    }
}

/// Run a property `iters` times with distinct seeds; panic with a
/// reproducible report on the first failure.
pub fn check(name: &str, iters: usize, prop: impl Fn(&mut Gen) -> bool) {
    let base = std::env::var("SPECBATCH_PT_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    for i in 0..iters {
        let seed = match base {
            Some(s) => s,
            None => 0x5eed_0000 + i as u64,
        };
        let mut g = Gen::new(seed);
        let ok = prop(&mut g);
        if !ok {
            panic!(
                "property {name:?} failed at iteration {i} (seed {seed}).\n\
                 drawn values: {:#?}\n\
                 reproduce with SPECBATCH_PT_SEED={seed}",
                g.trace
            );
        }
        if base.is_some() {
            break; // single reproduction run
        }
    }
}

/// Like [`check`] but the property returns a Result with a reason.
pub fn check_result(
    name: &str,
    iters: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    check(name, iters, |g| match prop(g) {
        Ok(()) => true,
        Err(why) => {
            eprintln!("property {name:?}: {why}");
            false
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iterations() {
        check("ints in range", 100, |g| {
            let v = g.int(3, 9);
            (3..=9).contains(&v)
        });
    }

    #[test]
    #[should_panic(expected = "SPECBATCH_PT_SEED")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |g| {
            let _ = g.int(0, 10);
            false
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        assert_eq!(a.int(0, 1000), b.int(0, 1000));
        assert_eq!(a.tokens(1, 8, 512), b.tokens(1, 8, 512));
        assert_eq!(a.bool(), b.bool());
    }
}
