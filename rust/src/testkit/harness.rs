//! Shared trace/experiment scaffolding for the integration tests.
//!
//! `tests/batcher_stub.rs`, `tests/continuous_sim.rs` and
//! `tests/cluster_routing.rs` each used to grow their own prompt pools,
//! paper-profile sim configs and conservation assertions; this module is
//! the single copy they (and every newer test, e.g.
//! `tests/kv_equivalence.rs`) pull from instead.

use crate::analytic::{AcceptanceModel, StepCostModel};
use crate::dataset::Prompt;
use crate::engine::EngineConfig;
use crate::kvcache::KvLayout;
use crate::metrics::LatencyRecorder;
use crate::policy::ModelBased;
use crate::server::{ExperimentOutcome, SchedulingMode, ServerConfig};
use crate::simulator::{round_cost, simulated_lut, CostModel, GpuProfile, ModelProfile, SimConfig};
use crate::testkit::stub::{StubModel, StubRole, StubSpec};
use crate::traffic::{SloSpec, Trace, TrafficPattern};

/// The stub integration tests' prompt pool: eight token-varied prompts
/// of 3..=10 tokens, all inside the default stub vocabulary.
pub fn stub_prompt_pool() -> Vec<Prompt> {
    (3..=10usize)
        .map(|n| Prompt {
            ids: (0..n).map(|k| 4 + ((k * 5 + n) % 50) as i32).collect(),
            text: String::new(),
        })
        .collect()
}

/// A single-prompt pool of constant length (the DES tests' workload).
pub fn const_prompt_pool(len: usize) -> Vec<Prompt> {
    vec![Prompt {
        ids: vec![1; len],
        text: String::new(),
    }]
}

/// Prompt lengths `lo..=hi` of ones — the Fig. 5 pool shape.
pub fn ramp_prompt_pool(lo: usize, hi: usize) -> Vec<Prompt> {
    (lo..=hi)
        .map(|n| Prompt {
            ids: vec![1; n],
            text: String::new(),
        })
        .collect()
}

/// The paper-scale simulator profile every acceptance test compares on:
/// OPT-6.7B target + OPT-125M draft on an RTX 3090.
pub fn paper_sim_config(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
    );
    cfg.seed = seed;
    cfg
}

/// Stationary Gamma traffic over `pool`.
pub fn stationary_trace(
    pool: &[Prompt],
    n: usize,
    seed: u64,
    interval: f64,
    cv: f64,
) -> Trace {
    Trace::generate(&TrafficPattern::Stationary { interval, cv }, pool, n, seed)
}

/// The Fig. 6 alternating intense/sparse pattern, optionally
/// time-compressed (`time_scale < 1` = denser).
pub fn fig6_trace(pool: &[Prompt], n: usize, seed: u64, time_scale: f64) -> Trace {
    Trace::generate(&TrafficPattern::fig6(), pool, n, seed).time_scaled(time_scale)
}

/// A deadlined Fig. 6 trace: the bursty workload of the SLO-admission
/// acceptance tests.  `p50`/`scale` parameterize the [`SloSpec`] budgets
/// (sampled on a separate PRNG stream — the base schedule is the plain
/// [`fig6_trace`], bit for bit).
pub fn slo_fig6_trace(
    pool: &[Prompt],
    n: usize,
    seed: u64,
    time_scale: f64,
    p50: f64,
    scale: f64,
) -> Trace {
    fig6_trace(pool, n, seed, time_scale).with_deadlines(&SloSpec::new(p50, scale), seed)
}

/// A [`ModelBased`] policy pre-seeded with fits matching the simulator's
/// own cost model at `ctx` (what the online fit converges to), so
/// `predict_token_time` — the signal `SloAware` admission and the
/// cost/deadline routers read — is warm and deterministic from round one.
pub fn warm_model_based(cfg: &SimConfig, ctx: usize) -> ModelBased {
    let buckets = [1usize, 2, 4, 8, 16];
    let lut = simulated_lut(cfg, &buckets, 8, ctx);
    let costs: Vec<StepCostModel> = buckets
        .iter()
        .map(|&b| {
            let t1 = round_cost(cfg, b, 1, ctx);
            let t2 = round_cost(cfg, b, 2, ctx);
            let alpha = t2 - t1;
            StepCostModel {
                batch: b,
                alpha,
                beta: (t1 - alpha).max(1e-9),
                t_ssm: 0.0,
                r2: 1.0,
            }
        })
        .collect();
    ModelBased::with_models(lut, AcceptanceModel::paper(), &costs)
}

/// Every id `0..n` leaves exactly one record (completed or shed), with
/// causal timestamps, and the attainment counters conserve:
/// `met + missed + shed == deadlined` over the deadlined population.
pub fn assert_slo_conserves(rec: &LatencyRecorder, n: usize) {
    assert_eq!(rec.len(), n, "request conservation (completed + shed)");
    let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
    for r in rec.records() {
        assert!(r.started_at >= r.sent_at - 1e-6, "start before send");
        assert!(r.finished_at >= r.started_at, "finish before start");
        if r.shed {
            assert_eq!(r.tokens, 0, "shed requests generate nothing");
        }
    }
    let s = rec.slo_attainment();
    let shed_deadlined = rec
        .records()
        .iter()
        .filter(|r| r.shed && r.deadline.is_some())
        .count();
    assert_eq!(
        s.met + s.missed + shed_deadlined,
        s.deadlined,
        "attainment counters must conserve: {s:?}"
    );
    assert_eq!(s.completed + s.shed, n);
}

/// Dense stub traffic for the e2e server tests: 2 ms mean inter-arrival
/// over the stub prompt pool.
pub fn quick_stub_trace(n: usize, seed: u64) -> Trace {
    stationary_trace(&stub_prompt_pool(), n, seed, 0.002, 1.0)
}

/// The small stub server config the e2e tests run (4-row cap, 8 tokens
/// per request) at an explicit KV layout.
pub fn stub_server_cfg(mode: SchedulingMode, kv_layout: KvLayout) -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        max_new_tokens: 8,
        mode,
        kv_layout,
        engine: EngineConfig {
            kv_layout,
            ..EngineConfig::default()
        },
        ..ServerConfig::default()
    }
}

/// The greedy reference chain of the stub LLM: the exact tokens any
/// lossless scheduling of a prompt ending in `start` must produce.
pub fn llm_chain(spec: &StubSpec, start: i32, n: usize) -> Vec<i32> {
    let m = StubModel::new(spec.clone(), StubRole::Llm);
    let mut out = Vec::with_capacity(n);
    let mut cur = start;
    for _ in 0..n {
        cur = m.llm_next(cur);
        out.push(cur);
    }
    out
}

/// Every id `0..n` served exactly once, with causal timestamps.
pub fn assert_conserves_ids(rec: &LatencyRecorder, n: usize) {
    assert_eq!(rec.len(), n, "request conservation");
    let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
    for r in rec.records() {
        assert!(r.started_at >= r.sent_at - 1e-6, "start before send");
        assert!(r.finished_at >= r.started_at, "finish before start");
    }
}

/// Block-accounting leak check over an experiment outcome: under the
/// paged layout every block must be back on the free list at shutdown.
/// (Dense outcomes carry no stats — nothing to check.)
pub fn assert_no_block_leaks(out: &ExperimentOutcome) {
    if let Some(stats) = &out.kv_blocks {
        assert!(
            stats.is_leak_free(),
            "KV blocks leaked or double-freed: {stats:?}"
        );
    }
}
