//! Deterministic PRNG + distribution samplers (the `rand` crate family is
//! unavailable offline).
//!
//! * [`Pcg64`] — PCG-XSL-RR 128/64: fast, statistically solid, tiny state,
//!   streams via odd increments.  Used everywhere randomness is needed so
//!   every experiment is reproducible from a seed recorded in its output.
//! * Samplers: uniform, exponential, normal (Box-Muller) and **Gamma**
//!   (Marsaglia-Tsang squeeze, with the alpha<1 boost) — the paper's client
//!   draws request inter-arrival times from a Gamma distribution whose
//!   shape/scale are set from the target mean interval and coefficient of
//!   variation (Sec. 5.3).

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
/// `PCG_MULT^-1 mod 2^128` (the multiplier is odd, hence invertible):
/// lets [`DrawBuffer::refund`] step the state transition backwards.
const PCG_MULT_INV: u128 = 0x07dd_a22b_9397_9860_98ab_c8b0_716e_ac8d;

impl Pcg64 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream: generators with different `stream` values are
    /// uncorrelated even with the same seed (used to give each simulated
    /// request source its own arrival process).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut g = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        g.next_u64();
        g.state = g.state.wrapping_add(seed as u128);
        g.next_u64();
        g
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 64-bit modulo bias at our n (< 2^20) is < 2^-44.
        (self.next_u64() % n as u64) as usize
    }

    /// Bulk fill: `out.len()` sequential raw draws.  Bit-identical to
    /// calling [`Pcg64::next_u64`] `out.len()` times — the hot loops use
    /// this to amortize per-call overhead without perturbing any pinned
    /// stream.
    pub fn fill_u64s(&mut self, out: &mut [u64]) {
        let mut state = self.state;
        for slot in out.iter_mut() {
            state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
            let rot = (state >> 122) as u32;
            let xored = ((state >> 64) as u64) ^ (state as u64);
            *slot = xored.rotate_right(rot);
        }
        self.state = state;
    }

    /// Bulk uniform-below fill: `out.len()` sequential draws in [0, n),
    /// with the modulo constant hoisted out of the per-token loop.
    /// Stream-identical to calling [`Pcg64::next_below`] per element.
    pub fn fill_below(&mut self, n: usize, out: &mut [u32]) {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        let n = n as u64;
        let mut state = self.state;
        for slot in out.iter_mut() {
            state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
            let rot = (state >> 122) as u32;
            let xored = ((state >> 64) as u64) ^ (state as u64);
            *slot = (xored.rotate_right(rot) % n) as u32;
        }
        self.state = state;
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Standard exponential (mean 1).
    pub fn next_exp(&mut self) -> f64 {
        // inverse CDF; guard the log(0) corner
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln()
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt()
                * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang.
    pub fn next_gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "gamma params must be positive");
        if shape < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.next_gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2
                || u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Shuffle a slice (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len())]
    }
}

/// Anything that yields uniform f64 draws in [0, 1).  Lets samplers such
/// as `AcceptanceProcess::sample` consume either a bare [`Pcg64`] or a
/// pre-filled [`DrawBuffer`] without changing the draw stream.
pub trait F64Source {
    fn next_f64(&mut self) -> f64;
}

impl F64Source for Pcg64 {
    #[inline]
    fn next_f64(&mut self) -> f64 {
        Pcg64::next_f64(self)
    }
}

/// A reusable buffer of raw PRNG draws, refilled in bulk once per round
/// instead of pulling from the generator per token.
///
/// Draw-order contract: [`DrawBuffer::ensure`] keeps unconsumed draws (in
/// order) and tops the buffer up with `fill_u64s`, so consumption through
/// [`DrawBuffer::next_u64`] / [`F64Source::next_f64`] is **bit-identical**
/// to calling the generator sequentially — leftovers are always spent
/// before freshly filled draws.  That is what keeps every pinned seed in
/// the DES stable across the batched-draw refactor.
#[derive(Debug, Default)]
pub struct DrawBuffer {
    buf: Vec<u64>,
    pos: usize,
}

impl DrawBuffer {
    pub fn new() -> Self {
        DrawBuffer { buf: Vec::new(), pos: 0 }
    }

    /// Number of unconsumed draws currently buffered.
    pub fn available(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Guarantee at least `n` unconsumed draws are buffered, pulling the
    /// shortfall from `rng` in one bulk fill.  Steady-state (buffer
    /// already at its high-water mark) this never allocates.
    pub fn ensure(&mut self, rng: &mut Pcg64, n: usize) {
        let avail = self.available();
        if avail >= n {
            return;
        }
        // compact leftovers to the front, then bulk-fill the shortfall
        self.buf.copy_within(self.pos.., 0);
        self.buf.truncate(avail);
        self.pos = 0;
        let old = self.buf.len();
        self.buf.resize(n, 0);
        rng.fill_u64s(&mut self.buf[old..]);
    }

    /// Next buffered raw draw.  Panics on underflow — callers `ensure`
    /// the round's worth of draws up front.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    /// Hand unconsumed draws back to the generator: steps `rng`'s state
    /// transition backwards once per leftover draw (the PCG multiplier is
    /// odd, hence invertible mod 2^128) and empties the buffer.  After a
    /// refund the generator state is **exactly** what sequential draws
    /// would have produced, so callers that share `rng` beyond a buffered
    /// region observe no difference at all.
    pub fn refund(&mut self, rng: &mut Pcg64) {
        for _ in 0..self.available() {
            rng.state = rng.state.wrapping_sub(rng.inc).wrapping_mul(PCG_MULT_INV);
        }
        self.buf.clear();
        self.pos = 0;
    }
}

impl F64Source for DrawBuffer {
    /// Same mapping as [`Pcg64::next_f64`], applied to buffered draws.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Inter-arrival sampler with a given mean and coefficient of variation,
/// exactly the paper's client model (Sec. 5.3): intervals ~ Gamma with
/// `shape = 1/CV^2`, `scale = mean * CV^2` so that E = mean, std/E = CV.
#[derive(Debug, Clone)]
pub struct GammaIntervals {
    pub mean: f64,
    pub cv: f64,
    shape: f64,
    scale: f64,
}

impl GammaIntervals {
    pub fn new(mean: f64, cv: f64) -> Self {
        assert!(mean > 0.0 && cv > 0.0);
        let shape = 1.0 / (cv * cv);
        GammaIntervals {
            mean,
            cv,
            shape,
            scale: mean / shape,
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.next_gamma(self.shape, self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_std(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, var.sqrt())
    }

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        assert_eq!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
        let mut c = Pcg64::with_stream(7, 99);
        assert_ne!(b.next_u64(), c.next_u64());
    }

    #[test]
    fn fill_u64s_matches_sequential_next_u64() {
        let mut a = Pcg64::with_stream(42, 17);
        let mut b = Pcg64::with_stream(42, 17);
        let seq: Vec<u64> = (0..257).map(|_| a.next_u64()).collect();
        let mut bulk = vec![0u64; 257];
        b.fill_u64s(&mut bulk[..100]);
        b.fill_u64s(&mut bulk[100..101]);
        b.fill_u64s(&mut bulk[101..]);
        assert_eq!(seq, bulk);
        // and the generators land in the same state
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fill_below_matches_sequential_next_below() {
        let mut a = Pcg64::new(5);
        let mut b = Pcg64::new(5);
        let seq: Vec<u32> = (0..300).map(|_| a.next_below(512) as u32).collect();
        let mut bulk = vec![0u32; 300];
        b.fill_below(512, &mut bulk);
        assert_eq!(seq, bulk);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn draw_buffer_preserves_the_sequential_stream() {
        let mut plain = Pcg64::new(99);
        let mut buffered = Pcg64::new(99);
        let mut db = DrawBuffer::new();
        let mut got = Vec::new();
        // uneven ensure/consume cycles: leftovers must drain in order
        // before freshly filled draws
        for (ensure_n, take_n) in [(8, 3), (4, 6), (10, 2), (5, 5), (1, 12)] {
            db.ensure(&mut buffered, ensure_n.max(take_n));
            for _ in 0..take_n {
                got.push(db.next_u64());
            }
        }
        let want: Vec<u64> = (0..got.len()).map(|_| plain.next_u64()).collect();
        assert_eq!(got, want);
        // f64 mapping agrees with the generator's
        db.ensure(&mut buffered, 1);
        assert_eq!(F64Source::next_f64(&mut db), plain.next_f64());
    }

    #[test]
    fn draw_buffer_refund_restores_the_sequential_state() {
        let mut plain = Pcg64::with_stream(7, 3);
        let mut buffered = Pcg64::with_stream(7, 3);
        let mut db = DrawBuffer::new();
        // over-fill, consume a prefix, refund the rest
        db.ensure(&mut buffered, 40);
        let got: Vec<u64> = (0..13).map(|_| db.next_u64()).collect();
        db.refund(&mut buffered);
        assert_eq!(db.available(), 0);
        let want: Vec<u64> = (0..13).map(|_| plain.next_u64()).collect();
        assert_eq!(got, want);
        // the refunded generator continues exactly where sequential
        // consumption would have left it
        assert_eq!(
            (0..8).map(|_| buffered.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| plain.next_u64()).collect::<Vec<_>>()
        );
        // refund on an empty buffer is a no-op
        db.refund(&mut buffered);
        assert_eq!(buffered.next_u64(), plain.next_u64());
    }

    #[test]
    fn uniform_is_in_range_and_roughly_uniform() {
        let mut g = Pcg64::new(1);
        let mut buckets = [0usize; 10];
        for _ in 0..20_000 {
            let u = g.next_f64();
            assert!((0.0..1.0).contains(&u));
            buckets[(u * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            assert!((1600..2400).contains(&b), "bucket {b} too skewed");
        }
    }

    #[test]
    fn next_range_covers_bounds() {
        let mut g = Pcg64::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = g.next_range(4, 6);
            assert!((4..=6).contains(&v));
            seen_lo |= v == 4;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg64::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| g.next_normal()).collect();
        let (m, s) = mean_std(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn exponential_moments() {
        let mut g = Pcg64::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| g.next_exp()).collect();
        let (m, s) = mean_std(&xs);
        assert!((m - 1.0).abs() < 0.03, "mean {m}");
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn gamma_matches_requested_mean_and_cv() {
        for &(mean, cv) in &[(0.1, 0.5), (0.4, 1.0), (0.8, 2.0), (0.2, 5.0)] {
            let gi = GammaIntervals::new(mean, cv);
            let mut g = Pcg64::new(17);
            let xs: Vec<f64> = (0..200_000).map(|_| gi.sample(&mut g)).collect();
            let (m, s) = mean_std(&xs);
            assert!(
                (m - mean).abs() / mean < 0.05,
                "mean {m} != {mean} (cv {cv})"
            );
            assert!(
                (s / m - cv).abs() / cv < 0.10,
                "cv {} != {cv} (mean {mean})",
                s / m
            );
            assert!(xs.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn gamma_small_shape_boost_path() {
        // cv = 5 => shape = 0.04 < 1 exercises the boost branch
        let mut g = Pcg64::new(23);
        let xs: Vec<f64> = (0..100_000).map(|_| g.next_gamma(0.04, 1.0)).collect();
        let (m, _) = mean_std(&xs);
        assert!((m - 0.04).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Pcg64::new(31);
        let mut v: Vec<usize> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
