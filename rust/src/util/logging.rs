//! Leveled stderr logger with wall-clock offsets (the `log`/`env_logger`
//! pair is replaced by this ~free substitute; level set via
//! `SPECBATCH_LOG={error,warn,info,debug,trace}` or programmatically).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    let level = match std::env::var("SPECBATCH_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
    let _ = START.set(Instant::now());
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
