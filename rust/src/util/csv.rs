//! CSV writer for bench/experiment outputs under `results/`.
//!
//! Every figure-reproduction bench emits one CSV whose columns mirror the
//! paper's axes, so plots can be regenerated with any tool.  Quoting
//! follows RFC 4180 (only when needed).

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::Result;

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row of already-stringified cells (must match header width).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: anything Display.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for r in &self.rows {
            write_record(&mut out, r);
        }
        out
    }

    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())?;
        Ok(())
    }
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            let _ = write!(out, "\"{}\"", c.replace('"', "\"\""));
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

/// Format a float with enough precision for plotting without noise.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e6 || x.abs() < 1e-4 {
        format!("{x:.6e}")
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "2".into()]);
        c.row_display(&[&3.5, &"x"]);
        assert_eq!(c.to_string(), "a,b\n1,2\n3.5,x\n");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn quotes_when_needed() {
        let mut c = Csv::new(&["x"]);
        c.row(&["has,comma".into()]);
        c.row(&["has \"quote\"".into()]);
        assert_eq!(
            c.to_string(),
            "x\n\"has,comma\"\n\"has \"\"quote\"\"\"\n"
        );
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn panics_on_width_mismatch() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1.5), "1.500000");
        assert!(f(1e-7).contains('e'));
        assert!(f(2e7).contains('e'));
    }
}
