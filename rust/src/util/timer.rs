//! Lightweight section timers for the §Perf profiling pass.
//!
//! [`Stopwatch`] accumulates per-section wall time across many iterations
//! of the serving loop (ssm/llm/host-staging/acceptance/…), giving the
//! breakdown that drives the hot-path optimization without external
//! profilers.  Overhead is one `Instant::now()` pair per section.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulating multi-section stopwatch.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    sections: BTreeMap<&'static str, (Duration, u64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a section label.
    pub fn time<T>(&mut self, section: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(section, t0.elapsed());
        out
    }

    pub fn add(&mut self, section: &'static str, d: Duration) {
        let e = self.sections.entry(section).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    pub fn total(&self, section: &str) -> Duration {
        self.sections
            .get(section)
            .map(|(d, _)| *d)
            .unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, section: &str) -> u64 {
        self.sections.get(section).map(|(_, c)| *c).unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Stopwatch) {
        for (k, (d, c)) in &other.sections {
            let e = self.sections.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    pub fn reset(&mut self) {
        self.sections.clear();
    }

    /// Pretty per-section report sorted by total time, with percentages.
    pub fn report(&self) -> String {
        let grand: f64 = self
            .sections
            .values()
            .map(|(d, _)| d.as_secs_f64())
            .sum();
        let mut rows: Vec<_> = self.sections.iter().collect();
        rows.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let mut out = String::from("section                     total      calls   mean       share\n");
        for (name, (d, c)) in rows {
            let t = d.as_secs_f64();
            let mean = if *c > 0 { t / *c as f64 } else { 0.0 };
            let share = if grand > 0.0 { 100.0 * t / grand } else { 0.0 };
            out.push_str(&format!(
                "{name:<26} {t:>9.4}s {c:>8} {:>9.3}ms {share:>6.1}%\n",
                mean * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sections() {
        let mut sw = Stopwatch::new();
        let v = sw.time("a", || 41 + 1);
        assert_eq!(v, 42);
        sw.add("a", Duration::from_millis(5));
        sw.add("b", Duration::from_millis(2));
        assert_eq!(sw.count("a"), 2);
        assert_eq!(sw.count("b"), 1);
        assert!(sw.total("a") >= Duration::from_millis(5));
        assert_eq!(sw.total("missing"), Duration::ZERO);
        let rep = sw.report();
        assert!(rep.contains('a') && rep.contains('b'));
    }

    #[test]
    fn merge_combines() {
        let mut a = Stopwatch::new();
        a.add("x", Duration::from_millis(1));
        let mut b = Stopwatch::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.total("y"), Duration::from_millis(3));
    }
}
