//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Full RFC 8259 value model with the subset of ergonomics this crate
//! needs: typed accessors with contextual errors, pretty/compact writing,
//! and escape handling (incl. `\uXXXX` with surrogate pairs).  The
//! artifact manifest, dataset, configs, goldens and all bench CSV/JSON
//! sidecars go through this module, so it is tested accordingly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept sorted (BTreeMap) so writing
/// is deterministic — handy for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse {
        pos: usize,
        msg: String,
    },
    Type {
        path: String,
        expected: &'static str,
        found: &'static str,
    },
    Missing {
        path: String,
    },
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type {
                path,
                expected,
                found,
            } => write!(
                f,
                "json type error at {path}: expected {expected}, found {found}"
            ),
            JsonError::Missing { path } => write!(f, "json missing key {path:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    fn type_err<T>(&self, expected: &'static str) -> Result<T> {
        Err(JsonError::Type {
            path: String::from("$"),
            expected,
            found: self.type_name(),
        })
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => other.type_err("number"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || !f.is_finite() {
            return self.type_err("integer");
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            return self.type_err("non-negative integer");
        }
        Ok(i as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => other.type_err("bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => other.type_err("string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => other.type_err("array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => other.type_err("object"),
        }
    }

    /// `obj["key"]` with a Missing error instead of a panic.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing { path: key.to_string() })
    }

    /// Optional key: Ok(None) when absent or null.
    pub fn get_opt(&self, key: &str) -> Result<Option<&Json>> {
        Ok(match self.as_obj()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        })
    }

    pub fn get_usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usize_slice(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<Json> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }

    pub fn write_file(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Compact single-line serialization.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Human-readable serialization with 1-space indent (matches the
    /// Python `json.dump(indent=1)` used by aot.py closely enough for
    /// diffing).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp like most writers refuse to.  We emit
        // null to keep documents valid and make the anomaly visible.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Parse {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            Some(got) => Err(self.err(format!(
                "expected '{}', found '{}'",
                b as char, got as char
            ))),
            None => Err(self.err(format!("expected '{}', found EOF", b as char))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected {word})")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected EOF")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid UTF-8 byte")),
                        };
                        if start + width > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let chunk = &self.bytes[start..start + width];
                        let s = std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                        out.push_str(s);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("EOF in \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_i64().unwrap(), 2);
        assert_eq!(*arr[2].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "quote \" back \\ newline \n tab \t unicode \u{1F600} dész";
        let doc = Json::Str(s.to_string()).compact();
        assert_eq!(Json::parse(&doc).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap().as_str().unwrap(),
            "Aé"
        );
        // surrogate pair for 😀
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str().unwrap(),
            "😀"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] trailing").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse(r#""\ud800x""#).is_err()); // lone surrogate
        assert!(Json::parse("01x").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("nums", Json::from_f64_slice(&[1.0, 2.5, -3.0])),
            ("flag", Json::Bool(true)),
            ("name", Json::Str("specbatch".into())),
            ("nested", Json::obj(vec![("x", Json::Null)])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        for doc in [v.compact(), v.pretty()] {
            assert_eq!(Json::parse(&doc).unwrap(), v);
        }
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a": 1.5, "b": -2}"#).unwrap();
        assert!(v.get("a").unwrap().as_i64().is_err()); // fractional
        assert!(v.get("b").unwrap().as_usize().is_err()); // negative
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert_eq!(v.get_opt("missing").unwrap(), None);
    }

    #[test]
    fn integers_written_without_decimal_point() {
        assert_eq!(Json::Num(3.0).compact(), "3");
        assert_eq!(Json::Num(3.25).compact(), "3.25");
        assert_eq!(Json::Num(f64::NAN).compact(), "null");
    }
}
