//! Substrate utilities, hand-rolled because the offline cargo registry
//! only carries the `xla` crate closure (see DESIGN.md §Substitutions):
//!
//! * [`json`]    — JSON parser/writer (replaces serde_json)
//! * [`prng`]    — PCG64 + Gamma/exponential/normal samplers (replaces rand)
//! * [`cli`]     — declarative argument parser (replaces clap)
//! * [`csv`]     — RFC-4180 CSV writer for bench outputs
//! * [`stats`]   — summaries, percentiles, linear & power-law fits
//! * [`logging`] — leveled stderr logger (replaces log/env_logger)
//! * [`timer`]   — accumulating section stopwatch for the §Perf pass

pub mod cli;
pub mod csv;
pub mod json;
pub mod logging;
pub mod prng;
pub mod stats;
pub mod timer;
