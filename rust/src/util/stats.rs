//! Statistics helpers: summary stats, percentiles, and the least-squares
//! fits the paper's analytical model needs (Sec. 3.3):
//!
//! * [`linear_fit`] — `y = a*x + b` for `t_L(b, s) ≈ α_b·s + β` (Fig. 3)
//! * [`power_fit`]  — `y = c * x^γ` via log-log linear regression for
//!   `l(s) ≈ c·s^γ` (Fig. 2; the paper reports `0.9·s^0.548`)

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summary(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    summary(xs).mean
}

/// Percentile by linear interpolation on the sorted sample (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Percentile on an already sorted slice (avoids re-sorting in loops).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0) / 100.0;
    let idx = q * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = idx - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Least-squares `y = slope*x + intercept`; returns (slope, intercept, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points for a linear fit");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    assert!(sxx > 0.0, "degenerate x values in linear fit");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (slope, intercept, r2)
}

/// Least-squares power-law `y = c * x^gamma` via regression in log-log
/// space; returns (c, gamma, r2_loglog).  Requires strictly positive data.
pub fn power_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let (lx, ly): (Vec<f64>, Vec<f64>) = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .unzip();
    assert!(lx.len() >= 2, "need >= 2 positive points for a power fit");
    let (gamma, lnc, r2) = linear_fit(&lx, &ly);
    (lnc.exp(), gamma, r2)
}

/// Exponential-moving-average smoother (used by the timeline plots).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(summary(&[]).mean.is_nan());
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        // single element
        assert_eq!(percentile(&[42.0], 75.0), 42.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b - 7.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_noisy_r2_below_one() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [2.1, 3.9, 6.2, 7.8, 10.3];
        let (a, _b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 0.15);
        assert!(r2 > 0.99 && r2 < 1.0);
    }

    #[test]
    fn power_fit_recovers_paper_curve() {
        // the paper's measured acceptance curve: l(s) = 0.9 * s^0.548
        let xs: Vec<f64> = (1..=8).map(|s| s as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|s| 0.9 * s.powf(0.548)).collect();
        let (c, gamma, r2) = power_fit(&xs, &ys);
        assert!((c - 0.9).abs() < 1e-9, "c={c}");
        assert!((gamma - 0.548).abs() < 1e-9, "gamma={gamma}");
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn power_fit_skips_nonpositive_points() {
        let xs = [0.0, 1.0, 2.0, 4.0];
        let ys = [0.0, 2.0, 4.0, 8.0];
        let (c, gamma, _) = power_fit(&xs, &ys);
        assert!((c - 2.0).abs() < 1e-9);
        assert!((gamma - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0, 7.5]);
        assert!(ema(&[], 0.3).is_empty());
    }
}
