//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed getters, defaults, and a generated usage string.  Each
//! subcommand in `main.rs` declares an [`ArgSpec`] so `--help` output stays
//! accurate.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Declarative description of one option (for usage text + validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Specification of a (sub)command's arguments.
#[derive(Debug, Clone, Default)]
pub struct ArgSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl ArgSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        ArgSpec {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let tail = match (&o.default, o.is_flag) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, tail));
        }
        s
    }

    /// Parse argv against this spec.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();

        let known_flag = |n: &str| {
            self.opts.iter().any(|o| o.name == n && o.is_flag)
        };
        let known_opt = |n: &str| {
            self.opts.iter().any(|o| o.name == n && !o.is_flag)
        };

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    if !known_opt(k) {
                        bail!("unknown option --{k}\n\n{}", self.usage());
                    }
                    values.insert(k.to_string(), v.to_string());
                } else if known_flag(body) {
                    flags.push(body.to_string());
                } else if known_opt(body) {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow!("--{body} expects a value"))?;
                    values.insert(body.to_string(), v.clone());
                } else {
                    bail!("unknown option --{body}\n\n{}", self.usage());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        // defaults + required check
        for o in &self.opts {
            if o.is_flag {
                continue;
            }
            if !values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        values.insert(o.name.to_string(), d.to_string());
                    }
                    None => bail!("missing required --{}\n\n{}", o.name, self.usage()),
                }
            }
        }
        Ok(Args {
            values,
            flags,
            positional,
        })
    }
}

/// Parsed arguments with typed getters.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Result<&str> {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing --{key}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .parse()
            .with_context(|| format!("--{key} must be an unsigned integer"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64> {
        self.get(key)?
            .parse()
            .with_context(|| format!("--{key} must be an unsigned integer"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?
            .parse()
            .with_context(|| format!("--{key} must be a number"))
    }

    /// Comma-separated list of unsigned integers, e.g. `--buckets 1,2,4`.
    pub fn get_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .with_context(|| format!("--{key}: bad integer {s:?}"))
            })
            .collect()
    }

    /// Comma-separated list of floats.
    pub fn get_f64_list(&self, key: &str) -> Result<Vec<f64>> {
        self.get(key)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .with_context(|| format!("--{key}: bad number {s:?}"))
            })
            .collect()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "a test command")
            .opt("batch", "4", "batch size")
            .opt("rate", "0.5", "arrival rate")
            .req("name", "a required value")
            .flag("verbose", "log more")
    }

    fn argv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = spec()
            .parse(&argv(&["--batch", "8", "--name=run1", "--verbose", "pos"]))
            .unwrap();
        assert_eq!(a.get_usize("batch").unwrap(), 8);
        assert_eq!(a.get("name").unwrap(), "run1");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn applies_defaults() {
        let a = spec().parse(&argv(&["--name", "x"])).unwrap();
        assert_eq!(a.get_usize("batch").unwrap(), 4);
        assert!((a.get_f64("rate").unwrap() - 0.5).abs() < 1e-12);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing() {
        assert!(spec().parse(&argv(&["--name", "x", "--bogus", "1"])).is_err());
        assert!(spec().parse(&argv(&[])).is_err()); // missing --name
        assert!(spec().parse(&argv(&["--name"])).is_err()); // dangling value
    }

    #[test]
    fn parses_lists() {
        let a = spec()
            .parse(&argv(&["--name", "x", "--batch=1"]))
            .unwrap();
        assert_eq!(a.get_usize_list("batch").unwrap(), vec![1]);
        let spec2 = ArgSpec::new("t", "t").opt("cvs", "0.5,1,2,5", "cv list");
        let b = spec2.parse(&argv(&[])).unwrap();
        assert_eq!(b.get_f64_list("cvs").unwrap(), vec![0.5, 1.0, 2.0, 5.0]);
    }

    #[test]
    fn help_is_an_error_with_usage() {
        let err = spec().parse(&argv(&["--help"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--batch"));
        assert!(msg.contains("required"));
    }
}
