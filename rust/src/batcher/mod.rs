//! Continuous (iteration-level) batching — the serving-side tentpole.
//!
//! The paper's server is batch-to-completion: while a batch generates its
//! 128 tokens, new arrivals queue for seconds, and the speculation length
//! is frozen with the batch.  [`ContinuousBatcher`] instead owns per-row
//! request lifecycles and works at **round granularity**, in the style of
//! iteration-level schedulers (Orca) and batched speculation on dynamic
//! batches (BASS, arXiv:2404.15778):
//!
//! * **retire** — finished rows leave the batch the moment they freeze,
//!   immediately freeing capacity;
//! * **admit** — queued requests enter free rows at the next round
//!   boundary instead of waiting for the whole batch to complete;
//! * **reshape** — when queue pressure outgrows the current bucket, the
//!   epoch is re-opened at the next larger bucket and unfinished rows are
//!   carried over: under the dense KV layout their contexts are
//!   re-ingested through chunked verify calls (O(context)), under the
//!   paged layout ([`crate::kvcache`]) their block chains are remapped
//!   into the new epoch's tables (O(1), zero token re-ingestion);
//! * **adapt** — every round re-queries the [`SpeculationPolicy`] with
//!   the *live* batch size and feeds the round's outcome back through
//!   its `observe` edge, so `s` tracks load within a single epoch (the
//!   paper's LUT regime) and online policies keep learning as the
//!   workload drifts.
//!
//! The batcher is clock-agnostic: the caller supplies `now` (real server:
//! the experiment clock; tests: a virtual clock).  The discrete-event
//! mirror for paper-scale sweeps lives in
//! [`crate::simulator::des::simulate_trace_continuous`].

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::admission::{
    apply_plan_to_queue, predicted_finish, predicted_token_time, AdmissionController,
    AdmissionView, Candidate, Fifo,
};
use crate::engine::{AdmitRequest, BatchState, Engine};
use crate::metrics::RoundEvent;
use crate::policy::SpeculationPolicy;
use crate::telemetry::attrib::Waterfall;
use crate::telemetry::{PhaseKind, Telemetry};

/// Batcher knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// cap on concurrently live requests (paper: 16, memory-bound)
    pub max_batch: usize,
    /// generation budget per request
    pub max_new_tokens: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_new_tokens: 128,
        }
    }
}

/// A request waiting for admission.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// client send time on the experiment clock (t_a)
    pub sent_at: f64,
    /// absolute deadline on the experiment clock (None = no SLO)
    pub deadline: Option<f64>,
    /// seconds spent in a dispatcher before reaching this batcher's queue
    /// (cluster paths; 0 on single-worker paths) — split out of the queue
    /// component in the request's latency waterfall
    pub route_hop: f64,
    /// workload class tag (0 = default) — rides into the engine slot so
    /// ragged policies can key per-row speculation on it
    pub class: u8,
}

impl BatchRequest {
    /// A deadline-free request (most tests and callers).
    pub fn new(id: u64, prompt: Vec<i32>, sent_at: f64) -> BatchRequest {
        BatchRequest {
            id,
            prompt,
            sent_at,
            deadline: None,
            route_hop: 0.0,
            class: 0,
        }
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub sent_at: f64,
    /// when the request entered a batch row (queueing ends here)
    pub admitted_at: f64,
    pub finished_at: f64,
    /// live batch size right after this request's admission
    pub batch_at_admit: usize,
    /// speculation length the policy chose at that batch size
    pub spec_at_admit: usize,
    /// absolute deadline, if the request carried one
    pub deadline: Option<f64>,
    /// round boundaries admission control deferred it at before admitting
    pub deferred_rounds: usize,
    /// when the row's first generated token was committed (the round
    /// boundary its prefill/ingest completed at) — TTFT numerator
    pub first_token_at: Option<f64>,
    /// sealed latency waterfall: where this request's wall time went
    /// (queue wait, prefill, per-phase decode splits, reshape stalls);
    /// `wf.total()` equals `finished_at - sent_at` by construction
    pub wf: Waterfall,
}

/// A request the admission controller rejected before it ever occupied a
/// batch row (drained via [`ContinuousBatcher::take_shed`]).
#[derive(Debug, Clone)]
pub struct ShedRequest {
    pub id: u64,
    pub sent_at: f64,
    pub deadline: Option<f64>,
    /// experiment-clock time of the shed decision
    pub shed_at: f64,
    /// round boundaries it was deferred at before being shed
    pub deferred_rounds: usize,
}

#[derive(Debug, Clone)]
struct RowMeta {
    id: u64,
    sent_at: f64,
    admitted_at: f64,
    batch_at_admit: usize,
    spec_at_admit: usize,
    deadline: Option<f64>,
    deferred_rounds: usize,
    /// stamped at the first round boundary the row has ≥ 1 generated token
    first_token_at: Option<f64>,
    /// accruing waterfall (sealed against measured latency at retire)
    wf: Waterfall,
}

/// A queued request plus its admission-control state.
#[derive(Debug, Clone)]
struct Queued {
    req: BatchRequest,
    /// round boundaries the controller has deferred this request at
    deferred: usize,
}

struct EpochState {
    state: BatchState,
    /// slot index -> request metadata (None = vacant slot)
    slots: Vec<Option<RowMeta>>,
}

/// The continuous batcher: request queue + at most one active epoch,
/// with queue ordering / deferral / shedding delegated to an
/// [`AdmissionController`] at every round boundary.
pub struct ContinuousBatcher {
    cfg: BatcherConfig,
    ctrl: Box<dyn AdmissionController>,
    queue: VecDeque<Queued>,
    /// shed requests awaiting pickup (see [`ContinuousBatcher::take_shed`])
    shed_buf: Vec<ShedRequest>,
    epoch: Option<EpochState>,
    epoch_seq: usize,
    /// per-round (t, epoch, live, queued, s) timeline for Fig. 6-style
    /// plots and the metrics CSV export
    pub timeline: Vec<RoundEvent>,
    /// KV-transfer totals folded in from completed epochs (see
    /// [`ContinuousBatcher::kv_transfer_totals`])
    reingested_total: usize,
    remapped_total: usize,
    /// admission totals folded in from completed epochs (see
    /// [`ContinuousBatcher::admission_totals`])
    deferred_total: usize,
    shed_total: usize,
    /// reusable buffer for [`Engine::export_rows`] at reshape boundaries
    export_buf: Vec<(usize, AdmitRequest)>,
}

impl ContinuousBatcher {
    /// FIFO admission: bit-for-bit the pre-admission-subsystem batcher.
    pub fn new(cfg: BatcherConfig) -> ContinuousBatcher {
        ContinuousBatcher::with_admission(cfg, Box::new(Fifo))
    }

    /// Batcher with an explicit admission controller.
    pub fn with_admission(
        cfg: BatcherConfig,
        ctrl: Box<dyn AdmissionController>,
    ) -> ContinuousBatcher {
        ContinuousBatcher {
            cfg,
            ctrl,
            queue: VecDeque::new(),
            shed_buf: Vec::new(),
            epoch: None,
            epoch_seq: 0,
            timeline: Vec::new(),
            reingested_total: 0,
            remapped_total: 0,
            deferred_total: 0,
            shed_total: 0,
            export_buf: Vec::new(),
        }
    }

    /// Requests the controller has shed since the last call (the server
    /// loop drains this after every [`ContinuousBatcher::step`] so shed
    /// requests still get a response on the wire).
    pub fn take_shed(&mut self) -> Vec<ShedRequest> {
        std::mem::take(&mut self.shed_buf)
    }

    /// Lifetime `(deferral events, shed requests)` totals across all
    /// epochs, active one included.  Deferrals count one event per
    /// candidate per round boundary it was held back at; both are 0 under
    /// [`Fifo`].
    pub fn admission_totals(&self) -> (usize, usize) {
        let (mut d, mut s) = (self.deferred_total, self.shed_total);
        if let Some(ep) = &self.epoch {
            d += ep.state.stats.deferrals;
            s += ep.state.stats.sheds;
        }
        (d, s)
    }

    /// Deadline pressure for the cluster gauge: queued + live requests
    /// whose SLO is already lost or predicted lost at the current load
    /// (predictions via the policy's fitted model when warm; while cold
    /// only already-late requests count).  Mirrors the DES twin
    /// (`cluster::sim::Shard::slo_pressure`): queued requests owe their
    /// full generation budget, live rows only what remains.
    pub fn slo_pressure(&self, now: f64, policy: &dyn SpeculationPolicy) -> usize {
        let load = self.live_rows() + self.queue.len();
        let t_tok = predicted_token_time(policy, load, self.cfg.max_batch);
        let late = |deadline: Option<f64>, tokens_left: usize| match deadline {
            None => false,
            Some(d) => match t_tok {
                None => d < now,
                Some(t) => now + tokens_left as f64 * t > d,
            },
        };
        let late_queued = self
            .queue
            .iter()
            .filter(|q| late(q.req.deadline, self.cfg.max_new_tokens))
            .count();
        let late_live = self.epoch.as_ref().map_or(0, |ep| {
            ep.slots
                .iter()
                .enumerate()
                .filter(|(slot, meta)| {
                    let Some(meta) = meta else { return false };
                    let generated =
                        ep.state.generated_tokens(*slot).map_or(0, |t| t.len());
                    late(
                        meta.deadline,
                        self.cfg.max_new_tokens.saturating_sub(generated),
                    )
                })
                .count()
        });
        late_queued + late_live
    }

    /// Record admission outcomes into the active epoch's `GenStats`
    /// (or the lifetime fold when no epoch is open).
    fn note_admission(&mut self, deferrals: usize, sheds: usize) {
        if deferrals == 0 && sheds == 0 {
            return;
        }
        if let Some(ep) = &mut self.epoch {
            ep.state.stats.deferrals += deferrals;
            ep.state.stats.sheds += sheds;
        } else {
            self.deferred_total += deferrals;
            self.shed_total += sheds;
        }
    }

    /// Lifetime `(reingested, remapped)` context-token totals across all
    /// epochs, active one included: how many carried tokens went back
    /// through verify calls (dense reshapes) vs were transferred by
    /// block-table remap (paged reshapes).  The equivalence tests pin
    /// `reingested == 0` under the paged layout.
    pub fn kv_transfer_totals(&self) -> (usize, usize) {
        let (mut re, mut rm) = (self.reingested_total, self.remapped_total);
        if let Some(ep) = &self.epoch {
            re += ep.state.stats.reingested_tokens;
            rm += ep.state.stats.remapped_tokens;
        }
        (re, rm)
    }

    /// Fold a dying epoch's transfer + admission counters into the
    /// lifetime totals.
    fn fold_epoch_stats(&mut self, st: &crate::engine::BatchState) {
        self.reingested_total += st.stats.reingested_tokens;
        self.remapped_total += st.stats.remapped_tokens;
        self.deferred_total += st.stats.deferrals;
        self.shed_total += st.stats.sheds;
    }

    /// Enqueue an arrival (considered for admission at the next round
    /// boundary).
    pub fn enqueue(&mut self, req: BatchRequest) {
        self.queue.push_back(Queued { req, deferred: 0 });
    }

    /// True while there is anything to do (live rows or queued requests).
    pub fn has_work(&self) -> bool {
        self.epoch.is_some() || !self.queue.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Live rows of the active epoch (0 when idle).
    pub fn live_rows(&self) -> usize {
        self.epoch.as_ref().map_or(0, |e| e.state.live_rows())
    }

    /// One round boundary: retire finished rows, consult the admission
    /// controller, admit/reshape against the queue, then run one decode
    /// round.  Returns the requests completed at this boundary; sheds
    /// accumulate in [`ContinuousBatcher::take_shed`].
    pub fn step(
        &mut self,
        engine: &mut Engine<'_>,
        policy: &mut dyn SpeculationPolicy,
        now: f64,
    ) -> Result<Vec<FinishedRequest>> {
        let mut finished = Vec::new();
        // cheap handle copy (an `Option<Arc>` bump; `None` when off) so
        // emissions below don't fight the `&mut engine` borrows
        let tel = engine.telemetry().clone();

        // --- retire: free capacity the moment rows finish ---
        let mut drained = false;
        if let Some(ep) = &mut self.epoch {
            for retired in engine.retire_finished(&mut ep.state) {
                let meta = ep.slots[retired.slot]
                    .take()
                    .expect("retired slot carries metadata");
                // seal the waterfall: whatever measured latency the
                // accrued components don't cover lands in `other`, so the
                // decomposition tiles `finished_at - sent_at` exactly
                let mut wf = meta.wf;
                wf.seal(now - meta.sent_at);
                if tel.active() {
                    // deadline slack on the experiment clock; the event
                    // timestamp on the telemetry clock like every other
                    // threaded-path event
                    tel.finish_attrib(
                        tel.now(),
                        meta.id,
                        retired.tokens.len(),
                        false,
                        meta.deadline.map(|d| d - now),
                        Some(wf),
                    );
                }
                finished.push(FinishedRequest {
                    id: meta.id,
                    tokens: retired.tokens,
                    sent_at: meta.sent_at,
                    admitted_at: meta.admitted_at,
                    finished_at: now,
                    batch_at_admit: meta.batch_at_admit,
                    spec_at_admit: meta.spec_at_admit,
                    deadline: meta.deadline,
                    deferred_rounds: meta.deferred_rounds,
                    first_token_at: meta.first_token_at,
                    wf,
                });
            }
            drained = !ep.state.has_live() && self.queue.is_empty();
        }
        if drained {
            // the epoch is over: fold its counters and return its blocks
            let mut ep = self.epoch.take().expect("drained epoch present");
            self.fold_epoch_stats(&ep.state);
            engine.release_state(&mut ep.state);
        }

        // --- admission plan: the controller orders the queue and rules
        //     on deferrals/sheds; the longest feasible prefix of its
        //     Admit verdicts is what the capacity logic below admits ---
        let tel_adm = tel.enabled().then(|| tel.now());
        let admit_n = self.plan_admission(policy, now, &tel);
        if let Some(t0) = tel_adm {
            tel.phase(t0, tel.now() - t0, PhaseKind::Admission);
        }

        // --- admit / reshape at the round boundary ---
        if admit_n > 0 {
            let live = self.live_rows();
            let want = (live + admit_n).min(self.cfg.max_batch);
            let desired_bucket = engine.limits().bucket_for_clamped(want);
            let current_bucket = self.epoch.as_ref().map(|e| e.state.bucket());
            match current_bucket {
                None => {
                    self.start_epoch(engine, policy, desired_bucket, now, Vec::new(), admit_n)?;
                }
                Some(bucket) if desired_bucket > bucket => {
                    // reshape: carry unfinished rows into a larger bucket.
                    // export_rows attaches each row's KV transfer — a
                    // reingest marker under the dense layout, ref-held
                    // block chains under the paged one — and the old
                    // epoch's remaining blocks go back to the pool before
                    // the new epoch allocates (the carried chains stay
                    // alive through the handles' refcounts)
                    let mut old = self.epoch.take().expect("epoch present");
                    let mut export_buf = std::mem::take(&mut self.export_buf);
                    engine.export_rows(&old.state, &mut export_buf);
                    let carry: Vec<(AdmitRequest, RowMeta)> = export_buf
                        .drain(..)
                        .map(|(slot, req)| {
                            let meta = old.slots[slot]
                                .clone()
                                .expect("live slot carries metadata");
                            (req, meta)
                        })
                        .collect();
                    self.export_buf = export_buf;
                    self.fold_epoch_stats(&old.state);
                    engine.release_state(&mut old.state);
                    self.start_epoch(engine, policy, desired_bucket, now, carry, admit_n)?;
                }
                Some(_) => {
                    self.admit_from_queue(engine, policy, now, admit_n)?;
                }
            }
        }

        // --- one decode round ---
        engine.set_round_context(self.epoch_seq, self.queue.len());
        if let Some(ep) = &mut self.epoch {
            if ep.state.has_live() {
                let info = engine.decode_round(&mut ep.state, policy)?;
                if tel.tracing() {
                    // snapshot() allocates, so only ask for it when the
                    // sink actually records
                    tel.policy_fit(tel.now(), policy.snapshot());
                }
                // every live row sat through this round: accrue its
                // phase split into each row's waterfall, and stamp the
                // first round boundary the row holds a generated token
                // (fresh prefills commit theirs this same boundary)
                for (slot, meta) in ep.slots.iter_mut().enumerate() {
                    let Some(meta) = meta else { continue };
                    meta.wf.add_round_split(
                        info.phases.catch_up,
                        info.phases.draft,
                        info.phases.verify,
                        info.phases.accept,
                    );
                    if meta.first_token_at.is_none()
                        && ep.state.generated_tokens(slot).map_or(0, |t| t.len()) > 0
                    {
                        meta.first_token_at = Some(now);
                    }
                }
                self.timeline.push(RoundEvent {
                    t: now,
                    epoch: self.epoch_seq,
                    live: info.live,
                    width: info.width,
                    queued: self.queue.len(),
                    s: info.s,
                    drafted: info.drafted,
                    accepted: info.accepted,
                    round_cost: info.round_time,
                    kv_blocks: ep.state.kv_blocks_in_use(),
                });
            }
        }
        Ok(finished)
    }

    /// Consult the admission controller over the current queue.  Sheds
    /// leave the queue into the shed buffer, the remaining queue is
    /// reordered to `[admits… defers…]` in plan priority order, deferral
    /// counters bump, and the number of Admit verdicts is returned (the
    /// prefix of the queue the capacity logic may admit this boundary).
    ///
    /// A FIFO plan (identity order, all Admit) leaves the queue untouched
    /// — the pre-subsystem batcher's behaviour, bit for bit.
    fn plan_admission(
        &mut self,
        policy: &dyn SpeculationPolicy,
        now: f64,
        tel: &Telemetry,
    ) -> usize {
        if self.queue.is_empty() {
            return 0;
        }
        let live = self.live_rows();
        let candidates: Vec<Candidate> = self
            .queue
            .iter()
            .map(|q| Candidate {
                id: q.req.id,
                sent_at: q.req.sent_at,
                deadline: q.req.deadline,
                prompt_len: q.req.prompt.len(),
                tokens_left: self.cfg.max_new_tokens,
                deferred: q.deferred,
            })
            .collect();
        let view = AdmissionView {
            now,
            live,
            max_batch: self.cfg.max_batch,
            policy,
        };
        let plan = self.ctrl.plan(&candidates, &view);
        let queue: Vec<Queued> = self.queue.drain(..).collect();
        let out = apply_plan_to_queue(plan, queue, live, |q| q.deferred += 1);
        let n_shed = out.shed.len();
        if tel.active() {
            // per-request verdict events with predicted deadline slack
            // at the post-plan load (what the controller's model saw)
            let t = tel.now();
            let load = live + out.queue.len();
            let fin = predicted_finish(
                policy,
                now,
                self.cfg.max_new_tokens,
                load,
                self.cfg.max_batch,
            );
            let slack = |deadline: Option<f64>| match (deadline, fin) {
                (Some(d), Some(f)) => Some(d - f),
                _ => None,
            };
            for q in &out.shed {
                tel.admission(
                    t,
                    q.req.id,
                    "shed",
                    q.req.deadline,
                    slack(q.req.deadline),
                    q.deferred,
                );
                // the shed IS the request's terminal event; its whole
                // lifetime was queue wait (plus any dispatcher hop)
                let mut wf = Waterfall::default();
                wf.route_hop = q.req.route_hop;
                wf.queue = (now - q.req.sent_at - q.req.route_hop).max(0.0);
                wf.deferred_rounds = q.deferred;
                wf.seal(now - q.req.sent_at);
                tel.finish_attrib(t, q.req.id, 0, true, q.req.deadline.map(|d| d - now), Some(wf));
            }
            for (i, q) in out.queue.iter().enumerate() {
                let verdict = if i < out.admit_n { "admit" } else { "defer" };
                tel.admission(
                    t,
                    q.req.id,
                    verdict,
                    q.req.deadline,
                    slack(q.req.deadline),
                    q.deferred,
                );
            }
        }
        for q in out.shed {
            self.shed_buf.push(ShedRequest {
                id: q.req.id,
                sent_at: q.req.sent_at,
                deadline: q.req.deadline,
                shed_at: now,
                deferred_rounds: q.deferred,
            });
        }
        self.queue = out.queue.into();
        self.note_admission(out.deferred, n_shed);
        out.admit_n
    }

    /// Open a fresh epoch at `bucket`: batch-prefill up to `admit_n`
    /// queued requests into the leading slots, then re-admit any
    /// carried-over rows.
    fn start_epoch(
        &mut self,
        engine: &mut Engine<'_>,
        policy: &mut dyn SpeculationPolicy,
        bucket: usize,
        now: f64,
        carry: Vec<(AdmitRequest, RowMeta)>,
        admit_n: usize,
    ) -> Result<()> {
        let capacity = bucket
            .saturating_sub(carry.len())
            .min(self.cfg.max_batch.saturating_sub(carry.len()));
        let n_fresh = admit_n.min(capacity);
        let fresh: Vec<Queued> = self.queue.drain(..n_fresh).collect();
        debug_assert!(!fresh.is_empty() || !carry.is_empty());

        // step() only opens an epoch while the queue is non-empty, and a
        // reshape always leaves at least one slot of fresh capacity (the
        // bucket math in step() guarantees live < max_batch), so there is
        // always a fresh prompt to seed the prefill with.
        if fresh.is_empty() {
            bail!("start_epoch: nothing to admit");
        }
        let may_speculate = policy.wants_speculation();
        self.epoch_seq += 1;
        let mut slots: Vec<Option<RowMeta>> = vec![None; bucket];

        let live_after = fresh.len() + carry.len();
        let spec_now = policy.choose(live_after, engine.limits().max_spec_len(bucket));

        let prompts: Vec<Vec<i32>> = fresh.iter().map(|q| q.req.prompt.clone()).collect();
        let t_prefill = std::time::Instant::now();
        let mut state =
            engine.prefill_rows(&prompts, bucket, may_speculate, self.cfg.max_new_tokens)?;
        let prefill_s = t_prefill.elapsed().as_secs_f64();
        for (i, q) in fresh.iter().enumerate() {
            state.set_class(i, q.req.class);
            let mut wf = Waterfall::default();
            wf.route_hop = q.req.route_hop;
            wf.queue = (now - q.req.sent_at - q.req.route_hop).max(0.0);
            wf.prefill = prefill_s;
            wf.deferred_rounds = q.deferred;
            slots[i] = Some(RowMeta {
                id: q.req.id,
                sent_at: q.req.sent_at,
                admitted_at: now,
                batch_at_admit: live_after,
                spec_at_admit: spec_now,
                deadline: q.req.deadline,
                deferred_rounds: q.deferred,
                first_token_at: None,
                wf,
            });
        }

        if !carry.is_empty() {
            let (reqs, metas): (Vec<AdmitRequest>, Vec<RowMeta>) = carry.into_iter().unzip();
            let t_carry = std::time::Instant::now();
            let carried_slots = engine.admit_rows(&mut state, reqs)?;
            // a carried row stalls through the new epoch's prefill AND its
            // own re-admission: both belong to its reshape component
            let reshape_s = prefill_s + t_carry.elapsed().as_secs_f64();
            for (slot, mut meta) in carried_slots.into_iter().zip(metas) {
                // carried rows keep their original admission metadata
                meta.wf.reshape += reshape_s;
                slots[slot] = Some(meta);
            }
        }

        self.epoch = Some(EpochState { state, slots });
        Ok(())
    }

    /// Admit up to `admit_n` queued requests into the active epoch's
    /// free slots.
    fn admit_from_queue(
        &mut self,
        engine: &mut Engine<'_>,
        policy: &mut dyn SpeculationPolicy,
        now: f64,
        admit_n: usize,
    ) -> Result<()> {
        let ep = self.epoch.as_mut().expect("active epoch");
        let live = ep.state.live_rows();
        let k = ep
            .state
            .free_slots()
            .min(admit_n)
            .min(self.cfg.max_batch.saturating_sub(live));
        if k == 0 {
            return Ok(());
        }
        let fresh: Vec<Queued> = self.queue.drain(..k).collect();
        let reqs: Vec<AdmitRequest> = fresh
            .iter()
            .map(|q| {
                AdmitRequest::fresh(
                    q.req.prompt.clone(),
                    q.req.prompt.len(),
                    self.cfg.max_new_tokens,
                )
                .with_class(q.req.class)
            })
            .collect();
        let t_admit = std::time::Instant::now();
        let slots = engine.admit_rows(&mut ep.state, reqs)?;
        // mid-epoch admission ingests the prompt through chunked verify
        // calls — the row's prefill, even though no fresh epoch opened
        let admit_s = t_admit.elapsed().as_secs_f64();
        let live_after = ep.state.live_rows();
        let spec_now = policy.choose(
            live_after,
            engine.limits().max_spec_len(ep.state.bucket()),
        );
        for (slot, q) in slots.into_iter().zip(fresh) {
            let mut wf = Waterfall::default();
            wf.route_hop = q.req.route_hop;
            wf.queue = (now - q.req.sent_at - q.req.route_hop).max(0.0);
            wf.prefill = admit_s;
            wf.deferred_rounds = q.deferred;
            ep.slots[slot] = Some(RowMeta {
                id: q.req.id,
                sent_at: q.req.sent_at,
                admitted_at: now,
                batch_at_admit: live_after,
                spec_at_admit: spec_now,
                deadline: q.req.deadline,
                deferred_rounds: q.deferred,
                first_token_at: None,
                wf,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineConfig;
    use crate::policy::{Fixed, LutAdaptive, ModelBased};
    use crate::testkit::stub::{StubModel, StubRole, StubSpec};

    fn stub_engine() -> Engine<'static> {
        Engine::stub(StubSpec::default(), EngineConfig::default()).unwrap()
    }

    fn chain(start: i32, n: usize) -> Vec<i32> {
        let m = StubModel::new(StubSpec::default(), StubRole::Llm);
        let mut out = Vec::with_capacity(n);
        let mut cur = start;
        for _ in 0..n {
            cur = m.llm_next(cur);
            out.push(cur);
        }
        out
    }

    fn drive(
        batcher: &mut ContinuousBatcher,
        engine: &mut Engine<'_>,
        policy: &mut dyn SpeculationPolicy,
        arrivals: &mut Vec<(usize, BatchRequest)>, // (step index, request)
    ) -> Vec<FinishedRequest> {
        let mut finished = Vec::new();
        let mut step = 0usize;
        while batcher.has_work() || !arrivals.is_empty() {
            arrivals.retain(|(at, req)| {
                if *at <= step {
                    batcher.enqueue(req.clone());
                    false
                } else {
                    true
                }
            });
            let now = step as f64 * 1e-3;
            finished.extend(batcher.step(engine, policy, now).unwrap());
            step += 1;
            assert!(step < 10_000, "batcher failed to drain");
        }
        finished
    }

    #[test]
    fn serves_every_request_losslessly_across_staggered_arrivals() {
        let mut policy = Fixed(3);
        let mut engine = stub_engine();
        let mut batcher = ContinuousBatcher::new(BatcherConfig {
            max_batch: 8,
            max_new_tokens: 12,
        });
        let prompts: Vec<Vec<i32>> = vec![
            vec![5, 9],
            vec![7],
            vec![40, 41, 42],
            vec![11, 12],
            vec![23],
            vec![30, 8, 4, 19],
        ];
        let mut arrivals: Vec<(usize, BatchRequest)> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                (
                    i * 2, // staggered: arrive while earlier rows decode
                    BatchRequest::new(i as u64, p.clone(), i as f64 * 1e-3),
                )
            })
            .collect();
        let finished = drive(&mut batcher, &mut engine, &mut policy, &mut arrivals);

        assert_eq!(finished.len(), prompts.len());
        for f in &finished {
            let expect = chain(*prompts[f.id as usize].last().unwrap(), 12);
            assert_eq!(f.tokens, expect, "request {} diverged", f.id);
            assert!(f.admitted_at >= f.sent_at - 1e-9);
            assert!(f.finished_at >= f.admitted_at);
            assert!(f.batch_at_admit >= 1 && f.batch_at_admit <= 8);
        }
    }

    #[test]
    fn timeline_shows_batch_growth_within_one_epoch() {
        // one early request, then a burst: the live batch must grow
        // mid-epoch and the adaptive policy must change s accordingly
        let lut = crate::scheduler::Lut::new(
            [(1usize, 5usize), (2, 4), (4, 3), (8, 2), (16, 1)]
                .into_iter()
                .collect(),
        )
        .unwrap();
        let mut policy = LutAdaptive(lut);
        let mut engine = stub_engine();
        let mut batcher = ContinuousBatcher::new(BatcherConfig {
            max_batch: 8,
            max_new_tokens: 24,
        });
        let mut arrivals: Vec<(usize, BatchRequest)> = vec![(
            0,
            BatchRequest::new(0, vec![5], 0.0),
        )];
        for i in 1..6u64 {
            arrivals.push((
                2, // all five arrive while request 0 is mid-generation
                BatchRequest::new(i, vec![6 + i as i32], 1e-3),
            ));
        }
        let finished = drive(&mut batcher, &mut engine, &mut policy, &mut arrivals);
        assert_eq!(finished.len(), 6);

        let lives: Vec<usize> = batcher.timeline.iter().map(|e| e.live).collect();
        let specs: Vec<usize> = batcher.timeline.iter().map(|e| e.s).collect();
        assert!(lives.iter().any(|&l| l == 1), "lives {lives:?}");
        assert!(lives.iter().any(|&l| l > 1), "lives {lives:?}");
        // the adaptive policy changed s as the live batch changed
        assert!(
            specs.iter().collect::<std::collections::BTreeSet<_>>().len() > 1,
            "s never adapted: {specs:?}"
        );
        // carried rows keep generating correctly across the reshape
        for f in &finished {
            assert_eq!(f.tokens.len(), 24);
        }
    }

    #[test]
    fn respects_max_batch_under_burst() {
        let mut policy = Fixed(2);
        let mut engine = stub_engine();
        let mut batcher = ContinuousBatcher::new(BatcherConfig {
            max_batch: 4,
            max_new_tokens: 8,
        });
        let mut arrivals: Vec<(usize, BatchRequest)> = (0..12u64)
            .map(|i| {
                (
                    0usize,
                    BatchRequest::new(i, vec![5 + i as i32], 0.0),
                )
            })
            .collect();
        let finished = drive(&mut batcher, &mut engine, &mut policy, &mut arrivals);
        assert_eq!(finished.len(), 12);
        assert!(batcher.timeline.iter().all(|e| e.live <= 4));
        for f in &finished {
            assert_eq!(f.tokens, chain(5 + f.id as i32, 8));
        }
    }

    /// Paged layout through the full batcher lifecycle: a reshape remaps
    /// carried rows (zero re-ingested tokens), outputs stay lossless, and
    /// the drained batcher leaves the engine's block pools leak-free.
    #[test]
    fn paged_reshape_remaps_and_leaks_nothing() {
        use crate::kvcache::KvLayout;

        let mut policy = Fixed(3);
        let mut engine = Engine::stub(
            StubSpec::default(),
            EngineConfig {
                kv_layout: KvLayout::Paged,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let mut batcher = ContinuousBatcher::new(BatcherConfig {
            max_batch: 8,
            max_new_tokens: 20,
        });
        // one early request, then a burst while it decodes: forces a
        // bucket reshape with a carried row, plus mid-stream retirement
        let mut arrivals: Vec<(usize, BatchRequest)> = vec![(
            0,
            BatchRequest::new(0, vec![5], 0.0),
        )];
        for i in 1..6u64 {
            arrivals.push((
                3,
                BatchRequest::new(i, vec![6 + i as i32], 1e-3),
            ));
        }
        let finished = drive(&mut batcher, &mut engine, &mut policy, &mut arrivals);
        assert_eq!(finished.len(), 6);
        for f in &finished {
            let start = if f.id == 0 { 5 } else { 6 + f.id as i32 };
            assert_eq!(f.tokens, chain(start, 20), "request {} diverged", f.id);
        }
        let (reingested, remapped) = batcher.kv_transfer_totals();
        assert_eq!(reingested, 0, "paged reshape must never re-ingest");
        assert!(remapped > 0, "the reshape should have remapped a carried row");
        // the prefix cache (env-enabled runs) holds block refs by design;
        // leak-freedom is asserted after a full eviction
        engine.clear_prefix_cache();
        let stats = engine.kv_block_stats().expect("paged engine");
        assert!(stats.is_leak_free(), "blocks leaked: {stats:?}");
        // the timeline recorded real block usage
        assert!(batcher.timeline.iter().any(|e| e.kv_blocks > 0));
    }

    /// Scheduling is output-invariant even under the online policy: the
    /// ModelBased choices change WHEN tokens appear, never WHICH.
    #[test]
    fn model_based_policy_serves_losslessly() {
        let lut = crate::scheduler::Lut::new(
            [(1usize, 4usize), (4, 2), (16, 1)].into_iter().collect(),
        )
        .unwrap();
        let mut policy = ModelBased::new(lut);
        let mut engine = stub_engine();
        let mut batcher = ContinuousBatcher::new(BatcherConfig {
            max_batch: 4,
            max_new_tokens: 10,
        });
        let mut arrivals: Vec<(usize, BatchRequest)> = (0..8u64)
            .map(|i| {
                (
                    (i as usize) * 2,
                    BatchRequest::new(i, vec![5 + i as i32, 6], i as f64 * 1e-3),
                )
            })
            .collect();
        let finished = drive(&mut batcher, &mut engine, &mut policy, &mut arrivals);
        assert_eq!(finished.len(), 8);
        for f in &finished {
            assert_eq!(f.tokens, chain(6, 10), "request {} diverged", f.id);
        }
        // the feedback edge ran: the policy accumulated acceptance
        // samples (cold start speculates via the fallback LUT, so every
        // round reports per-row accepted counts) — or, in the unlikely
        // case the CUSUM detector flushed on the very last round, it at
        // least recorded the flush
        let snap = policy.snapshot().expect("model-based always snapshots");
        let samples = snap.get("samples").unwrap().as_f64().unwrap();
        let flushes = snap.get("drift_flushes").unwrap().as_f64().unwrap();
        assert!(
            samples > 0.0 || flushes > 0.0,
            "observe never delivered samples: {snap:?}"
        );
        // the recorded timeline carries the new accepted/cost columns
        assert!(!batcher.timeline.is_empty());
        assert!(batcher
            .timeline
            .iter()
            .any(|e| e.s > 0 && e.accepted <= e.s * e.live));
    }
}
