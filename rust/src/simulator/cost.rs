//! Roofline step-cost model: `t_L(b, s)` and `t_S(b, 1)` for paper-scale
//! models on the [`GpuProfile`]s.
//!
//! One decode/verify forward over `T` tokens × batch `b`:
//!
//! * memory time — the whole weight matrix streams from HBM once per step
//!   (the paper Sec. 1: "the sequential execution paradigm requires GPUs
//!   to load the huge weight matrices from off-chip memory in each
//!   iteration"), plus the KV cache read;
//! * compute time — `2·params` FLOPs per token over `b·T` tokens;
//! * `t = max(mem, compute) + launch_overhead`.
//!
//! The max() is the roofline; its knee at `b·T ≈ crossover_tokens`
//! produces exactly the flat-then-linear `t_L(b, s)` curves of Fig. 3.

use super::hw::GpuProfile;

/// A paper-scale model described by its bulk parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// total parameters
    pub params: f64,
    /// bytes per parameter (2 = fp16 serving)
    pub bytes_per_param: f64,
    /// hidden width & layers, for the KV-cache traffic estimate
    pub d_model: f64,
    pub n_layers: f64,
}

impl ModelProfile {
    pub const OPT_125M: ModelProfile = ModelProfile {
        name: "opt-125m",
        params: 125.0e6,
        bytes_per_param: 2.0,
        d_model: 768.0,
        n_layers: 12.0,
    };
    pub const OPT_1_3B: ModelProfile = ModelProfile {
        name: "opt-1.3b",
        params: 1.3e9,
        bytes_per_param: 2.0,
        d_model: 2048.0,
        n_layers: 24.0,
    };
    pub const OPT_6_7B: ModelProfile = ModelProfile {
        name: "opt-6.7b",
        params: 6.7e9,
        bytes_per_param: 2.0,
        d_model: 4096.0,
        n_layers: 32.0,
    };
    pub const LLAMA_7B: ModelProfile = ModelProfile {
        name: "llama-7b",
        params: 6.74e9,
        bytes_per_param: 2.0,
        d_model: 4096.0,
        n_layers: 32.0,
    };

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        match name {
            "opt-125m" => Some(Self::OPT_125M),
            "opt-1.3b" => Some(Self::OPT_1_3B),
            "opt-6.7b" => Some(Self::OPT_6_7B),
            "llama-7b" => Some(Self::LLAMA_7B),
            _ => None,
        }
    }

    pub fn weight_bytes(&self) -> f64 {
        self.params * self.bytes_per_param
    }

    /// FLOPs to process one token (forward only).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params
    }

    /// KV bytes touched per token position per row.
    pub fn kv_bytes_per_pos(&self) -> f64 {
        2.0 * self.n_layers * self.d_model * self.bytes_per_param
    }
}

/// Cost model binding a model to a GPU.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub model: ModelProfile,
    pub gpu: GpuProfile,
}

impl CostModel {
    pub fn new(model: ModelProfile, gpu: GpuProfile) -> CostModel {
        CostModel { model, gpu }
    }

    /// One forward pass over `tokens_per_row` query tokens with `batch`
    /// rows and `ctx` context length (KV read traffic).
    pub fn forward_time(&self, batch: usize, tokens_per_row: usize, ctx: usize) -> f64 {
        let tokens = (batch * tokens_per_row) as f64;
        let mem = (self.model.weight_bytes()
            + batch as f64 * ctx as f64 * self.model.kv_bytes_per_pos())
            / self.gpu.bw();
        let compute = tokens * self.model.flops_per_token() / self.gpu.flops();
        mem.max(compute) + self.gpu.launch_overhead
    }

    /// `t_L(b, s)`: one verify step (s draft tokens + 1).
    pub fn t_verify(&self, batch: usize, s: usize, ctx: usize) -> f64 {
        self.forward_time(batch, s + 1, ctx)
    }

    /// `t_S(b, 1)`: one draft token (the SSM runs sequentially).
    pub fn t_draft(&self, batch: usize, ctx: usize) -> f64 {
        self.forward_time(batch, 1, ctx)
    }

    /// Prefill over a prompt of `plen` tokens.
    pub fn t_prefill(&self, batch: usize, plen: usize) -> f64 {
        self.forward_time(batch, plen, 0)
    }

    /// Fitted (α_b, β) of the linearized `t_L(b, s) ≈ α_b·s + β` over
    /// s ∈ [0, s_max] (what the analytic model consumes).
    pub fn linearize(&self, batch: usize, s_max: usize, ctx: usize) -> (f64, f64) {
        let xs: Vec<f64> = (0..=s_max).map(|s| s as f64).collect();
        let ys: Vec<f64> = (0..=s_max)
            .map(|s| self.t_verify(batch, s, ctx))
            .collect();
        let (a, b, _) = crate::util::stats::linear_fit(&xs, &ys);
        (a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m67_3090() -> CostModel {
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090)
    }

    #[test]
    fn small_batch_is_memory_bound_and_flat() {
        let cm = m67_3090();
        // at b=1 the verify cost barely moves from s=0 to s=7 (Fig. 3 top)
        let t0 = cm.t_verify(1, 0, 256);
        let t7 = cm.t_verify(1, 7, 256);
        assert!(
            (t7 - t0) / t0 < 0.02,
            "b=1 should be flat: {t0} -> {t7}"
        );
    }

    #[test]
    fn large_batch_goes_linear_in_s() {
        let cm = m67_3090();
        // at b=32 the cost grows clearly with s (compute-bound regime)
        let t0 = cm.t_verify(32, 0, 256);
        let t7 = cm.t_verify(32, 7, 256);
        assert!(t7 > 1.5 * t0, "b=32 should be compute-bound: {t0} -> {t7}");
    }

    #[test]
    fn alpha_increases_with_batch() {
        // the analytical model's premise: α_b increasing in b
        let cm = m67_3090();
        let mut last = -1.0;
        for b in [1usize, 2, 4, 8, 16, 32] {
            let (alpha, beta) = cm.linearize(b, 8, 256);
            assert!(alpha >= last, "alpha not monotone at b={b}");
            assert!(beta > 0.0);
            last = alpha;
        }
    }

    #[test]
    fn per_token_decode_latency_is_plausible() {
        // OPT-6.7B fp16 on 3090 ≈ 13.4 GB / ~580 GB/s ≈ 23 ms + overhead;
        // the paper's Fig. 1b no-spec b=1 sits at tens of ms
        let cm = m67_3090();
        let t = cm.t_verify(1, 0, 128);
        assert!((0.015..0.06).contains(&t), "t = {t}s");
    }

    #[test]
    fn ssm_is_much_cheaper_than_llm() {
        let llm = m67_3090();
        let ssm = CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090);
        assert!(ssm.t_draft(1, 128) < 0.1 * llm.t_verify(1, 0, 128));
    }
}
