//! GPU hardware profiles for the calibrated cost simulator.
//!
//! The paper benchmarks RTX 3090 / RTX 4090 / A100 (Sec. 3.2).  Those
//! GPUs are not available in this environment, so Fig. 1/3/5-scale
//! experiments run on a **roofline cost model** built from the public
//! specs below (DESIGN.md §Substitutions).  The model only needs two
//! structural facts to reproduce the paper's phenomena, and both follow
//! from the roofline:
//!
//! 1. decode steps are memory-bound until `b·(s+1)` reaches the
//!    compute/memory crossover, so `t_L(b, s)` is flat then linear
//!    (Fig. 3: the b=1 curve jumps near s≈64, b=8 near s≈8 — the
//!    crossover token counts of a 3090 below are ≈62 and ≈8);
//! 2. the crossover shifts left as batch grows, which is exactly why
//!    `s_opt` shrinks with batch size.

/// One GPU's roofline parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    /// HBM/GDDR bandwidth, bytes/s
    pub mem_bw: f64,
    /// dense fp16 tensor-core throughput, FLOP/s
    pub peak_flops: f64,
    /// achievable fraction of peak bandwidth (large contiguous reads)
    pub mem_eff: f64,
    /// achievable fraction of peak FLOPs (GEMM at serving shapes)
    pub compute_eff: f64,
    /// fixed per-forward overhead (kernel launches, allocator, framework),
    /// seconds — dominates nothing but keeps tiny models honest
    pub launch_overhead: f64,
}

impl GpuProfile {
    pub const RTX3090: GpuProfile = GpuProfile {
        name: "rtx3090",
        mem_bw: 936.0e9,
        peak_flops: 71.0e12,
        mem_eff: 0.62,
        // serving-shape GEMMs (tens of rows) sit far below tensor peak;
        // 0.32 puts the roofline knee at ~39 tokens, matching Fig. 3's
        // empirical jumps (b=1 at s~64 is flat-side, b=8 knees by s~8
        // on the real curve's step)
        compute_eff: 0.32,
        launch_overhead: 0.8e-3,
    };

    pub const RTX4090: GpuProfile = GpuProfile {
        name: "rtx4090",
        mem_bw: 1008.0e9,
        peak_flops: 165.0e12,
        mem_eff: 0.65,
        compute_eff: 0.33,
        launch_overhead: 0.5e-3,
    };

    pub const A100: GpuProfile = GpuProfile {
        name: "a100",
        mem_bw: 1555.0e9,
        peak_flops: 312.0e12,
        mem_eff: 0.70,
        // A100 serving GEMMs at these shapes achieve a smaller fraction
        // of the huge tensor peak; its higher per-kernel latency also
        // makes SSM drafts relatively dearer (the paper's Fig. 1c stars
        // sit below the 4090's at equal batch)
        compute_eff: 0.28,
        launch_overhead: 1.0e-3,
    };

    pub fn by_name(name: &str) -> Option<GpuProfile> {
        match name {
            "rtx3090" | "3090" => Some(Self::RTX3090),
            "rtx4090" | "4090" => Some(Self::RTX4090),
            "a100" => Some(Self::A100),
            _ => None,
        }
    }

    pub fn all() -> [GpuProfile; 3] {
        [Self::RTX3090, Self::RTX4090, Self::A100]
    }

    /// Effective bandwidth (bytes/s).
    pub fn bw(&self) -> f64 {
        self.mem_bw * self.mem_eff
    }

    /// Effective compute (FLOP/s).
    pub fn flops(&self) -> f64 {
        self.peak_flops * self.compute_eff
    }

    /// Token count at which a forward pass turns compute-bound:
    /// tokens ≥ flops_eff / (bw_eff · arithmetic-intensity⁻¹) — for a
    /// 2-bytes/param fp16 model it is flops()/bw() · (bytes/flop of one
    /// token) and simplifies to flops()/bw() (2 FLOP per 2 bytes).
    pub fn crossover_tokens(&self) -> f64 {
        self.flops() / self.bw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_matches_fig3_structure() {
        // Fig. 3 (OPT-6.7B on 3090): b=1 jumps near s=64, b=8 near s=8.
        // crossover_tokens is the b·(s+1) product at the knee.
        let c = GpuProfile::RTX3090.crossover_tokens();
        assert!(
            (25.0..60.0).contains(&c),
            "3090 crossover {c} tokens out of the Fig.3-compatible range"
        );
    }

    #[test]
    fn faster_gpus_have_earlier_or_equal_knees_per_bandwidth() {
        // A100 has both more compute and more bandwidth; its crossover
        // stays in the same order of magnitude
        let a = GpuProfile::A100.crossover_tokens();
        assert!((50.0..120.0).contains(&a), "a100 crossover {a}");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuProfile::by_name("3090").unwrap().name, "rtx3090");
        assert_eq!(GpuProfile::by_name("a100").unwrap().name, "a100");
        assert!(GpuProfile::by_name("h100").is_none());
    }
}
