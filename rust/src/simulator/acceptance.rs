//! Stochastic acceptance process for the simulator.
//!
//! Samples the number of accepted drafts per speculative round so that the
//! expectation `E[min(L, s)]` matches a target `l(s)` curve.  Two flavours:
//!
//! * [`AcceptanceProcess::Geometric`] — constant per-token agreement `q`
//!   (what a stationary draft/target pair produces; our trained tiny pair
//!   measures q ≈ 0.7);
//! * [`AcceptanceProcess::PowerLaw`] — matches the paper's fitted
//!   `l(s) = c·s^γ` exactly via the survival decomposition of Eq. 6:
//!   `P(L ≥ j) = l(j) − l(j−1)`, sampled sequentially through the
//!   conditional probabilities `P(L ≥ j | L ≥ j−1)`.

use crate::util::prng::F64Source;

#[derive(Debug, Clone)]
pub enum AcceptanceProcess {
    /// Each draft token independently correct with probability q (given
    /// the prefix was correct).
    Geometric { q: f64 },
    /// Matches l(s) = c·s^γ (c ≤ 1 required for a valid process at j=1).
    PowerLaw { c: f64, gamma: f64 },
}

impl AcceptanceProcess {
    /// The paper's measured curve (Fig. 2).
    pub fn paper() -> AcceptanceProcess {
        AcceptanceProcess::PowerLaw {
            c: 0.9,
            gamma: 0.548,
        }
    }

    /// Survival probability P(L >= j), j >= 1.
    pub fn survival(&self, j: usize) -> f64 {
        match *self {
            AcceptanceProcess::Geometric { q } => q.powi(j as i32),
            AcceptanceProcess::PowerLaw { c, gamma } => {
                // P(L >= j) = l(j) - l(j-1); clamp into [0, 1]
                let l = |s: f64| c * s.powf(gamma);
                (l(j as f64) - l(j as f64 - 1.0)).clamp(0.0, 1.0)
            }
        }
    }

    /// Expected accepted count at speculation length s: E[min(L, s)]
    /// = Σ_{j=1..s} P(L ≥ j) (Eq. 6).
    pub fn expected_accepted(&self, s: usize) -> f64 {
        (1..=s).map(|j| self.survival(j)).sum()
    }

    /// Sample one round's accepted count (0..=s).  Generic over the draw
    /// source so the DES hot loops can feed it from a per-round
    /// [`crate::util::prng::DrawBuffer`] without touching the stream.
    pub fn sample<R: F64Source>(&self, s: usize, rng: &mut R) -> usize {
        let mut accepted = 0;
        while accepted < s {
            let j = accepted + 1;
            let cond = {
                let s_prev = if accepted == 0 {
                    1.0
                } else {
                    self.survival(accepted)
                };
                if s_prev <= 0.0 {
                    0.0
                } else {
                    (self.survival(j) / s_prev).clamp(0.0, 1.0)
                }
            };
            if rng.next_f64() < cond {
                accepted += 1;
            } else {
                break;
            }
        }
        accepted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn empirical_l(proc_: &AcceptanceProcess, s: usize, n: usize) -> f64 {
        let mut rng = Pcg64::new(99);
        (0..n).map(|_| proc_.sample(s, &mut rng)).sum::<usize>() as f64 / n as f64
    }

    #[test]
    fn geometric_expectation_matches_formula() {
        let p = AcceptanceProcess::Geometric { q: 0.7 };
        // E[min(L,3)] = .7 + .49 + .343
        assert!((p.expected_accepted(3) - 1.533).abs() < 1e-9);
        let emp = empirical_l(&p, 3, 200_000);
        assert!((emp - 1.533).abs() < 0.01, "empirical {emp}");
    }

    #[test]
    fn powerlaw_matches_paper_curve() {
        let p = AcceptanceProcess::paper();
        for s in [1usize, 2, 4, 8] {
            let target = 0.9 * (s as f64).powf(0.548);
            let analytic = p.expected_accepted(s);
            assert!(
                (analytic - target).abs() < 1e-9,
                "analytic l({s}) = {analytic} != {target}"
            );
            let emp = empirical_l(&p, s, 200_000);
            assert!(
                (emp - target).abs() < 0.02,
                "empirical l({s}) = {emp} != {target}"
            );
        }
    }

    #[test]
    fn survival_is_monotone_decreasing() {
        for p in [
            AcceptanceProcess::paper(),
            AcceptanceProcess::Geometric { q: 0.8 },
        ] {
            let mut prev = 1.0;
            for j in 1..=12 {
                let s = p.survival(j);
                assert!(s <= prev + 1e-12, "survival up at j={j}");
                assert!((0.0..=1.0).contains(&s));
                prev = s;
            }
        }
    }

    #[test]
    fn sample_is_bounded() {
        let p = AcceptanceProcess::paper();
        let mut rng = Pcg64::new(1);
        for _ in 0..1000 {
            assert!(p.sample(5, &mut rng) <= 5);
        }
    }
}
