//! Calibrated GPU simulator: paper-scale experiments without the paper's
//! testbed (DESIGN.md §Substitutions).
//!
//! * [`hw`]         — RTX 3090 / RTX 4090 / A100 roofline profiles
//! * [`cost`]       — `t_L(b, s)` / `t_S(b, 1)` step-cost model for
//!   OPT-125M/1.3B/6.7B and Llama-7B
//! * [`acceptance`] — stochastic draft-acceptance process matching a
//!   target `l(s)` curve
//! * [`des`]        — virtual-time single-server queue simulation of the
//!   serving loop (Fig. 5/6 at paper scale)
//!
//! The simulator shares the *policy* code ([`crate::scheduler`]) and the
//! *metrics* code ([`crate::metrics`]) with the real engine, so adaptive
//! vs fixed comparisons exercise the same decision logic in both worlds.

pub mod acceptance;
pub mod cost;
pub mod des;
pub mod hw;

pub use acceptance::AcceptanceProcess;
pub use cost::{CostModel, ModelProfile};
pub use des::{
    batch_service_time, batch_service_time_tel, per_token_latency, reshape_cost, round_cost,
    round_cost_ragged, simulate_trace, simulate_trace_admission, simulate_trace_admission_tel,
    simulate_trace_admission_tel_prefix, simulate_trace_continuous,
    simulate_trace_continuous_admission, simulate_trace_continuous_admission_tel,
    simulate_trace_continuous_admission_tel_prefix, AcceptanceDrift, SimConfig,
};
pub use hw::GpuProfile;

use std::collections::BTreeMap;

use crate::policy::{Fixed, LutAdaptive, NoSpec, SpeculationPolicy};
use crate::scheduler::Lut;
use crate::util::prng::Pcg64;

/// Build an adaptive LUT for the simulator by grid search over the cost
/// model (the simulator-world analogue of `scheduler::profiler::profile`).
pub fn simulated_lut(
    cfg: &SimConfig,
    buckets: &[usize],
    s_max: usize,
    ctx: usize,
) -> Lut {
    let mut rng = Pcg64::with_stream(cfg.seed, 0x107);
    let mut entries = BTreeMap::new();
    for &b in buckets {
        let mut best = (0usize, f64::INFINITY);
        for s in 0..=s_max {
            let lat = per_token_latency(cfg, b, s, ctx, 600, &mut rng);
            if lat < best.1 {
                best = (s, lat);
            }
        }
        entries.insert(b, best.0);
    }
    Lut::new(entries).expect("non-empty buckets")
}

/// Convenience: the four comparison points of the paper's Sec. 5.3.
pub fn comparison_policies(lut: Lut) -> Vec<(String, Box<dyn SpeculationPolicy>)> {
    vec![
        ("no-spec".into(), Box::new(NoSpec) as Box<dyn SpeculationPolicy>),
        ("fixed-2".into(), Box::new(Fixed(2))),
        ("fixed-4".into(), Box::new(Fixed(4))),
        ("adaptive".into(), Box::new(LutAdaptive(lut))),
    ]
}

/// Exact-expectation oracle `s_opt` at one live batch size under a given
/// acceptance process: argmin over s ∈ {0, 1..s_max} of the expected
/// virtual per-token round cost the DES charges.  Used by the drift
/// tests as the ground truth an online policy must re-converge to.
pub fn oracle_s_opt(
    cfg: &SimConfig,
    acceptance: &AcceptanceProcess,
    live: usize,
    s_max: usize,
    ctx: usize,
) -> usize {
    let mut best = (0usize, round_cost(cfg, live, 0, ctx));
    for s in 1..=s_max {
        let per_token = round_cost(cfg, live, s, ctx) / (acceptance.expected_accepted(s) + 1.0);
        if per_token < best.1 {
            best = (s, per_token);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_lut_is_monotone_non_increasing() {
        // the paper's headline: s_opt shrinks as batch grows
        let cfg = SimConfig::paper_default(
            CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
            CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        );
        let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16, 32], 8, 160);
        let vals: Vec<usize> = [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&b| lut.lookup(b))
            .collect();
        for w in vals.windows(2) {
            assert!(w[1] <= w[0], "s_opt increased with batch: {vals:?}");
        }
        assert!(vals[0] >= 3, "b=1 should want long speculation: {vals:?}");
        assert!(
            *vals.last().unwrap() <= 2,
            "b=32 should want short speculation: {vals:?}"
        );
    }
}
