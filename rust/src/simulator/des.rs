//! Discrete-event simulation of the serving loop at paper scale.
//!
//! The paper's server is a single FIFO worker: while a batch is being
//! generated, arrivals queue; when the worker frees, everything queued
//! (capped at `max_batch`) merges into the next batch.  That makes the
//! queueing process a single-server queue that can be simulated exactly
//! with a virtual clock — no real time, so the Fig. 5 grid (4 CVs × 8
//! intervals × 4 policies × 1000 requests of OPT-6.7B on an RTX 3090)
//! runs in milliseconds.
//!
//! Per-batch service time comes from the roofline [`CostModel`]s and the
//! stochastic [`AcceptanceProcess`]; the round structure mirrors
//! `engine::Engine::generate_batch` exactly (prefill, then speculate/
//! verify rounds with per-row accept counts, frozen finished rows).
//! Both entry points drive the policy's **feedback edge** in virtual
//! time: after every simulated round the policy's `observe` receives the
//! live batch, the `s` used, the sampled per-row accepted counts and the
//! round's virtual cost — so online policies
//! ([`crate::policy::ModelBased`]) learn inside the simulator exactly as
//! they do on the real engine.
//!
//! Two scheduling modes are modelled:
//!
//! * [`simulate_trace`] — the paper's batch-to-completion static batching
//!   (drain the queue, serve, repeat);
//! * [`simulate_trace_continuous`] — the round-granular continuous
//!   batcher (`crate::batcher`): admissions at round boundaries,
//!   immediate retirement, and a per-round policy query with the live
//!   batch size.
//!
//! **Acceptance drift** ([`SimConfig::drift`]) models the non-stationary
//! workloads of the speculative-execution literature: at a chosen
//! virtual time the draft/target agreement curve `l(s)` switches to a
//! different process (a workload shift, a draft model gone stale).  An
//! offline LUT keeps serving its now-stale `s`; the online policy
//! re-fits and re-converges — `tests/online_policy.rs` pins that payoff.

use std::collections::{BTreeMap, VecDeque};

use crate::admission::{
    apply_plan_to_queue, AdmissionController, AdmissionView, Candidate, Fifo,
};
use crate::kvcache::prefix::{PrefixCache, PrefixStats};
use crate::kvcache::{BlockManager, KvLayout, DEFAULT_BLOCK_SIZE};
use crate::metrics::{LatencyRecorder, RequestRecord, RoundEvent};
use crate::policy::{RoundFeedback, SpeculationPolicy};
use crate::telemetry::attrib::Waterfall;
use crate::telemetry::{PhaseKind, Telemetry};
use crate::traffic::{Trace, TraceItem};
use crate::util::prng::{DrawBuffer, Pcg64};

use super::acceptance::AcceptanceProcess;
use super::cost::CostModel;

/// Mid-trace acceptance drift: from virtual time `at` on, draft
/// acceptance follows `after` instead of `SimConfig::acceptance`.
#[derive(Debug, Clone)]
pub struct AcceptanceDrift {
    /// virtual seconds at which the workload shifts
    pub at: f64,
    /// the post-drift acceptance process
    pub after: AcceptanceProcess,
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub llm: CostModel,
    pub ssm: CostModel,
    pub acceptance: AcceptanceProcess,
    /// per-workload-class acceptance overrides (keyed by
    /// [`crate::traffic::TraceItem::class`]): rows of a tagged class
    /// follow their own draft/target agreement curve, modelling e.g.
    /// code-completion rows next to chat rows in one batch.  Classes
    /// absent from the map — and everything, when the map is empty —
    /// fall back to `acceptance`.
    pub class_acceptance: BTreeMap<u8, AcceptanceProcess>,
    /// optional mid-trace acceptance drift scenario
    pub drift: Option<AcceptanceDrift>,
    pub max_batch: usize,
    pub max_new_tokens: usize,
    /// host-side per-round overhead (acceptance logic, staging), seconds
    pub host_overhead: f64,
    /// KV layout the continuous mirror charges epoch-reshape costs for:
    /// `Dense` re-ingests every carried context at a bucket growth
    /// (chunked verify + SSM catch-up, mirroring the engine), `Paged`
    /// reshapes by block-table remap at zero cost.  Defaults to `Paged`
    /// — which is also what earlier revisions implicitly idealized.
    pub kv_layout: KvLayout,
    /// tokens per KV block for the timeline's block-utilization column
    pub kv_block: usize,
    /// admission-time prefix-sharing mirror: when on, a prompt whose
    /// leading blocks were already served maps them from the shared
    /// cache and the LLM prefill charge covers only the unmatched
    /// suffix (the engine's `PrefixCache` payoff in virtual time; the
    /// SSM still ingests the full prompt — its dense cache is private).
    /// Off by default: the paper pipeline and every pinned baseline
    /// predate sharing, and with it off the charges are bit-identical
    /// to earlier revisions.
    pub prefix_cache: bool,
    pub seed: u64,
}

impl SimConfig {
    pub fn paper_default(llm: CostModel, ssm: CostModel) -> SimConfig {
        SimConfig {
            llm,
            ssm,
            acceptance: AcceptanceProcess::paper(),
            class_acceptance: BTreeMap::new(),
            drift: None,
            max_batch: 16,
            max_new_tokens: 128,
            host_overhead: 0.2e-3,
            kv_layout: KvLayout::Paged,
            kv_block: DEFAULT_BLOCK_SIZE,
            prefix_cache: false,
            seed: 0,
        }
    }

    /// Acceptance process in effect at virtual time `t`.
    pub fn acceptance_at(&self, t: f64) -> &AcceptanceProcess {
        match &self.drift {
            Some(d) if t >= d.at => &d.after,
            _ => &self.acceptance,
        }
    }

    /// Acceptance process in effect for workload class `class` at virtual
    /// time `t`.  Drift (a global workload shift) overrides every class
    /// after the cut; before it, tagged classes follow their
    /// [`SimConfig::class_acceptance`] override and everything else falls
    /// back to [`SimConfig::acceptance`] — so with an empty map this is
    /// exactly [`SimConfig::acceptance_at`].
    pub fn class_acceptance_at(&self, class: u8, t: f64) -> &AcceptanceProcess {
        match &self.drift {
            Some(d) if t >= d.at => &d.after,
            _ => self.class_acceptance.get(&class).unwrap_or(&self.acceptance),
        }
    }
}

/// Blocks backing the DES prefix mirror's pool: generous enough that a
/// trace-scale working set fits and eviction only triggers under real
/// template churn (the engine-level tests pin the pressure path).
const SIM_PREFIX_POOL_BLOCKS: usize = 4096;

/// The DES twin of the engine's admission-time prefix sharing: a real
/// [`PrefixCache`] over a private [`BlockManager`], consulted once per
/// admitted row.  Rows are virtual — no KV is read — so a mapped chain
/// is released back immediately and only the *matched token count*
/// feeds the timing model (the LLM prefill charge shrinks to the
/// unmatched suffix).  Registration donates a freshly allocated chain
/// to the trie and drops the row's own references, mirroring an
/// immediately retired row; the refcount choreography is exactly the
/// engine's, so the same leak invariant holds (`finish` asserts it).
pub(crate) struct SimPrefix {
    cache: PrefixCache,
    mgr: BlockManager,
}

impl SimPrefix {
    pub(crate) fn new(block: usize) -> SimPrefix {
        SimPrefix {
            cache: PrefixCache::new(block),
            mgr: BlockManager::new(SIM_PREFIX_POOL_BLOCKS, block),
        }
    }

    /// Prompt tokens whose prefill a cached prefix covers (0 on a miss).
    /// The mappable span is capped at `len - 1` — at least one suffix
    /// token must prefill, exactly as the engine caps it.
    pub(crate) fn lookup_saved(&mut self, ids: &[i32]) -> usize {
        if ids.len() < 2 {
            return 0;
        }
        match self.cache.lookup(&ids[..ids.len() - 1], &mut self.mgr) {
            Some(m) => {
                for &b in &m.blocks {
                    self.mgr.release(b);
                }
                m.tokens
            }
            None => 0,
        }
    }

    /// Register a freshly prefilled prompt for later arrivals.  Allocates
    /// the chain the row's table would hold (evicting LRU entries under
    /// pool pressure), donates it to the trie, releases the row's own
    /// references.  Skipped silently when eviction cannot make room.
    pub(crate) fn register(&mut self, ids: &[i32]) {
        if ids.len() < 2 {
            return;
        }
        let n_blocks = ids.len().div_ceil(self.cache.block_size());
        let mut chain = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            loop {
                match self.mgr.alloc() {
                    Ok(id) => {
                        chain.push(id);
                        break;
                    }
                    Err(_) => {
                        if !self.cache.evict_lru(&mut self.mgr) {
                            for &id in &chain {
                                self.mgr.release(id);
                            }
                            return;
                        }
                    }
                }
            }
        }
        self.cache.insert(ids, &chain, &mut self.mgr);
        for &id in &chain {
            self.mgr.release(id);
        }
    }

    /// Drain the cache and return its lifetime counters; debug-asserts
    /// the pool's leak invariant (free list back at capacity).
    pub(crate) fn finish(mut self) -> PrefixStats {
        let stats = self.cache.stats();
        self.cache.evict_all(&mut self.mgr);
        debug_assert!(
            self.mgr.stats().is_leak_free(),
            "DES prefix mirror leaked blocks: {:?}",
            self.mgr.stats()
        );
        stats
    }
}

/// Virtual cost the DES charges one decode round at `(batch, s, ctx)` —
/// the single definition shared by both simulate entry points, the
/// Fig. 1 grid metric, and the convergence oracle
/// (`crate::simulator::oracle_s_opt`).
pub fn round_cost(cfg: &SimConfig, batch: usize, s: usize, ctx: usize) -> f64 {
    if s == 0 {
        cfg.llm.t_verify(batch, 0, ctx) + cfg.host_overhead
    } else {
        s as f64 * cfg.ssm.t_draft(batch, ctx)
            + cfg.llm.t_verify(batch, s, ctx)
            + cfg.host_overhead
    }
}

/// Draft-phase cost of a ragged round: `s_rows[i]` draft steps for live
/// row `i`, inside a batch executing `batch` padded lanes.  The SSM runs
/// `max(s_rows)` sequential single-token forwards; at step `k` the lanes
/// still drafting are the rows with `s_rows[i] > k` **plus every padding
/// lane** (`batch - s_rows.len()` vacant or finished slots — the padded
/// kernel executes them regardless, exactly as `round_cost` charges the
/// full `batch` width).  Consecutive steps of equal width are grouped
/// into one `run * t_draft(width)` term, so a uniform `s_rows` collapses
/// to the single `s * t_draft(batch)` multiplication of [`round_cost`]
/// and reproduces it bit for bit.
pub(crate) fn ragged_draft_cost(
    cfg: &SimConfig,
    batch: usize,
    s_rows: &[usize],
    ctx: usize,
) -> f64 {
    let s_max = s_rows.iter().copied().max().unwrap_or(0);
    let pad = batch - s_rows.len().min(batch);
    let width_at = |k: usize| pad + s_rows.iter().filter(|&&si| si > k).count();
    let mut draft = 0.0;
    let mut step = 0usize;
    while step < s_max {
        let width = width_at(step);
        let mut run = 1usize;
        while step + run < s_max && width_at(step + run) == width {
            run += 1;
        }
        draft += run as f64 * cfg.ssm.t_draft(width, ctx);
        step += run;
    }
    draft
}

/// Virtual cost the DES charges one **ragged** decode round: per-row
/// draft lengths `s_rows` (one entry per live row) inside a batch
/// executing `batch` padded lanes.  Drafting shrinks with the active
/// width per [`ragged_draft_cost`]; verification is padded to the widest
/// row (`t_verify(batch, max(s_rows))` — one kernel over the rectangular
/// bucket, exactly as the bucket already pads width).  A uniform
/// `s_rows` reproduces [`round_cost`] bit for bit, operation for
/// operation.
pub fn round_cost_ragged(cfg: &SimConfig, batch: usize, s_rows: &[usize], ctx: usize) -> f64 {
    let s_max = s_rows.iter().copied().max().unwrap_or(0);
    if s_max == 0 {
        cfg.llm.t_verify(batch, 0, ctx) + cfg.host_overhead
    } else {
        ragged_draft_cost(cfg, batch, s_rows, ctx)
            + cfg.llm.t_verify(batch, s_max, ctx)
            + cfg.host_overhead
    }
}

/// Chunk the dense reshape re-ingest runs at: the stub engine's largest
/// verify span + 1 (`Engine::ingest_admitted` feeds contexts this wide).
const RESHAPE_CHUNK: usize = 9;

/// The engine's batch bucket for `n` live rows (compiled buckets are
/// powers of two).  Shared with the cluster mirror (`cluster::sim`).
pub(crate) fn sim_bucket_for(n: usize) -> usize {
    n.next_power_of_two().max(1)
}

/// KV blocks the live rows occupy under the paged layout (the timeline's
/// block-utilization column; the DES models the LLM cache only).
/// Shared with the cluster mirror (`cluster::sim`).
pub(crate) fn kv_blocks_of(cfg: &SimConfig, ctx_lens: impl Iterator<Item = usize>) -> usize {
    if cfg.kv_layout != KvLayout::Paged {
        return 0;
    }
    ctx_lens.map(|c| c.div_ceil(cfg.kv_block.max(1))).sum()
}

/// Virtual cost of an epoch reshape carrying rows with the given context
/// lengths into a bucket executing at `width` rows.
///
/// `Dense` mirrors what the engine actually pays: the LLM re-ingests the
/// longest carried context in `RESHAPE_CHUNK`-token verify passes (all
/// rows ingest in parallel inside each pass), and the SSM catches up two
/// tokens per throwaway speculate call (charged here at reshape time
/// rather than at the next speculative round — the work is the same).
/// `Paged` reshapes by block-table remap: a handful of pointer writes,
/// modeled as free — which also keeps the paper-default (`Paged`)
/// numbers bit-identical to earlier revisions, where the DES implicitly
/// idealized reshape.
pub fn reshape_cost(cfg: &SimConfig, carried_ctx: &[usize], width: usize) -> f64 {
    if carried_ctx.is_empty() {
        return 0.0;
    }
    match cfg.kv_layout {
        KvLayout::Paged => 0.0,
        KvLayout::Dense => {
            let max_ctx = carried_ctx.iter().copied().max().unwrap_or(0);
            let mean_ctx = (carried_ctx.iter().sum::<usize>() as f64
                / carried_ctx.len() as f64)
                .ceil() as usize;
            let llm_passes = max_ctx.div_ceil(RESHAPE_CHUNK);
            let ssm_passes = max_ctx.div_ceil(2);
            llm_passes as f64
                * (cfg.llm.t_verify(width, RESHAPE_CHUNK - 1, mean_ctx) + cfg.host_overhead)
                + ssm_passes as f64 * cfg.ssm.t_draft(width, mean_ctx)
        }
    }
}

/// Simulated duration of serving one batch to completion, starting at
/// virtual time `start_t` (drift is evaluated against the advancing
/// clock).  Drives the policy's `observe` edge per simulated round.
///
/// Returns (service_seconds, tokens_generated, first_spec_len).
pub fn batch_service_time(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    prompt_lens: &[usize],
    start_t: f64,
    rng: &mut Pcg64,
) -> (f64, usize, usize) {
    batch_service_time_tel(
        cfg,
        policy,
        prompt_lens,
        &[],
        None,
        start_t,
        rng,
        &Telemetry::disabled(),
        0,
        0,
        None,
    )
}

/// [`batch_service_time`] with an event stream: round spans, phase spans
/// and counters land on `tel` in **virtual time** (`start_t`-anchored),
/// under the same schema the threaded engine emits in wall time.
/// `epoch`/`queued` label the round spans; emission consumes no
/// randomness, so a disabled handle reproduces [`batch_service_time`]
/// bit for bit.
///
/// When `wf_out` is given, the batch's latency decomposition (prefill +
/// per-round draft/verify/accept splits) accrues into it; every request
/// of a batch-to-completion batch experiences the same body, so the
/// caller stamps per-request queue wait and seals against latency.
///
/// `classes` tags each row with its workload class (parallel to
/// `prompt_lens`; empty = every row class 0).  Classed rows sample their
/// [`SimConfig::class_acceptance`] process, and the policy's ragged API
/// (`choose_ragged_into`) picks one draft length per live row — a
/// uniform choice (every non-ragged policy, and `ModelBased` before its
/// per-class fits diverge) reproduces the classless path bit for bit.
///
/// `prefill_lens` overrides the per-row token span the **LLM** prefill
/// is charged for (parallel to `prompt_lens`): the prefix-sharing
/// mirror passes each row's unmatched suffix here, while context
/// lengths — and the SSM prefill, whose dense cache is private — keep
/// following the full `prompt_lens`.  `None` charges the full prompts,
/// bit for bit the pre-sharing behaviour.
#[allow(clippy::too_many_arguments)]
pub fn batch_service_time_tel(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    prompt_lens: &[usize],
    classes: &[u8],
    prefill_lens: Option<&[usize]>,
    start_t: f64,
    rng: &mut Pcg64,
    tel: &Telemetry,
    epoch: usize,
    queued: usize,
    mut wf_out: Option<&mut Waterfall>,
) -> (f64, usize, usize) {
    let b = prompt_lens.len();
    assert!(b >= 1);
    let mean_prompt = prompt_lens.iter().sum::<usize>() as f64 / b as f64;
    let prefill_lens = prefill_lens.unwrap_or(prompt_lens);
    debug_assert_eq!(prefill_lens.len(), b, "one prefill span per row");
    let mean_prefill = prefill_lens.iter().sum::<usize>() as f64 / b as f64;
    let may_speculate = policy.wants_speculation();
    let mut drift_seen = policy.drift_flushes();

    // prefill (both models when speculating; the LLM charge covers only
    // the rows' unmapped spans, the SSM always ingests the full prompt)
    let mut t = cfg.llm.t_prefill(b, mean_prefill.ceil() as usize);
    if may_speculate {
        t += cfg.ssm.t_prefill(b, mean_prompt.ceil() as usize);
    }
    if tel.enabled() {
        tel.phase(start_t, t, PhaseKind::Prefill);
    }
    if let Some(wf) = wf_out.as_deref_mut() {
        wf.prefill += t;
    }

    // prefill commits one token per row
    let mut generated = vec![1usize; b];
    let mut first_spec_len = None;
    // round-scratch mirrors of the engine's arenas: the accepted-count
    // buffer cycles through the policy feedback by mem::take, and PRNG
    // draws come in one bulk fill per round (order-preserving, and
    // refunded at the end so the caller's stream is untouched)
    let mut accepted_rows: Vec<u32> = Vec::new();
    let mut draws = DrawBuffer::new();
    // ragged-round scratch: per-live-row classes and chosen draft
    // lengths, plus the feedback's per-row vectors (cycled by mem::take)
    let mut live_classes: Vec<u8> = Vec::new();
    let mut s_choice: Vec<usize> = Vec::new();
    let mut fb_s_rows: Vec<u32> = Vec::new();
    let mut fb_classes: Vec<u8> = Vec::new();
    let classed = classes.iter().any(|&c| c != 0);
    while generated.iter().any(|&g| g < cfg.max_new_tokens) {
        let live = generated.iter().filter(|&&g| g < cfg.max_new_tokens).count();
        live_classes.clear();
        for (i, &g) in generated.iter().enumerate() {
            if g < cfg.max_new_tokens {
                live_classes.push(classes.get(i).copied().unwrap_or(0));
            }
        }
        if may_speculate {
            policy.choose_ragged_into(&live_classes, 8, &mut s_choice);
        } else {
            s_choice.clear();
            s_choice.resize(live, 0);
        }
        let s = s_choice.iter().copied().max().unwrap_or(0);
        let ragged = s_choice.iter().any(|&si| si != s);
        if first_spec_len.is_none() {
            first_spec_len = Some(s);
        }
        let ctx = mean_prompt as usize + generated.iter().sum::<usize>() / b;
        // the static batch keeps executing at its admitted width `b` even
        // as rows freeze, so `b` is the padded lane count
        let rc = if ragged {
            round_cost_ragged(cfg, b, &s_choice, ctx)
        } else {
            round_cost(cfg, b, s, ctx)
        };
        accepted_rows.clear();
        let mut committed = 0usize;
        if s == 0 {
            for g in generated.iter_mut() {
                if *g < cfg.max_new_tokens {
                    *g += 1;
                    committed += 1;
                }
            }
        } else {
            // SSM drafts sequentially: up to s_i single-token forwards
            // per row (a row at s_i = 0 rides the round non-speculative
            // and still commits its verify token)
            draws.ensure(rng, s_choice.iter().sum::<usize>());
            let mut li = 0usize;
            for (i, g) in generated.iter_mut().enumerate() {
                if *g < cfg.max_new_tokens {
                    let acc = cfg
                        .class_acceptance_at(classes.get(i).copied().unwrap_or(0), start_t + t);
                    let a = acc.sample(s_choice[li], &mut draws);
                    accepted_rows.push(a as u32);
                    *g += a + 1;
                    committed += a + 1;
                    li += 1;
                }
            }
        }
        let t_round = start_t + t;
        t += rc;
        let (draft, verify, accept) = if ragged {
            round_phase_split_ragged(cfg, rc, b, &s_choice, ctx)
        } else {
            round_phase_split(cfg, rc, b, s, ctx)
        };
        fb_s_rows.clear();
        if ragged {
            fb_s_rows.extend(s_choice.iter().map(|&si| si as u32));
        }
        fb_classes.clear();
        if classed {
            fb_classes.extend_from_slice(&live_classes);
        }
        if tel.active() {
            let kvb = kv_blocks_of(
                cfg,
                prompt_lens
                    .iter()
                    .zip(generated.iter())
                    .map(|(&p, &g)| p + g.min(cfg.max_new_tokens)),
            );
            tel.round(
                t_round,
                rc,
                epoch,
                live,
                b,
                queued,
                s,
                committed,
                &accepted_rows,
                &fb_s_rows,
                kvb,
            );
            emit_phase_tiles(tel, t_round, draft, verify, accept);
        }
        if let Some(wf) = wf_out.as_deref_mut() {
            wf.add_round_split(0.0, draft, verify, accept);
        }
        let fb = RoundFeedback {
            live,
            // the static batch keeps executing at its admitted width
            // even as rows finish
            width: b,
            s,
            accepted: std::mem::take(&mut accepted_rows),
            committed,
            round_time: rc,
            s_rows: std::mem::take(&mut fb_s_rows),
            classes: std::mem::take(&mut fb_classes),
        };
        policy.observe(&fb);
        accepted_rows = fb.accepted;
        fb_s_rows = fb.s_rows;
        fb_classes = fb.classes;
        let flushes = policy.drift_flushes();
        if flushes > drift_seen {
            drift_seen = flushes;
            tel.drift_flush(t);
        }
    }
    // hand unconsumed bulk draws back so the caller's generator sits at
    // exactly the sequential-equivalent state
    draws.refund(rng);
    let tokens: usize = generated.iter().map(|&g| g.min(cfg.max_new_tokens)).sum();
    (t, tokens, first_spec_len.unwrap_or(0))
}

/// Decompose one simulated round's cost `rc` into `(draft, verify,
/// accept)` — the virtual-time twin of the engine's stopwatch-delta
/// decomposition.  The three parts tile `rc` exactly: accept is the
/// remainder (host overhead) after the modeled draft and verify costs.
/// Shared with the cluster mirror and the waterfall accrual below.
pub(crate) fn round_phase_split(
    cfg: &SimConfig,
    rc: f64,
    b: usize,
    s: usize,
    ctx: usize,
) -> (f64, f64, f64) {
    let draft = if s == 0 {
        0.0
    } else {
        s as f64 * cfg.ssm.t_draft(b, ctx)
    };
    let verify = cfg.llm.t_verify(b, s, ctx);
    let accept = (rc - draft - verify).max(0.0);
    (draft, verify, accept)
}

/// [`round_phase_split`] for a ragged round: the draft part is the
/// shrinking-width sum of [`ragged_draft_cost`], verify is padded to the
/// widest row, accept is the remainder.  The tiles still sum to `rc`
/// exactly, because [`round_cost_ragged`] is built from the same terms.
pub(crate) fn round_phase_split_ragged(
    cfg: &SimConfig,
    rc: f64,
    b: usize,
    s_rows: &[usize],
    ctx: usize,
) -> (f64, f64, f64) {
    let s_max = s_rows.iter().copied().max().unwrap_or(0);
    let draft = if s_max == 0 {
        0.0
    } else {
        ragged_draft_cost(cfg, b, s_rows, ctx)
    };
    let verify = cfg.llm.t_verify(b, s_max, ctx);
    let accept = (rc - draft - verify).max(0.0);
    (draft, verify, accept)
}

/// Emit one simulated round's draft/verify/accept spans on `tel`, tiling
/// `[t_round, t_round + rc]`.  Shared with the cluster mirror
/// (`cluster::sim`).
pub(crate) fn emit_round_phases(
    cfg: &SimConfig,
    tel: &Telemetry,
    t_round: f64,
    rc: f64,
    b: usize,
    s: usize,
    ctx: usize,
) {
    let (draft, verify, accept) = round_phase_split(cfg, rc, b, s, ctx);
    emit_phase_tiles(tel, t_round, draft, verify, accept);
}

/// Emit an already-decomposed round as draft/verify/accept spans tiling
/// `[t_round, t_round + draft + verify + accept]` — the shared tail of
/// [`emit_round_phases`], reused directly where the split was already
/// computed (ragged rounds accrue it into waterfalls anyway).
pub(crate) fn emit_phase_tiles(
    tel: &Telemetry,
    t_round: f64,
    draft: f64,
    verify: f64,
    accept: f64,
) {
    let mut pt = t_round;
    if draft > 0.0 {
        tel.phase(pt, draft, PhaseKind::Draft);
        pt += draft;
    }
    tel.phase(pt, verify, PhaseKind::Verify);
    pt += verify;
    tel.phase(pt, accept, PhaseKind::Accept);
}

/// Simulate a full trace through the single-server FIFO queue
/// (bit-for-bit the pre-admission-subsystem behaviour).
pub fn simulate_trace(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    trace: &Trace,
) -> LatencyRecorder {
    simulate_trace_admission(cfg, policy, &mut Fifo, trace)
}

/// A queued trace item plus its admission-control state (the DES twin of
/// the batcher's internal queue entry).
struct Waiting {
    item: TraceItem,
    deferred: usize,
}

/// Record a shed decision at virtual time `t`.
fn push_shed(recorder: &mut LatencyRecorder, w: &Waiting, t: f64) {
    recorder.push(RequestRecord {
        id: w.item.id,
        sent_at: w.item.send_at,
        started_at: t,
        finished_at: t,
        tokens: 0,
        batch: 0,
        spec_len: 0,
        shard: 0,
        deadline: w.item.deadline,
        deferred_rounds: w.deferred,
        shed: true,
        first_token_at: None,
    });
}

/// Simulate a full trace through the single-server batch-to-completion
/// queue with an [`AdmissionController`] ruling on every batch formation:
/// the backlog is reordered per the plan, sheds leave the system as
/// `shed` records, and deferred requests wait for the next formation
/// (batch-to-completion forms batches with zero live rows, so `SloAware`
/// only sheds hopeless requests here, mirroring `server::serve_static`).
pub fn simulate_trace_admission(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
) -> LatencyRecorder {
    simulate_trace_admission_tel(cfg, policy, ctrl, trace, &Telemetry::disabled())
}

/// [`simulate_trace_admission`] with an event stream on `tel`: admission
/// verdicts, round/phase spans (via [`batch_service_time_tel`]) and
/// terminal finish/shed events, all stamped in **virtual time** under the
/// same schema the threaded server emits in wall time.  Emission consumes
/// no randomness: a disabled handle reproduces the plain entry point bit
/// for bit.
pub fn simulate_trace_admission_tel(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
    tel: &Telemetry,
) -> LatencyRecorder {
    simulate_trace_admission_tel_prefix(cfg, policy, ctrl, trace, tel).0
}

/// [`simulate_trace_admission_tel`] returning the prefix-sharing
/// mirror's lifetime counters next to the records: `Some` when
/// [`SimConfig::prefix_cache`] is on (hit rate, prefill tokens saved),
/// `None` when off.  The records themselves are identical to the plain
/// entry point's.
pub fn simulate_trace_admission_tel_prefix(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
    tel: &Telemetry,
) -> (LatencyRecorder, Option<PrefixStats>) {
    let mut prefix = if cfg.prefix_cache {
        Some(SimPrefix::new(cfg.kv_block.max(1)))
    } else {
        None
    };
    let mut rng = Pcg64::with_stream(cfg.seed, 0x5e5);
    let mut recorder = LatencyRecorder::new();
    let items = &trace.items;
    let mut next = 0usize; // first unarrived request
    let mut waiting: VecDeque<Waiting> = VecDeque::new();
    let mut free_at = 0.0f64; // server availability
    let mut epoch = 0usize; // one epoch per formed batch

    while next < items.len() || !waiting.is_empty() {
        // the server starts the next batch when it is free AND at least
        // one request is waiting
        let start = if let Some(head) = waiting.front() {
            free_at.max(head.item.send_at)
        } else {
            free_at.max(items[next].send_at)
        };
        // everything sent by `start` joins the backlog
        while next < items.len() && items[next].send_at <= start {
            waiting.push_back(Waiting {
                item: items[next].clone(),
                deferred: 0,
            });
            next += 1;
        }
        // admission plan over the whole backlog (live == 0: the previous
        // batch ran to completion)
        let candidates: Vec<Candidate> = waiting
            .iter()
            .map(|w| Candidate {
                id: w.item.id,
                sent_at: w.item.send_at,
                deadline: w.item.deadline,
                prompt_len: w.item.prompt.ids.len(),
                tokens_left: cfg.max_new_tokens,
                deferred: w.deferred,
            })
            .collect();
        let view = AdmissionView {
            now: start,
            live: 0,
            max_batch: cfg.max_batch,
            policy,
        };
        let queue: Vec<Waiting> = waiting.drain(..).collect();
        let out = apply_plan_to_queue(ctrl.plan(&candidates, &view), queue, 0, |w| {
            w.deferred += 1
        });
        for w in &out.shed {
            push_shed(&mut recorder, w, start);
        }
        // the admissible prefix forms the batch (capped); the rest —
        // over-capacity admits, then defers — stays queued in order
        let n_batch = out.admit_n.min(cfg.max_batch);
        if tel.active() {
            let fin = crate::admission::predicted_finish(
                policy,
                start,
                cfg.max_new_tokens,
                out.queue.len(),
                cfg.max_batch,
            );
            let slack = |d: Option<f64>| match (d, fin) {
                (Some(d), Some(f)) => Some(d - f),
                _ => None,
            };
            for w in &out.shed {
                tel.admission(
                    start,
                    w.item.id,
                    "shed",
                    w.item.deadline,
                    slack(w.item.deadline),
                    w.deferred,
                );
                // a shed request's whole lifetime was queue wait
                let mut wf = Waterfall::default();
                wf.queue = start - w.item.send_at;
                wf.deferred_rounds = w.deferred;
                wf.seal(start - w.item.send_at);
                tel.finish_attrib(
                    start,
                    w.item.id,
                    0,
                    true,
                    w.item.deadline.map(|d| d - start),
                    Some(wf),
                );
            }
            for (i, w) in out.queue.iter().enumerate() {
                let verdict = if i < n_batch { "admit" } else { "defer" };
                tel.admission(
                    start,
                    w.item.id,
                    verdict,
                    w.item.deadline,
                    slack(w.item.deadline),
                    w.deferred,
                );
            }
        }
        let mut rest = out.queue;
        let batch: Vec<Waiting> = rest.drain(..n_batch).collect();
        waiting.extend(rest);
        if batch.is_empty() {
            // the whole backlog was shed: the next iteration re-anchors
            // on the next arrival
            continue;
        }
        epoch += 1;
        let prompt_lens: Vec<usize> = batch.iter().map(|w| w.item.prompt.ids.len()).collect();
        let classes: Vec<u8> = batch.iter().map(|w| w.item.class).collect();
        // prefix sharing: map each row's cached leading blocks read-only,
        // so the LLM prefills only the unmatched suffix
        let prefill_lens: Option<Vec<usize>> = prefix.as_mut().map(|p| {
            batch
                .iter()
                .map(|w| {
                    let ids = &w.item.prompt.ids;
                    ids.len() - p.lookup_saved(ids)
                })
                .collect()
        });
        // the shared latency body of this batch-to-completion batch:
        // prefill + per-round phase splits, identical for every member
        let mut body = Waterfall::default();
        let (dur, _tokens, spec_len) = batch_service_time_tel(
            cfg,
            policy,
            &prompt_lens,
            &classes,
            prefill_lens.as_deref(),
            start,
            &mut rng,
            tel,
            epoch,
            waiting.len(),
            Some(&mut body),
        );
        // the batch's prompts are prefilled now: register them for
        // later arrivals (batchmates never hit each other — exactly the
        // engine's map-at-admit / insert-after-prefill order)
        if let Some(p) = prefix.as_mut() {
            for w in &batch {
                p.register(&w.item.prompt.ids);
            }
        }
        let finish = start + dur;
        for w in &batch {
            if tel.active() {
                let mut wf = body;
                wf.queue = start - w.item.send_at;
                wf.deferred_rounds = w.deferred;
                wf.seal(finish - w.item.send_at);
                tel.finish_attrib(
                    finish,
                    w.item.id,
                    cfg.max_new_tokens,
                    false,
                    w.item.deadline.map(|d| d - finish),
                    Some(wf),
                );
            }
            recorder.push(RequestRecord {
                id: w.item.id,
                sent_at: w.item.send_at,
                started_at: start,
                finished_at: finish,
                tokens: cfg.max_new_tokens,
                batch: batch.len(),
                spec_len,
                shard: 0,
                deadline: w.item.deadline,
                deferred_rounds: w.deferred,
                shed: false,
                first_token_at: Some(start + body.prefill),
            });
        }
        if tel.tracing() {
            tel.policy_fit(finish, policy.snapshot());
        }
        free_at = finish;
    }
    (recorder, prefix.map(SimPrefix::finish))
}

/// Virtual-time mirror of the continuous batcher with FIFO admission
/// (bit-for-bit the pre-admission-subsystem behaviour).
pub fn simulate_trace_continuous(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    trace: &Trace,
) -> (LatencyRecorder, Vec<RoundEvent>) {
    simulate_trace_continuous_admission(cfg, policy, &mut Fifo, trace)
}

/// Virtual-time mirror of the continuous batcher
/// (`crate::batcher::ContinuousBatcher`): requests are admitted into free
/// rows at round boundaries — in the order, and with the deferrals and
/// sheds, the [`AdmissionController`] rules — finished rows retire
/// immediately, and the policy is re-queried with the *live* batch size
/// (and fed back the round outcome) every round.  Returns the latency
/// records (sheds included, as `shed` records) plus the per-round
/// timeline, so Fig. 5/6-style sweeps can compare scheduling modes,
/// policies and admission controllers without hardware.
pub fn simulate_trace_continuous_admission(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
) -> (LatencyRecorder, Vec<RoundEvent>) {
    simulate_trace_continuous_admission_tel(cfg, policy, ctrl, trace, &Telemetry::disabled())
}

/// [`simulate_trace_continuous_admission`] with an event stream on `tel`:
/// per-round spans with draft/verify/accept phase decomposition,
/// prefill/reshape charges as phase spans, admission verdicts with
/// predicted deadline slack, policy-fit snapshots (trace mode) and one
/// terminal finish-or-shed event per request — all stamped in **virtual
/// time** under the same schema the threaded batcher emits in wall time.
/// Emission consumes no randomness: a disabled handle reproduces the
/// plain entry point bit for bit.
pub fn simulate_trace_continuous_admission_tel(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
    tel: &Telemetry,
) -> (LatencyRecorder, Vec<RoundEvent>) {
    let (rec, rounds, _) =
        simulate_trace_continuous_admission_tel_prefix(cfg, policy, ctrl, trace, tel);
    (rec, rounds)
}

/// [`simulate_trace_continuous_admission_tel`] returning the
/// prefix-sharing mirror's lifetime counters next to the records:
/// `Some` when [`SimConfig::prefix_cache`] is on, `None` when off.
pub fn simulate_trace_continuous_admission_tel_prefix(
    cfg: &SimConfig,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    trace: &Trace,
    tel: &Telemetry,
) -> (LatencyRecorder, Vec<RoundEvent>, Option<PrefixStats>) {
    struct SimRow {
        id: u64,
        sent_at: f64,
        admitted_at: f64,
        plen: usize,
        /// committed tokens (prefill counts as the first one)
        generated: usize,
        batch_at_admit: usize,
        spec_at_admit: usize,
        deadline: Option<f64>,
        deferred: usize,
        /// workload class tag (drives per-class acceptance + ragged `s`)
        class: u8,
        /// virtual time the row's first token committed (end of its
        /// admission prefill — "prefill commits the first token")
        first_token_at: Option<f64>,
        /// accruing latency decomposition: every virtual-clock advance a
        /// live row sits through is charged to exactly one component, so
        /// the sealed waterfall tiles the DES latency with `other == 0`
        wf: Waterfall,
    }

    let mut prefix = if cfg.prefix_cache {
        Some(SimPrefix::new(cfg.kv_block.max(1)))
    } else {
        None
    };
    // prompts admitted at the current boundary, pending post-prefill
    // registration into the prefix mirror
    let mut admitted_ids: Vec<Vec<i32>> = Vec::new();
    let mut rng = Pcg64::with_stream(cfg.seed, 0xC0_11);
    let mut recorder = LatencyRecorder::new();
    let mut rounds: Vec<RoundEvent> = Vec::new();
    let may_speculate = policy.wants_speculation();
    let items = &trace.items;
    let mut live: Vec<SimRow> = Vec::new();
    let mut waiting: VecDeque<Waiting> = VecDeque::new();
    let mut next = 0usize;
    let mut t = 0.0f64;
    let mut epoch = 0usize;
    // padded bucket of the active epoch (0 = idle); admissions that push
    // the live batch past it trigger an epoch reshape
    let mut cur_bucket = 0usize;
    // round-scratch mirrors of the engine's arenas (see
    // batch_service_time_tel): reused accepted buffer + bulk PRNG draws,
    // plus the ragged-round class/draft-length buffers
    let mut accepted_rows: Vec<u32> = Vec::new();
    let mut draws = DrawBuffer::new();
    let mut live_classes: Vec<u8> = Vec::new();
    let mut s_choice: Vec<usize> = Vec::new();
    let mut fb_s_rows: Vec<u32> = Vec::new();
    let mut fb_classes: Vec<u8> = Vec::new();
    let mut drift_seen = policy.drift_flushes();

    while next < items.len() || !live.is_empty() || !waiting.is_empty() {
        if live.is_empty() {
            // idle: jump to the next arrival, opening a new epoch (a
            // deferred backlog is already due, so the clock holds)
            if waiting.is_empty() && next < items.len() && items[next].send_at > t {
                t = items[next].send_at;
            }
            epoch += 1;
            cur_bucket = 0;
        }

        // --- pull arrivals due at this boundary into the queue ---
        while next < items.len() && items[next].send_at <= t {
            waiting.push_back(Waiting {
                item: items[next].clone(),
                deferred: 0,
            });
            next += 1;
        }

        // --- plan admission over the queue ---
        let admit_n = if waiting.is_empty() {
            0
        } else {
            let candidates: Vec<Candidate> = waiting
                .iter()
                .map(|w| Candidate {
                    id: w.item.id,
                    sent_at: w.item.send_at,
                    deadline: w.item.deadline,
                    prompt_len: w.item.prompt.ids.len(),
                    tokens_left: cfg.max_new_tokens,
                    deferred: w.deferred,
                })
                .collect();
            let view = AdmissionView {
                now: t,
                live: live.len(),
                max_batch: cfg.max_batch,
                policy,
            };
            let queue: Vec<Waiting> = waiting.drain(..).collect();
            let out = apply_plan_to_queue(ctrl.plan(&candidates, &view), queue, live.len(), |w| {
                w.deferred += 1
            });
            for w in &out.shed {
                push_shed(&mut recorder, w, t);
            }
            if tel.active() {
                let fin = crate::admission::predicted_finish(
                    policy,
                    t,
                    cfg.max_new_tokens,
                    live.len() + out.queue.len(),
                    cfg.max_batch,
                );
                let slack = |d: Option<f64>| match (d, fin) {
                    (Some(d), Some(f)) => Some(d - f),
                    _ => None,
                };
                for w in &out.shed {
                    tel.admission(
                        t,
                        w.item.id,
                        "shed",
                        w.item.deadline,
                        slack(w.item.deadline),
                        w.deferred,
                    );
                    // a shed request's whole lifetime was queue wait
                    let mut wf = Waterfall::default();
                    wf.queue = t - w.item.send_at;
                    wf.deferred_rounds = w.deferred;
                    wf.seal(t - w.item.send_at);
                    tel.finish_attrib(
                        t,
                        w.item.id,
                        0,
                        true,
                        w.item.deadline.map(|d| d - t),
                        Some(wf),
                    );
                }
                for (i, w) in out.queue.iter().enumerate() {
                    let verdict = if i < out.admit_n { "admit" } else { "defer" };
                    tel.admission(
                        t,
                        w.item.id,
                        verdict,
                        w.item.deadline,
                        slack(w.item.deadline),
                        w.deferred,
                    );
                }
            }
            waiting = out.queue.into();
            out.admit_n
        };

        // --- admit the planned prefix, up to the live-capacity cap ---
        let mut n_admit = 0usize;
        let mut plen_sum = 0usize;
        // prompt tokens the LLM actually prefills (prefix hits shrink a
        // row's span to its unmatched suffix; == plen_sum when off)
        let mut prefill_sum = 0usize;
        let n_before = live.len();
        let admit_t = t;
        while n_admit < admit_n && live.len() < cfg.max_batch {
            let mut w = waiting.pop_front().expect("planned admits are queued");
            let plen = w.item.prompt.ids.len();
            let saved = match prefix.as_mut() {
                Some(p) => {
                    let saved = p.lookup_saved(&w.item.prompt.ids);
                    admitted_ids.push(std::mem::take(&mut w.item.prompt.ids));
                    saved
                }
                None => 0,
            };
            let mut wf = Waterfall::default();
            wf.queue = admit_t - w.item.send_at;
            wf.deferred_rounds = w.deferred;
            live.push(SimRow {
                id: w.item.id,
                sent_at: w.item.send_at,
                admitted_at: admit_t,
                plen,
                generated: 1, // prefill commits the first token
                batch_at_admit: 0,
                spec_at_admit: 0,
                deadline: w.item.deadline,
                deferred: w.deferred,
                class: w.item.class,
                first_token_at: None,
                wf,
            });
            plen_sum += plen;
            prefill_sum += plen - saved;
            n_admit += 1;
        }
        if live.is_empty() {
            // the whole backlog was shed: nothing to run this boundary
            continue;
        }
        if n_admit > 0 {
            let mean_plen = (plen_sum as f64 / n_admit as f64).ceil() as usize;
            let mean_prefill = (prefill_sum as f64 / n_admit as f64).ceil() as usize;
            let t_pre = t;
            t += cfg.llm.t_prefill(n_admit, mean_prefill);
            if may_speculate {
                // the SSM's dense cache is private: it ingests the full
                // prompts even when the LLM mapped shared blocks
                t += cfg.ssm.t_prefill(n_admit, mean_plen);
            }
            if tel.enabled() {
                tel.phase(t_pre, t - t_pre, PhaseKind::Prefill);
            }
            // the newcomers' prompts are prefilled now: register them
            // for later arrivals (map-at-admit / insert-after-prefill,
            // the engine's order — batchmates never hit each other)
            if let Some(p) = prefix.as_mut() {
                for ids in admitted_ids.drain(..) {
                    p.register(&ids);
                }
            }
            // every live row — resident rows included — sits through the
            // prefill of the newcomers
            let dpre = t - t_pre;
            for row in live.iter_mut() {
                row.wf.prefill += dpre;
            }
            // the newcomers' first tokens committed with this prefill
            let t_first = t;
            // epoch reshape: bucket growth carries the resident rows —
            // O(context) re-ingest under Dense, O(1) remap under Paged.
            // The bucket is monotone within an epoch (the real batcher
            // never shrinks an open epoch, so shrinking `live` must not
            // set up a phantom re-growth charge later).
            let want = sim_bucket_for(live.len());
            if cur_bucket != 0 && want > cur_bucket && n_before > 0 {
                let carried: Vec<usize> = live[..n_before]
                    .iter()
                    .map(|r| r.plen + r.generated)
                    .collect();
                let rcst = reshape_cost(cfg, &carried, live.len());
                if tel.enabled() {
                    tel.phase(t, rcst, PhaseKind::Reshape);
                }
                // the whole (grown) batch stalls while carried contexts
                // re-ingest
                for row in live.iter_mut() {
                    row.wf.reshape += rcst;
                }
                t += rcst;
            }
            cur_bucket = cur_bucket.max(want);
            let b = live.len();
            let s_now = if may_speculate { policy.choose(b, 8) } else { 0 };
            for row in live.iter_mut().rev().take(n_admit) {
                row.batch_at_admit = b;
                row.spec_at_admit = s_now;
                row.first_token_at = Some(t_first);
            }
        }

        // --- one decode round over the live rows ---
        let b = live.len();
        let ctx = live.iter().map(|r| r.plen + r.generated).sum::<usize>() / b;
        live_classes.clear();
        live_classes.extend(live.iter().map(|r| r.class));
        let classed = live_classes.iter().any(|&c| c != 0);
        if may_speculate {
            policy.choose_ragged_into(&live_classes, 8, &mut s_choice);
        } else {
            s_choice.clear();
            s_choice.resize(b, 0);
        }
        let s = s_choice.iter().copied().max().unwrap_or(0);
        let ragged = s_choice.iter().any(|&si| si != s);
        let rc = if ragged {
            round_cost_ragged(cfg, b, &s_choice, ctx)
        } else {
            round_cost(cfg, b, s, ctx)
        };
        accepted_rows.clear();
        let mut committed = 0usize;
        if s == 0 {
            for row in live.iter_mut() {
                row.generated += 1;
                committed += 1;
            }
        } else {
            draws.ensure(&mut rng, s_choice.iter().sum::<usize>());
            for (row, &si) in live.iter_mut().zip(s_choice.iter()) {
                let a = cfg.class_acceptance_at(row.class, t).sample(si, &mut draws);
                accepted_rows.push(a as u32);
                row.generated += a + 1;
                committed += a + 1;
            }
        }
        let t_round = t;
        t += rc;
        let accepted_total: usize = accepted_rows.iter().map(|&a| a as usize).sum();
        let drafted: usize = if s == 0 { 0 } else { s_choice.iter().sum() };
        // every live row sits through this round: accrue its phase split
        let (draft, verify, accept) = if ragged {
            round_phase_split_ragged(cfg, rc, b, &s_choice, ctx)
        } else {
            round_phase_split(cfg, rc, b, s, ctx)
        };
        for row in live.iter_mut() {
            row.wf.add_round_split(0.0, draft, verify, accept);
        }
        fb_s_rows.clear();
        if ragged {
            fb_s_rows.extend(s_choice.iter().map(|&si| si as u32));
        }
        fb_classes.clear();
        if classed {
            fb_classes.extend_from_slice(&live_classes);
        }
        let fb = RoundFeedback {
            live: b,
            width: b, // continuous rounds execute at exactly the live width
            s,
            accepted: std::mem::take(&mut accepted_rows),
            committed,
            round_time: rc,
            s_rows: std::mem::take(&mut fb_s_rows),
            classes: std::mem::take(&mut fb_classes),
        };
        policy.observe(&fb);
        let flushes = policy.drift_flushes();
        if flushes > drift_seen {
            drift_seen = flushes;
            tel.drift_flush(t_round);
        }
        // arrivals during the round join the queue now, so the timeline's
        // queue column reflects the post-round backlog
        while next < items.len() && items[next].send_at <= t {
            waiting.push_back(Waiting {
                item: items[next].clone(),
                deferred: 0,
            });
            next += 1;
        }
        let kvb = kv_blocks_of(cfg, live.iter().map(|r| r.plen + r.generated));
        // the epoch's padded bucket is the executing width; rows that
        // retired since the bucket grew leave padding slack behind
        let width = cur_bucket.max(sim_bucket_for(b));
        rounds.push(RoundEvent {
            t,
            epoch,
            live: b,
            width,
            queued: waiting.len(),
            s,
            drafted,
            accepted: accepted_total,
            round_cost: rc,
            kv_blocks: kvb,
        });
        if tel.active() {
            tel.round(
                t_round,
                rc,
                epoch,
                b,
                width,
                waiting.len(),
                s,
                committed,
                &fb.accepted,
                &fb.s_rows,
                kvb,
            );
            emit_phase_tiles(tel, t_round, draft, verify, accept);
            if tel.tracing() {
                tel.policy_fit(t, policy.snapshot());
            }
        }
        // reclaim the feedback's per-row buffers for the next round
        accepted_rows = fb.accepted;
        fb_s_rows = fb.s_rows;
        fb_classes = fb.classes;

        // --- retire finished rows immediately, freeing capacity ---
        let mut i = 0;
        while i < live.len() {
            if live[i].generated >= cfg.max_new_tokens {
                let row = live.swap_remove(i);
                if tel.active() {
                    let mut wf = row.wf;
                    wf.seal(t - row.sent_at);
                    tel.finish_attrib(
                        t,
                        row.id,
                        cfg.max_new_tokens,
                        false,
                        row.deadline.map(|d| d - t),
                        Some(wf),
                    );
                }
                recorder.push(RequestRecord {
                    id: row.id,
                    sent_at: row.sent_at,
                    started_at: row.admitted_at,
                    finished_at: t,
                    tokens: cfg.max_new_tokens,
                    batch: row.batch_at_admit,
                    spec_len: row.spec_at_admit,
                    shard: 0,
                    deadline: row.deadline,
                    deferred_rounds: row.deferred,
                    shed: false,
                    first_token_at: row.first_token_at,
                });
            } else {
                i += 1;
            }
        }
    }
    // hand unconsumed bulk draws back so the rng state matches the
    // sequential-sampling stream exactly
    draws.refund(&mut rng);
    (recorder, rounds, prefix.map(SimPrefix::finish))
}

/// Direct per-token latency at a fixed (batch, s) point — the Fig. 1 grid
/// metric, without queueing.  Averages `rounds` simulated decode rounds.
pub fn per_token_latency(
    cfg: &SimConfig,
    batch: usize,
    s: usize,
    ctx: usize,
    rounds: usize,
    rng: &mut Pcg64,
) -> f64 {
    let mut time = 0.0;
    let mut tokens = 0usize;
    for _ in 0..rounds {
        time += round_cost(cfg, batch, s, ctx);
        if s == 0 {
            tokens += batch;
        } else {
            for _ in 0..batch {
                tokens += cfg.acceptance.sample(s, rng) + 1;
            }
        }
    }
    time / (tokens as f64 / batch as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Prompt;
    use crate::policy::{Fixed, NoSpec};
    use crate::simulator::cost::ModelProfile;
    use crate::simulator::hw::GpuProfile;
    use crate::traffic::TrafficPattern;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::paper_default(
            CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
            CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        );
        c.max_new_tokens = 32; // keep tests quick
        c
    }

    fn pool() -> Vec<Prompt> {
        vec![Prompt {
            ids: vec![1; 12],
            text: String::new(),
        }]
    }

    #[test]
    fn speculation_speeds_up_small_batches() {
        let cfg = cfg();
        let mut rng = Pcg64::new(4);
        let (t_nospec, tok0, _) =
            batch_service_time(&cfg, &mut NoSpec, &[12], 0.0, &mut rng);
        let (t_spec, tok1, s) =
            batch_service_time(&cfg, &mut Fixed(4), &[12], 0.0, &mut rng);
        assert_eq!(tok0, 32);
        assert_eq!(tok1, 32);
        assert_eq!(s, 4);
        assert!(
            t_spec < 0.75 * t_nospec,
            "spec {t_spec}s not clearly faster than {t_nospec}s"
        );
    }

    #[test]
    fn conservation_every_request_served_once() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.3,
                cv: 1.0,
            },
            &pool(),
            200,
            9,
        );
        let rec = simulate_trace(&cfg, &mut Fixed(2), &trace);
        assert_eq!(rec.len(), 200);
        let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..200).collect::<Vec<u64>>());
        // causality: start >= send, finish > start
        for r in rec.records() {
            assert!(r.started_at >= r.sent_at - 1e-12);
            assert!(r.finished_at > r.started_at);
        }
    }

    #[test]
    fn fifo_batches_respect_capacity() {
        let cfg = cfg();
        // burst of 50 simultaneous arrivals: batches must cap at 16
        let items: Vec<crate::traffic::TraceItem> = (0..50)
            .map(|i| crate::traffic::TraceItem {
                id: i,
                send_at: 0.0,
                deadline: None,
                class: 0,
                prompt: pool()[0].clone(),
            })
            .collect();
        let trace = Trace { items };
        let rec = simulate_trace(&cfg, &mut NoSpec, &trace);
        let max_batch = rec.records().iter().map(|r| r.batch).max().unwrap();
        assert!(max_batch <= 16);
        // the later requests must have waited for earlier batches
        let first = rec.records().iter().find(|r| r.id == 0).unwrap();
        let last = rec.records().iter().find(|r| r.id == 49).unwrap();
        assert!(last.queue_delay() > first.queue_delay());
    }

    #[test]
    fn sparser_traffic_has_lower_latency() {
        let cfg = cfg();
        let p = |interval| TrafficPattern::Stationary { interval, cv: 1.0 };
        let t_dense = Trace::generate(&p(0.05), &pool(), 150, 5);
        let t_sparse = Trace::generate(&p(2.0), &pool(), 150, 5);
        let dense = simulate_trace(&cfg, &mut Fixed(2), &t_dense).summary().mean;
        let sparse = simulate_trace(&cfg, &mut Fixed(2), &t_sparse).summary().mean;
        assert!(
            dense > sparse,
            "queueing should raise dense-traffic latency: {dense} vs {sparse}"
        );
    }

    #[test]
    fn continuous_conserves_requests_and_causality() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.2,
                cv: 1.0,
            },
            &pool(),
            150,
            17,
        );
        let (rec, rounds) = simulate_trace_continuous(&cfg, &mut Fixed(2), &trace);
        assert_eq!(rec.len(), 150);
        let mut ids: Vec<u64> = rec.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..150).collect::<Vec<u64>>());
        for r in rec.records() {
            assert!(r.started_at >= r.sent_at - 1e-12);
            assert!(r.finished_at > r.started_at);
            assert!(r.batch >= 1 && r.batch <= cfg.max_batch);
        }
        assert!(!rounds.is_empty());
        assert!(rounds.iter().all(|e| e.live >= 1 && e.live <= cfg.max_batch));
        // round times are non-decreasing, costs positive, accepted bounded
        for w in rounds.windows(2) {
            assert!(w[1].t >= w[0].t);
        }
        assert!(rounds.iter().all(|e| e.round_cost > 0.0));
        assert!(rounds.iter().all(|e| e.accepted <= e.s * e.live));
    }

    #[test]
    fn continuous_batching_beats_static_under_load() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.1,
                cv: 1.0,
            },
            &pool(),
            200,
            21,
        );
        let static_mean = simulate_trace(&cfg, &mut Fixed(2), &trace).summary().mean;
        let (cont, _) = simulate_trace_continuous(&cfg, &mut Fixed(2), &trace);
        let cont_mean = cont.summary().mean;
        assert!(
            cont_mean < static_mean,
            "continuous ({cont_mean:.3}s) should beat static ({static_mean:.3}s)"
        );
    }

    #[test]
    fn grid_per_token_latency_reproduces_crossover() {
        // small batch: larger s helps; huge batch: s hurts — Fig. 1's core
        let cfg = cfg();
        let mut rng = Pcg64::new(11);
        let small_s1 = per_token_latency(&cfg, 1, 1, 128, 400, &mut rng);
        let small_s5 = per_token_latency(&cfg, 1, 5, 128, 400, &mut rng);
        assert!(small_s5 < small_s1, "b=1: s=5 ({small_s5}) !< s=1 ({small_s1})");
        let big_s1 = per_token_latency(&cfg, 32, 1, 128, 400, &mut rng);
        let big_s6 = per_token_latency(&cfg, 32, 6, 128, 400, &mut rng);
        assert!(big_s6 > big_s1, "b=32: s=6 ({big_s6}) !> s=1 ({big_s1})");
    }

    #[test]
    fn reshape_cost_is_free_under_paged_and_grows_with_context_under_dense() {
        let mut c = cfg();
        assert_eq!(reshape_cost(&c, &[], 8), 0.0, "no carried rows, no cost");
        assert_eq!(c.kv_layout, KvLayout::Paged, "paper default idealizes reshape");
        assert_eq!(
            reshape_cost(&c, &[120, 40], 8),
            0.0,
            "paged reshape is a free block-table remap"
        );
        c.kv_layout = KvLayout::Dense;
        let short = reshape_cost(&c, &[24], 8);
        let long = reshape_cost(&c, &[120], 8);
        assert!(short > 0.0);
        assert!(
            long > 2.0 * short,
            "dense reshape must scale with the carried context: {short} vs {long}"
        );
    }

    #[test]
    fn dense_reshapes_slow_the_continuous_path_paged_does_not() {
        // staggered heavy traffic: live batches repeatedly grow across
        // bucket edges, so the dense layout keeps paying re-ingest
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.05,
                cv: 1.0,
            },
            &pool(),
            150,
            31,
        );
        let paged = cfg();
        let mut dense = cfg();
        dense.kv_layout = KvLayout::Dense;
        let (rec_p, rounds_p) = simulate_trace_continuous(&paged, &mut Fixed(2), &trace);
        let (rec_d, _) = simulate_trace_continuous(&dense, &mut Fixed(2), &trace);
        assert_eq!(rec_p.len(), 150);
        assert_eq!(rec_d.len(), 150);
        let (mp, md) = (rec_p.summary().mean, rec_d.summary().mean);
        assert!(
            md > mp * 1.01,
            "dense reshape re-ingest should cost real latency: dense {md:.3}s \
             vs paged {mp:.3}s"
        );
        // the paged timeline records block utilization: every live row
        // holds at most ceil((12 prompt + 32 generated) / 16) = 3 blocks
        assert!(rounds_p.iter().any(|e| e.kv_blocks > 0));
        assert!(rounds_p.iter().all(|e| e.kv_blocks <= 3 * e.live));
    }

    #[test]
    fn layouts_agree_exactly_when_no_reshape_occurs() {
        // arrivals so sparse every request is served alone at bucket 1:
        // no bucket ever grows, so the two layouts charge identical costs
        // and consume identical randomness
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 10.0,
                cv: 0.1,
            },
            &pool(),
            20,
            5,
        );
        let paged = cfg();
        let mut dense = cfg();
        dense.kv_layout = KvLayout::Dense;
        let (rec_p, _) = simulate_trace_continuous(&paged, &mut Fixed(3), &trace);
        let (rec_d, _) = simulate_trace_continuous(&dense, &mut Fixed(3), &trace);
        let lat = |r: &LatencyRecorder| {
            let mut v: Vec<(u64, f64)> =
                r.records().iter().map(|x| (x.id, x.latency())).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        assert_eq!(lat(&rec_p), lat(&rec_d));
    }

    #[test]
    fn acceptance_drift_switches_the_process_at_the_cut() {
        let mut c = cfg();
        c.drift = Some(AcceptanceDrift {
            at: 10.0,
            after: AcceptanceProcess::PowerLaw {
                c: 0.5,
                gamma: 0.1,
            },
        });
        let before = c.acceptance_at(9.9).expected_accepted(4);
        let after = c.acceptance_at(10.0).expected_accepted(4);
        assert!(before > after, "drift must lower acceptance: {before} vs {after}");
        assert_eq!(
            c.acceptance_at(0.0).expected_accepted(4),
            c.acceptance.expected_accepted(4)
        );
    }
}
