//! Message-queue serving loop: the paper's server/client setting (Sec. 5.3).
//!
//! "We launch a server process and wrap the LLM inference as a service
//! that receives requests from a message queue and responds the generated
//! tokens via another message queue.  If there is more than one request in
//! the queue, they will be merged as one batched request (up to a maximal
//! batch size of 16)."
//!
//! Here the message queues are `std::sync::mpsc` channels and the server
//! is a dedicated worker thread that owns the [`Runtime`] + [`Engine`]
//! (PJRT handles are not `Send`, so the runtime is constructed *inside*
//! the worker).  Dynamic batching is exactly the paper's rule: drain
//! whatever is queued, cap at `max_batch`.  While a batch is being served
//! (seconds at 128 tokens/request), new arrivals accumulate in the queue —
//! their queueing delay is part of the measured latency.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::PolicySpec;
use crate::engine::{Engine, EngineConfig};
use crate::log_info;
use crate::metrics::{LatencyRecorder, RequestRecord};
use crate::runtime::Runtime;
use crate::scheduler::profiler::{profile, ProfilerConfig};
use crate::scheduler::{Lut, SpecPolicy};
use crate::traffic::Trace;

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// merge cap (paper: 16, limited by GPU memory)
    pub max_batch: usize,
    /// tokens generated per request (paper: 128)
    pub max_new_tokens: usize,
    pub engine: EngineConfig,
    /// profiling sample size when the policy is adaptive without a LUT
    pub profile_prompts: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_new_tokens: 128,
            engine: EngineConfig::default(),
            profile_prompts: 32,
        }
    }
}

/// A request on the inbound message queue.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// send time in seconds on the experiment clock (t_a)
    pub sent_at: f64,
}

/// A response on the outbound message queue.
#[derive(Debug, Clone)]
pub struct ServerResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub sent_at: f64,
    pub started_at: f64,
    pub finished_at: f64,
    pub batch: usize,
    pub spec_len: usize,
}

/// Inbound queue message.
pub enum ServerMsg {
    Request(ServerRequest),
    Shutdown,
}

/// Handle to a running server thread.
pub struct ServerHandle {
    pub requests: Sender<ServerMsg>,
    pub responses: Receiver<ServerResponse>,
    join: JoinHandle<Result<()>>,
    /// LUT resolved by the worker (present once ready when adaptive)
    lut_rx: Receiver<Option<Lut>>,
}

impl ServerHandle {
    /// Wait for the worker to finish startup (artifact load, warmup,
    /// optional profiling).  Returns the LUT when the policy is adaptive.
    pub fn wait_ready(&self, timeout: Duration) -> Result<Option<Lut>> {
        self.lut_rx
            .recv_timeout(timeout)
            .map_err(|_| anyhow!("server did not become ready within {timeout:?}"))
    }

    pub fn shutdown(self) -> Result<()> {
        let _ = self.requests.send(ServerMsg::Shutdown);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => bail!("server thread panicked"),
        }
    }
}

/// Spawn the serving worker thread.
///
/// `epoch` anchors the experiment clock: all timestamps are seconds since
/// it, shared with the client.  When `policy` is adaptive and `lut` is
/// `None`, the worker runs the offline profiling stage before accepting
/// traffic (paper Sec. 4) using the dataset's *profile* split.
pub fn spawn_server(
    artifacts_dir: std::path::PathBuf,
    cfg: ServerConfig,
    policy: PolicySpec,
    lut: Option<Lut>,
    epoch: Instant,
) -> ServerHandle {
    let (req_tx, req_rx) = channel::<ServerMsg>();
    let (resp_tx, resp_rx) = channel::<ServerResponse>();
    let (lut_tx, lut_rx) = channel::<Option<Lut>>();

    let join = std::thread::Builder::new()
        .name("specbatch-server".into())
        .spawn(move || {
            worker(
                artifacts_dir,
                cfg,
                policy,
                lut,
                epoch,
                req_rx,
                resp_tx,
                lut_tx,
            )
        })
        .expect("spawning server thread");

    ServerHandle {
        requests: req_tx,
        responses: resp_rx,
        join,
        lut_rx,
    }
}

#[allow(clippy::too_many_arguments)]
fn worker(
    artifacts_dir: std::path::PathBuf,
    cfg: ServerConfig,
    policy_spec: PolicySpec,
    lut: Option<Lut>,
    epoch: Instant,
    req_rx: Receiver<ServerMsg>,
    resp_tx: Sender<ServerResponse>,
    lut_tx: Sender<Option<Lut>>,
) -> Result<()> {
    let rt = Runtime::load(&artifacts_dir)?;
    let mut engine = Engine::new(&rt, cfg.engine.clone())?;

    // resolve the policy, profiling if necessary
    let (policy, lut_used) = match policy_spec {
        PolicySpec::None => (SpecPolicy::NoSpec, None),
        PolicySpec::Fixed(s) => (SpecPolicy::Fixed(s), None),
        PolicySpec::Adaptive => {
            let lut = match lut {
                Some(l) => l,
                None => {
                    let dataset = rt.dataset()?;
                    let mut prng = crate::util::prng::Pcg64::new(0xADA);
                    let prompts = dataset.sample_profile(&mut prng, cfg.profile_prompts);
                    let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
                    pcfg.buckets.retain(|&b| b <= cfg.max_batch);
                    log_info!("server: profiling for the adaptive LUT…");
                    profile(&mut engine, &prompts, &pcfg)?.lut
                }
            };
            log_info!("server: adaptive LUT = {}", lut.to_json().compact());
            (SpecPolicy::Adaptive(lut.clone()), Some(lut))
        }
    };
    // precompile before going live: no compilation on the request path
    rt.warmup(cfg.max_batch, rt.manifest.verify_lengths.iter().copied().max().unwrap_or(0))?;
    lut_tx
        .send(lut_used)
        .map_err(|_| anyhow!("server handle dropped before ready"))?;

    let mut pending: Vec<ServerRequest> = Vec::new();
    let mut shutdown = false;
    while !shutdown {
        // block for the first request, then drain whatever queued
        if pending.is_empty() {
            match req_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ServerMsg::Request(r)) => pending.push(r),
                Ok(ServerMsg::Shutdown) => break,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        while pending.len() < cfg.max_batch {
            match req_rx.try_recv() {
                Ok(ServerMsg::Request(r)) => pending.push(r),
                Ok(ServerMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }

        let batch: Vec<ServerRequest> =
            pending.drain(..pending.len().min(cfg.max_batch)).collect();
        if batch.is_empty() {
            continue;
        }
        let started_at = epoch.elapsed().as_secs_f64();
        let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
        let out = engine.generate_batch(&prompts, cfg.max_new_tokens, &policy)?;
        let finished_at = epoch.elapsed().as_secs_f64();
        let spec_len = out.stats.spec_lens.first().copied().unwrap_or(0);
        for (req, tokens) in batch.into_iter().zip(out.tokens) {
            let resp = ServerResponse {
                id: req.id,
                tokens,
                sent_at: req.sent_at,
                started_at,
                finished_at,
                batch: prompts.len(),
                spec_len,
            };
            if resp_tx.send(resp).is_err() {
                // harness went away; stop serving
                return Ok(());
            }
        }
    }
    Ok(())
}

/// Replay a trace against a server in real time (the client process).
///
/// Sleeps until each item's `send_at`, stamps it on the experiment clock,
/// and sends it.  Returns the number of requests sent.
pub fn run_client(trace: &Trace, requests: &Sender<ServerMsg>, epoch: Instant) -> Result<usize> {
    for item in &trace.items {
        let now = epoch.elapsed().as_secs_f64();
        if item.send_at > now {
            std::thread::sleep(Duration::from_secs_f64(item.send_at - now));
        }
        let req = ServerRequest {
            id: item.id,
            prompt: item.prompt.ids.clone(),
            sent_at: epoch.elapsed().as_secs_f64(),
        };
        requests
            .send(ServerMsg::Request(req))
            .map_err(|_| anyhow!("server hung up mid-trace"))?;
    }
    Ok(trace.items.len())
}

/// Run one full client/server experiment: spawn server, wait until ready,
/// replay the trace, collect all responses.  Returns the latency records
/// (and the LUT, when adaptive).
pub fn run_experiment(
    artifacts_dir: std::path::PathBuf,
    cfg: ServerConfig,
    policy: PolicySpec,
    lut: Option<Lut>,
    trace: &Trace,
) -> Result<(LatencyRecorder, Option<Lut>)> {
    let epoch = Instant::now();
    let server = spawn_server(artifacts_dir, cfg, policy, lut, epoch);
    let lut_used = server.wait_ready(Duration::from_secs(600))?;

    let n = trace.len();
    let tx = server.requests.clone();
    let trace_cloned = trace.clone();
    let client = std::thread::Builder::new()
        .name("specbatch-client".into())
        .spawn(move || run_client(&trace_cloned, &tx, epoch))
        .expect("spawning client thread");

    let mut recorder = LatencyRecorder::new();
    while recorder.len() < n {
        let resp = server
            .responses
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow!("timed out waiting for responses ({}/{n})", recorder.len()))?;
        recorder.push(RequestRecord {
            id: resp.id,
            sent_at: resp.sent_at,
            started_at: resp.started_at,
            finished_at: resp.finished_at,
            tokens: resp.tokens.len(),
            batch: resp.batch,
            spec_len: resp.spec_len,
        });
    }
    client
        .join()
        .map_err(|_| anyhow!("client thread panicked"))??;
    server.shutdown()?;
    Ok((recorder, lut_used))
}
