//! Message-queue serving loop: the paper's server/client setting (Sec. 5.3),
//! with a selectable scheduling mode.
//!
//! "We launch a server process and wrap the LLM inference as a service
//! that receives requests from a message queue and responds the generated
//! tokens via another message queue.  If there is more than one request in
//! the queue, they will be merged as one batched request (up to a maximal
//! batch size of 16)."
//!
//! Here the message queues are `std::sync::mpsc` channels and the server
//! is a dedicated worker thread that owns the engine (PJRT handles are
//! not `Send`, so the runtime is constructed *inside* the worker).  Two
//! scheduling modes:
//!
//! * [`SchedulingMode::Static`] — the paper's rule: drain whatever is
//!   queued, serve the batch to completion, repeat.  While a batch is
//!   served (seconds at 128 tokens/request), arrivals queue — their
//!   queueing delay is part of the measured latency.
//! * [`SchedulingMode::Continuous`] — the round-granular
//!   [`ContinuousBatcher`]: arrivals are admitted into free rows at round
//!   boundaries, finished rows retire immediately, and the speculation
//!   policy sees the live batch size every round.
//!
//! The worker runs on the real PJRT artifacts ([`Backend::Artifacts`],
//! `--features pjrt`) or on the deterministic stub pair
//! ([`Backend::Stub`], always available).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::admission::{
    apply_plan_to_queue, build_controller, predicted_finish, AdmissionView, Candidate,
};
use crate::batcher::{BatchRequest, BatcherConfig, ContinuousBatcher, ShedRequest};
use crate::cluster::server::ShardGauge;
use crate::cluster::ShardBreakdown;
use crate::config::{AdmissionSpec, PolicySpec, RouterSpec};
use crate::engine::{prefix_cache_from_env, Engine, EngineConfig};
use crate::kvcache::prefix::PrefixStats;
use crate::kvcache::{KvBlockStats, KvLayout};
use crate::log_info;
use crate::metrics::{LatencyRecorder, RequestRecord, RoundEvent};
use crate::policy::{Fixed, LutAdaptive, ModelBased, NoSpec, SpeculationPolicy};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
#[cfg(feature = "pjrt")]
use crate::scheduler::profiler::{profile, ProfilerConfig};
use crate::scheduler::Lut;
use crate::simulator::{simulated_lut, CostModel, GpuProfile, ModelProfile, SimConfig};
use crate::telemetry::attrib::Waterfall;
use crate::telemetry::Telemetry;
use crate::testkit::stub::StubSpec;
use crate::traffic::Trace;
use crate::util::json::Json;

/// What the worker thread builds its engine from.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Real PJRT runtime over `make artifacts` output.
    #[cfg(feature = "pjrt")]
    Artifacts(std::path::PathBuf),
    /// Deterministic stub model pair — no artifacts needed.
    Stub(StubSpec),
}

/// How queued requests are merged into device batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingMode {
    /// Batch-to-completion (the paper's server).
    Static,
    /// Iteration-level admission/retirement via the continuous batcher.
    Continuous,
}

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// merge cap (paper: 16, limited by GPU memory)
    pub max_batch: usize,
    /// tokens generated per request (paper: 128)
    pub max_new_tokens: usize,
    pub engine: EngineConfig,
    /// profiling sample size when the policy is adaptive without a LUT
    pub profile_prompts: usize,
    pub mode: SchedulingMode,
    /// worker shards serving in parallel; > 1 selects the threaded
    /// cluster path (`crate::cluster::server`, stub backend, continuous
    /// mode), each shard owning its own engine + batcher + policy
    pub workers: usize,
    /// how the dispatcher routes arrivals across shards when `workers > 1`
    pub router: RouterSpec,
    /// per-slot KV organisation: `Paged` makes epoch reshape a block-
    /// table remap (stub backend only).  Defaults to the
    /// `SPECBATCH_KV_LAYOUT` env override, else dense; the worker honours
    /// an explicit non-default choice here OR on `engine.kv_layout`
    /// (whichever deviates from the default wins)
    pub kv_layout: KvLayout,
    /// admission control consulted before every batch/round: queue
    /// ordering, deferral and shedding.  Defaults to the
    /// `SPECBATCH_ADMISSION` env override, else FIFO (with no deadlines
    /// on the requests every controller behaves exactly like FIFO)
    pub admission: AdmissionSpec,
    /// prefix-sharing KV cache (paged layout only).  Same resolution rule
    /// as `kv_layout`: defaults to the `SPECBATCH_PREFIX_CACHE` env
    /// override, and an explicit non-default choice here OR on
    /// `engine.prefix_cache` wins
    pub prefix_cache: bool,
    /// observability handle the worker's engine (and, `workers > 1`, the
    /// dispatcher and every shard's engine via [`Telemetry::for_shard`])
    /// emit on.  Defaults to the disabled handle: every emitter is an
    /// early-return on a `None` arc, so the hot path pays nothing
    pub telemetry: Telemetry,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_new_tokens: 128,
            engine: EngineConfig::default(),
            profile_prompts: 32,
            mode: SchedulingMode::Static,
            workers: 1,
            router: RouterSpec::RoundRobin,
            kv_layout: KvLayout::default_layout(),
            admission: AdmissionSpec::default_spec(),
            prefix_cache: prefix_cache_from_env(),
            telemetry: Telemetry::disabled(),
        }
    }
}

/// A request on the inbound message queue.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// send time in seconds on the experiment clock (t_a)
    pub sent_at: f64,
    /// absolute deadline on the experiment clock (None = no SLO)
    pub deadline: Option<f64>,
    /// seconds the request spent in the cluster dispatcher before it was
    /// forwarded to a shard (stamped by the dispatcher; 0 single-worker).
    /// Surfaces as the `route_hop` waterfall component
    pub route_hop: f64,
    /// workload class tag (0 = default) — forwarded to the batcher so
    /// ragged policies can key per-row speculation on it
    pub class: u8,
}

/// A response on the outbound message queue.  A shed request still gets a
/// response (`shed == true`, no tokens) — the client-side accounting must
/// see every request leave the system.
#[derive(Debug, Clone)]
pub struct ServerResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub sent_at: f64,
    pub started_at: f64,
    pub finished_at: f64,
    pub batch: usize,
    pub spec_len: usize,
    /// absolute deadline, if the request carried one
    pub deadline: Option<f64>,
    /// round boundaries admission control deferred the request at
    pub deferred_rounds: usize,
    /// true when admission control shed the request unserved
    pub shed: bool,
    /// experiment-clock instant the first generated token was committed
    /// (end of the request's prefill; `None` for shed requests) — the
    /// numerator of TTFT = `first_token_at - sent_at`
    pub first_token_at: Option<f64>,
}

/// Inbound queue message.
pub enum ServerMsg {
    Request(ServerRequest),
    Shutdown,
}

/// What a worker delivers at shutdown: its per-round timeline, the
/// policy's fitted-model snapshot (online policies only), the KV
/// block-pool accounting (paged layout only — the leak tests assert
/// `is_leak_free()` on it), and the admission-control totals.
#[derive(Debug, Default)]
pub struct WorkerReport {
    pub timeline: Vec<RoundEvent>,
    pub policy_snapshot: Option<Json>,
    pub kv_blocks: Option<KvBlockStats>,
    /// prefix-cache counters, snapshotted before the shutdown
    /// `clear_prefix_cache` that returns shared blocks to the pool (so
    /// `kv_blocks.is_leak_free()` keeps meaning "no block unaccounted")
    pub prefix: Option<PrefixStats>,
    /// admission defer events (one per candidate per boundary held back)
    pub deferrals: usize,
    /// requests shed by admission control
    pub sheds: usize,
}

/// Handle to a running server thread.
pub struct ServerHandle {
    pub requests: Sender<ServerMsg>,
    pub responses: Receiver<ServerResponse>,
    join: JoinHandle<Result<()>>,
    /// LUT resolved by the worker (present once ready when adaptive /
    /// model-based, where it seeds the cold-start fallback)
    lut_rx: Receiver<Option<Lut>>,
    /// timeline + snapshot + block accounting, delivered on exit
    report_rx: Receiver<WorkerReport>,
}

impl ServerHandle {
    /// Wait for the worker to finish startup (artifact load, warmup,
    /// optional profiling).  Returns the LUT when the policy is adaptive.
    pub fn wait_ready(&self, timeout: Duration) -> Result<Option<Lut>> {
        self.lut_rx
            .recv_timeout(timeout)
            .map_err(|_| anyhow!("server did not become ready within {timeout:?}"))
    }

    /// Stop the worker and collect its shutdown report (per-round
    /// timeline, fitted-model snapshot, KV block accounting).
    pub fn shutdown(self) -> Result<WorkerReport> {
        let _ = self.requests.send(ServerMsg::Shutdown);
        match self.join.join() {
            Ok(r) => r?,
            Err(_) => bail!("server thread panicked"),
        }
        Ok(self.report_rx.try_recv().unwrap_or_default())
    }
}

/// Spawn the serving worker thread.
///
/// `epoch` anchors the experiment clock: all timestamps are seconds since
/// it, shared with the client.  When `policy` is adaptive and `lut` is
/// `None`, the worker resolves a LUT before accepting traffic: offline
/// profiling on the dataset's *profile* split (paper Sec. 4) on the
/// artifact backend, or the calibrated simulator's LUT on the stub
/// backend (wall-clock profiling of a µs-fast stub is meaningless).
pub fn spawn_server(
    backend: Backend,
    cfg: ServerConfig,
    policy: PolicySpec,
    lut: Option<Lut>,
    epoch: Instant,
) -> ServerHandle {
    let (req_tx, req_rx) = channel::<ServerMsg>();
    let (resp_tx, resp_rx) = channel::<ServerResponse>();
    let (lut_tx, lut_rx) = channel::<Option<Lut>>();
    let (report_tx, report_rx) = channel::<WorkerReport>();

    let join = std::thread::Builder::new()
        .name("specbatch-server".into())
        .spawn(move || {
            worker(
                backend,
                cfg,
                policy,
                lut,
                epoch,
                req_rx,
                resp_tx,
                lut_tx,
                report_tx,
                None,
            )
        })
        .expect("spawning server thread");

    ServerHandle {
        requests: req_tx,
        responses: resp_rx,
        join,
        lut_rx,
        report_rx,
    }
}

/// Simulator-derived LUT for the stub backend (deterministic, fast).
fn stub_adaptive_lut(engine: &Engine<'_>, max_batch: usize) -> Lut {
    let sim = SimConfig::paper_default(
        CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
        CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
    );
    let mut buckets: Vec<usize> = engine
        .limits()
        .batch_buckets
        .iter()
        .copied()
        .filter(|&b| b <= max_batch)
        .collect();
    if buckets.is_empty() {
        buckets.push(engine.limits().batch_buckets[0]);
    }
    let s_max = engine.limits().max_spec_overall().max(1);
    simulated_lut(&sim, &buckets, s_max, 80)
}

/// Resolve a parsed [`PolicySpec`] into a live policy object, given a
/// resolver for the offline LUT (profiling on the artifact backend, the
/// calibrated simulator on the stub backend).  Returns the policy and
/// the LUT it is seeded with, if any.
fn resolve_policy(
    spec: &PolicySpec,
    lut: Option<Lut>,
    resolve_lut: impl FnOnce() -> Result<Lut>,
) -> Result<(Box<dyn SpeculationPolicy>, Option<Lut>)> {
    Ok(match spec {
        PolicySpec::None => (Box::new(NoSpec) as Box<dyn SpeculationPolicy>, None),
        PolicySpec::Fixed(s) => (Box::new(Fixed(*s)), None),
        PolicySpec::Adaptive => {
            let lut = match lut {
                Some(l) => l,
                None => resolve_lut()?,
            };
            (Box::new(LutAdaptive(lut.clone())), Some(lut))
        }
        PolicySpec::ModelBased => {
            // the LUT seeds the online policy's cold-start fallback
            let lut = match lut {
                Some(l) => l,
                None => resolve_lut()?,
            };
            (Box::new(ModelBased::new(lut.clone())), Some(lut))
        }
    })
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn worker(
    backend: Backend,
    cfg: ServerConfig,
    policy_spec: PolicySpec,
    lut: Option<Lut>,
    epoch: Instant,
    req_rx: Receiver<ServerMsg>,
    resp_tx: Sender<ServerResponse>,
    lut_tx: Sender<Option<Lut>>,
    report_tx: Sender<WorkerReport>,
    gauge: Option<std::sync::Arc<ShardGauge>>,
) -> Result<()> {
    // two knobs can name the layout (the embedded EngineConfig and the
    // server-level field, both defaulting to the env-driven layout); an
    // explicit non-default choice on either wins, so setting just one of
    // them is never silently clobbered by the other's default
    let default_layout = KvLayout::default_layout();
    let default_prefix = prefix_cache_from_env();
    let engine_cfg = EngineConfig {
        kv_layout: if cfg.kv_layout != default_layout {
            cfg.kv_layout
        } else {
            cfg.engine.kv_layout
        },
        prefix_cache: if cfg.prefix_cache != default_prefix {
            cfg.prefix_cache
        } else {
            cfg.engine.prefix_cache
        },
        ..cfg.engine.clone()
    };
    // announce readiness, serve, deliver timeline + model snapshot +
    // block accounting — shared by both backends once the engine and
    // policy are resolved
    let go = |engine: &mut Engine<'_>,
              mut policy: Box<dyn SpeculationPolicy>,
              lut_used: Option<Lut>|
     -> Result<()> {
        engine.set_telemetry(cfg.telemetry.clone());
        lut_tx
            .send(lut_used)
            .map_err(|_| anyhow!("server handle dropped before ready"))?;
        let (timeline, deferrals, sheds) = serve_loop(
            engine,
            &cfg,
            policy.as_mut(),
            epoch,
            &req_rx,
            &resp_tx,
            gauge.as_deref(),
        )?;
        // snapshot the prefix counters, then drop the cache's block
        // references: after a full eviction the pool must be back at
        // capacity, which is exactly what the leak asserts check
        let prefix = engine.prefix_stats();
        engine.clear_prefix_cache();
        let _ = report_tx.send(WorkerReport {
            timeline,
            policy_snapshot: policy.snapshot(),
            kv_blocks: engine.kv_block_stats(),
            prefix,
            deferrals,
            sheds,
        });
        Ok(())
    };
    match backend {
        #[cfg(feature = "pjrt")]
        Backend::Artifacts(artifacts_dir) => {
            let rt = Runtime::load(&artifacts_dir)?;
            let mut engine = Engine::new(&rt, engine_cfg)?;
            // resolve the policy, profiling if necessary
            let (policy, lut_used) = {
                let engine = &mut engine;
                let rt = &rt;
                let cfg = &cfg;
                resolve_policy(&policy_spec, lut, move || {
                    let dataset = rt.dataset()?;
                    let mut prng = crate::util::prng::Pcg64::new(0xADA);
                    let prompts = dataset.sample_profile(&mut prng, cfg.profile_prompts);
                    let mut pcfg = ProfilerConfig::from_manifest(&rt.manifest);
                    pcfg.buckets.retain(|&b| b <= cfg.max_batch);
                    log_info!("server: profiling for the offline LUT…");
                    let lut = profile(engine, &prompts, &pcfg)?.lut;
                    log_info!("server: LUT = {}", lut.to_json().compact());
                    Ok(lut)
                })?
            };
            // precompile before going live: no compilation on the request path
            rt.warmup(
                cfg.max_batch,
                rt.manifest.verify_lengths.iter().copied().max().unwrap_or(0),
            )?;
            go(&mut engine, policy, lut_used)
        }
        Backend::Stub(spec) => {
            let mut engine = Engine::stub(spec, engine_cfg)?;
            let (policy, lut_used) = resolve_policy(&policy_spec, lut, || {
                log_info!("server: stub backend — using the simulator's LUT");
                Ok(stub_adaptive_lut(&engine, cfg.max_batch))
            })?;
            go(&mut engine, policy, lut_used)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_loop(
    engine: &mut Engine<'_>,
    cfg: &ServerConfig,
    policy: &mut dyn SpeculationPolicy,
    epoch: Instant,
    req_rx: &Receiver<ServerMsg>,
    resp_tx: &Sender<ServerResponse>,
    gauge: Option<&ShardGauge>,
) -> Result<(Vec<RoundEvent>, usize, usize)> {
    match cfg.mode {
        SchedulingMode::Static => serve_static(engine, cfg, policy, epoch, req_rx, resp_tx),
        SchedulingMode::Continuous => {
            serve_continuous(engine, cfg, policy, epoch, req_rx, resp_tx, gauge)
        }
    }
}

/// The wire response for a shed request: no tokens, timestamps at the
/// shed decision.
fn shed_response(shed: ShedRequest) -> ServerResponse {
    ServerResponse {
        id: shed.id,
        tokens: Vec::new(),
        sent_at: shed.sent_at,
        started_at: shed.shed_at,
        finished_at: shed.shed_at,
        batch: 0,
        spec_len: 0,
        deadline: shed.deadline,
        deferred_rounds: shed.deferred_rounds,
        shed: true,
        first_token_at: None,
    }
}

/// The paper's batch-to-completion loop: drain whatever is queued, let
/// the admission controller order/shed the backlog, serve the admitted
/// prefix (capped at `max_batch`) with `generate_batch`, respond, repeat.
/// Batch-to-completion has no live rows at a planning point, so the
/// controller never defers here (`SloAware` only sheds hopeless
/// requests); FIFO admission reproduces the pre-subsystem loop exactly.
fn serve_static(
    engine: &mut Engine<'_>,
    cfg: &ServerConfig,
    policy: &mut dyn SpeculationPolicy,
    epoch: Instant,
    req_rx: &Receiver<ServerMsg>,
    resp_tx: &Sender<ServerResponse>,
) -> Result<(Vec<RoundEvent>, usize, usize)> {
    let mut ctrl = build_controller(cfg.admission);
    let tel = cfg.telemetry.clone();
    let mut timeline: Vec<RoundEvent> = Vec::new();
    // (request, boundaries it has been deferred at)
    let mut pending: Vec<(ServerRequest, usize)> = Vec::new();
    let mut shutdown = false;
    let mut batch_idx = 0usize;
    let mut deferrals = 0usize;
    let mut sheds = 0usize;
    // pull everything the channel currently holds into `pending`
    let drain = |pending: &mut Vec<(ServerRequest, usize)>, shutdown: &mut bool| loop {
        match req_rx.try_recv() {
            Ok(ServerMsg::Request(r)) => pending.push((r, 0)),
            Ok(ServerMsg::Shutdown) => {
                *shutdown = true;
                break;
            }
            Err(_) => break,
        }
    };
    while !shutdown {
        // block for the first request, then drain whatever queued
        if pending.is_empty() {
            match req_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ServerMsg::Request(r)) => pending.push((r, 0)),
                Ok(ServerMsg::Shutdown) => break,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        drain(&mut pending, &mut shutdown);

        // admission plan over the whole backlog (live == 0: the previous
        // batch ran to completion before this boundary)
        let now = epoch.elapsed().as_secs_f64();
        let candidates: Vec<Candidate> = pending
            .iter()
            .map(|(r, deferred)| Candidate {
                id: r.id,
                sent_at: r.sent_at,
                deadline: r.deadline,
                prompt_len: r.prompt.len(),
                tokens_left: cfg.max_new_tokens,
                deferred: *deferred,
            })
            .collect();
        let view = AdmissionView {
            now,
            live: 0,
            max_batch: cfg.max_batch,
            policy,
        };
        let backlog: Vec<(ServerRequest, usize)> = pending.drain(..).collect();
        let out = apply_plan_to_queue(ctrl.plan(&candidates, &view), backlog, 0, |p| p.1 += 1);
        deferrals += out.deferred;
        // predicted deadline slack on the experiment clock (events are
        // stamped on the telemetry clock, like the engine's)
        let pred_fin = if tel.active() {
            predicted_finish(&*policy, now, cfg.max_new_tokens, out.queue.len(), cfg.max_batch)
        } else {
            None
        };
        let slack = |d: Option<f64>| match (d, pred_fin) {
            (Some(d), Some(f)) => Some(d - f),
            _ => None,
        };
        for (r, deferred) in out.shed {
            sheds += 1;
            if tel.active() {
                tel.admission(tel.now(), r.id, "shed", r.deadline, slack(r.deadline), deferred);
                // a shed request's whole lifetime was queue wait
                let mut wf = Waterfall::default();
                wf.queue = now - r.sent_at;
                wf.deferred_rounds = deferred;
                wf.seal(now - r.sent_at);
                tel.finish_attrib(tel.now(), r.id, 0, true, r.deadline.map(|d| d - now), Some(wf));
            }
            let resp = shed_response(ShedRequest {
                id: r.id,
                sent_at: r.sent_at,
                deadline: r.deadline,
                shed_at: now,
                deferred_rounds: deferred,
            });
            if resp_tx.send(resp).is_err() {
                return Ok((timeline, deferrals, sheds));
            }
        }
        // the admissible prefix forms the batch (capped); over-capacity
        // admits, then defers, stay pending in order — each keeping its
        // deferral count
        let n_batch = out.admit_n.min(cfg.max_batch);
        if tel.active() {
            for (i, (r, deferred)) in out.queue.iter().enumerate() {
                let verdict = if i < n_batch { "admit" } else { "defer" };
                tel.admission(tel.now(), r.id, verdict, r.deadline, slack(r.deadline), *deferred);
            }
        }
        let mut rest = out.queue;
        let batch: Vec<(ServerRequest, usize)> = rest.drain(..n_batch).collect();
        pending.extend(rest);
        if batch.is_empty() {
            continue;
        }
        batch_idx += 1;
        engine.set_round_context(batch_idx, pending.len());
        let started_at = epoch.elapsed().as_secs_f64();
        let prompts: Vec<Vec<i32>> = batch.iter().map(|(r, _)| r.prompt.clone()).collect();
        let out = engine.generate_batch(&prompts, cfg.max_new_tokens, policy)?;
        let finished_at = epoch.elapsed().as_secs_f64();
        // pick up what arrived while the batch was being served, so the
        // timeline's queue column reflects real pressure (per-round
        // timestamps are not observable batch-to-completion — every round
        // of the batch carries its start time)
        drain(&mut pending, &mut shutdown);
        // batch-to-completion attribution: every request in the batch sat
        // through the same prefill and every decode round, so one shared
        // waterfall body serves the whole batch — only the queue wait
        // (and therefore the sealed `other` residue) is per-request
        let mut body = Waterfall::default();
        let mut rounds_wall = 0.0f64;
        for info in &out.stats.per_round {
            body.add_round_split(
                info.phases.catch_up,
                info.phases.draft,
                info.phases.verify,
                info.phases.accept,
            );
            rounds_wall += info.round_time;
            timeline.push(RoundEvent {
                t: started_at,
                epoch: batch_idx,
                live: info.live,
                width: info.width,
                queued: pending.len(),
                s: info.s,
                drafted: info.drafted,
                accepted: info.accepted,
                round_cost: info.round_time,
                // batch-to-completion rounds are reconstructed after the
                // epoch released its blocks; no per-round sample exists
                kv_blocks: 0,
            });
        }
        // what generate_batch spent outside decode rounds is the prefill
        body.prefill = ((finished_at - started_at) - rounds_wall).max(0.0);
        // batch-to-completion commits every row's first token when the
        // shared prefill finishes
        let first_token_at = started_at + body.prefill;
        if tel.tracing() {
            tel.policy_fit(tel.now(), policy.snapshot());
        }
        let spec_len = out.stats.spec_lens.first().copied().unwrap_or(0);
        for ((req, deferred), tokens) in batch.into_iter().zip(out.tokens) {
            if tel.active() {
                let mut wf = body;
                wf.queue = started_at - req.sent_at;
                wf.deferred_rounds = deferred;
                wf.seal(finished_at - req.sent_at);
                tel.finish_attrib(
                    tel.now(),
                    req.id,
                    tokens.len(),
                    false,
                    req.deadline.map(|d| d - finished_at),
                    Some(wf),
                );
            }
            let resp = ServerResponse {
                id: req.id,
                tokens,
                sent_at: req.sent_at,
                started_at,
                finished_at,
                batch: prompts.len(),
                spec_len,
                deadline: req.deadline,
                deferred_rounds: deferred,
                shed: false,
                first_token_at: Some(first_token_at),
            };
            if resp_tx.send(resp).is_err() {
                // harness went away; stop serving
                return Ok((timeline, deferrals, sheds));
            }
        }
        // batch boundary = safe point for flight-recorder dumps
        for p in tel.flight_poll() {
            log_info!("server: flight recorder dumped {}", p.display());
        }
    }
    Ok((timeline, deferrals, sheds))
}

/// Map a completed batcher request onto the wire format: queueing ends at
/// admission, so `started_at` is the admission time.
fn to_response(fin: crate::batcher::FinishedRequest) -> ServerResponse {
    ServerResponse {
        id: fin.id,
        tokens: fin.tokens,
        sent_at: fin.sent_at,
        started_at: fin.admitted_at,
        finished_at: fin.finished_at,
        batch: fin.batch_at_admit,
        spec_len: fin.spec_at_admit,
        deadline: fin.deadline,
        deferred_rounds: fin.deferred_rounds,
        shed: false,
        first_token_at: fin.first_token_at,
    }
}

/// The continuous loop: one batcher round per iteration, draining the
/// inbound channel between rounds so arrivals admit at round boundaries.
/// A cluster worker passes a [`ShardGauge`] so the dispatcher's router
/// can see this shard's load and fitted marginal cost between rounds.
#[allow(clippy::too_many_arguments)]
fn serve_continuous(
    engine: &mut Engine<'_>,
    cfg: &ServerConfig,
    policy: &mut dyn SpeculationPolicy,
    epoch: Instant,
    req_rx: &Receiver<ServerMsg>,
    resp_tx: &Sender<ServerResponse>,
    gauge: Option<&ShardGauge>,
) -> Result<(Vec<RoundEvent>, usize, usize)> {
    let mut batcher = ContinuousBatcher::with_admission(
        BatcherConfig {
            max_batch: cfg.max_batch,
            max_new_tokens: cfg.max_new_tokens,
        },
        build_controller(cfg.admission),
    );
    let publish = |batcher: &ContinuousBatcher, policy: &dyn SpeculationPolicy, now: f64| {
        if let Some(g) = gauge {
            let load = batcher.live_rows() + batcher.queue_len();
            g.publish(
                batcher.live_rows(),
                batcher.queue_len(),
                crate::cluster::marginal_cost(policy, load, cfg.max_batch),
                batcher.slo_pressure(now, policy),
            );
        }
    };
    // one batcher round: respond to completions AND sheds (shed requests
    // must leave the system visibly, not vanish from the accounting)
    let round = |batcher: &mut ContinuousBatcher,
                 engine: &mut Engine<'_>,
                 policy: &mut dyn SpeculationPolicy,
                 now: f64|
     -> Result<bool> {
        for fin in batcher.step(engine, policy, now)? {
            if resp_tx.send(to_response(fin)).is_err() {
                return Ok(false);
            }
        }
        for shed in batcher.take_shed() {
            if resp_tx.send(shed_response(shed)).is_err() {
                return Ok(false);
            }
        }
        Ok(true)
    };
    let mut shutdown = false;
    'serve: while !shutdown {
        // drain arrivals that showed up during the last round
        loop {
            match req_rx.try_recv() {
                Ok(ServerMsg::Request(r)) => batcher.enqueue(BatchRequest {
                    id: r.id,
                    prompt: r.prompt,
                    sent_at: r.sent_at,
                    deadline: r.deadline,
                    route_hop: r.route_hop,
                    class: r.class,
                }),
                Ok(ServerMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        publish(&batcher, &*policy, epoch.elapsed().as_secs_f64());
        if !batcher.has_work() {
            if shutdown {
                break;
            }
            // idle: block for the next message
            match req_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ServerMsg::Request(r)) => batcher.enqueue(BatchRequest {
                    id: r.id,
                    prompt: r.prompt,
                    sent_at: r.sent_at,
                    deadline: r.deadline,
                    route_hop: r.route_hop,
                    class: r.class,
                }),
                Ok(ServerMsg::Shutdown) => break,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }
        let now = epoch.elapsed().as_secs_f64();
        if !round(&mut batcher, engine, policy, now)? {
            break 'serve;
        }
        publish(&batcher, &*policy, epoch.elapsed().as_secs_f64());
        // round boundary = safe point for flight-recorder dumps
        for p in cfg.telemetry.flight_poll() {
            log_info!("server: flight recorder dumped {}", p.display());
        }
    }
    // finish in-flight work after a shutdown request (the controller's
    // progress contract guarantees this drains: an idle worker either
    // admits or sheds, never defers forever)
    while batcher.has_work() {
        let now = epoch.elapsed().as_secs_f64();
        if !round(&mut batcher, engine, policy, now)? {
            break;
        }
    }
    let (deferrals, sheds) = batcher.admission_totals();
    Ok((batcher.timeline, deferrals, sheds))
}

/// Replay a trace against a server in real time (the client process).
///
/// Sleeps until each item's `send_at`, stamps it on the experiment clock,
/// and sends it.  Returns the number of requests sent.
pub fn run_client(trace: &Trace, requests: &Sender<ServerMsg>, epoch: Instant) -> Result<usize> {
    for item in &trace.items {
        let now = epoch.elapsed().as_secs_f64();
        if item.send_at > now {
            std::thread::sleep(Duration::from_secs_f64(item.send_at - now));
        }
        let req = ServerRequest {
            id: item.id,
            prompt: item.prompt.ids.clone(),
            sent_at: epoch.elapsed().as_secs_f64(),
            deadline: item.deadline,
            route_hop: 0.0,
            class: item.class,
        };
        requests
            .send(ServerMsg::Request(req))
            .map_err(|_| anyhow!("server hung up mid-trace"))?;
    }
    Ok(trace.items.len())
}

/// Everything one client/server experiment produces: per-request latency
/// records, the offline LUT the policy was seeded with (adaptive /
/// model-based), the server's per-round timeline, and — for online
/// policies — the fitted-model snapshot at shutdown.  Cluster runs
/// (`workers > 1`) leave `timeline`/`policy_snapshot` empty and report
/// per-shard breakdowns instead.
pub struct ExperimentOutcome {
    pub recorder: LatencyRecorder,
    pub lut: Option<Lut>,
    pub timeline: Vec<RoundEvent>,
    pub policy_snapshot: Option<Json>,
    /// per-shard breakdowns (empty on the single-worker paths)
    pub shards: Vec<ShardBreakdown>,
    /// KV block-pool accounting at shutdown (paged layout only; cluster
    /// runs merge the per-shard pools).  A clean run is leak-free:
    /// `free == capacity` — `rust/tests/kv_equivalence.rs` pins it.
    pub kv_blocks: Option<KvBlockStats>,
    /// prefix-sharing cache counters at shutdown (paged layout with the
    /// cache enabled only; cluster runs merge the per-shard caches)
    pub prefix: Option<PrefixStats>,
    /// admission defer events across all workers (0 under FIFO)
    pub deferrals: usize,
    /// requests shed by admission control across all workers; the shed
    /// requests themselves stay visible as records in `recorder`
    pub sheds: usize,
}

/// Run one full client/server experiment: spawn server, wait until ready,
/// replay the trace, collect all responses.  `cfg.workers > 1` selects
/// the threaded cluster path (stub backend, continuous mode).
pub fn run_experiment(
    backend: Backend,
    cfg: ServerConfig,
    policy: PolicySpec,
    lut: Option<Lut>,
    trace: &Trace,
) -> Result<ExperimentOutcome> {
    if cfg.workers > 1 {
        return match backend {
            Backend::Stub(spec) => {
                crate::cluster::server::run_cluster_experiment(spec, cfg, policy, lut, trace)
            }
            #[cfg(feature = "pjrt")]
            Backend::Artifacts(_) => bail!(
                "multi-worker serving is stub-only for now: PJRT handles are \
                 not Send, so each artifact shard needs its own runtime \
                 (run with the stub backend or workers = 1)"
            ),
        };
    }
    let epoch = Instant::now();
    // align the telemetry clock (and the flight recorder's) with the
    // experiment epoch so every track of the exported trace shares one
    // time origin — shard handles clone the same inner, so this rebases
    // all of them at once
    cfg.telemetry.rebase_to_now();
    let server = spawn_server(backend, cfg, policy, lut, epoch);
    let lut_used = server.wait_ready(Duration::from_secs(600))?;

    let n = trace.len();
    let tx = server.requests.clone();
    let trace_cloned = trace.clone();
    let client = std::thread::Builder::new()
        .name("specbatch-client".into())
        .spawn(move || run_client(&trace_cloned, &tx, epoch))
        .expect("spawning client thread");

    let mut recorder = LatencyRecorder::new();
    while recorder.len() < n {
        let resp = server
            .responses
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow!("timed out waiting for responses ({}/{n})", recorder.len()))?;
        recorder.push(RequestRecord {
            id: resp.id,
            sent_at: resp.sent_at,
            started_at: resp.started_at,
            finished_at: resp.finished_at,
            tokens: resp.tokens.len(),
            batch: resp.batch,
            spec_len: resp.spec_len,
            shard: 0,
            deadline: resp.deadline,
            deferred_rounds: resp.deferred_rounds,
            shed: resp.shed,
            first_token_at: resp.first_token_at,
        });
    }
    client
        .join()
        .map_err(|_| anyhow!("client thread panicked"))??;
    let report = server.shutdown()?;
    Ok(ExperimentOutcome {
        recorder,
        lut: lut_used,
        timeline: report.timeline,
        policy_snapshot: report.policy_snapshot,
        shards: Vec::new(),
        kv_blocks: report.kv_blocks,
        prefix: report.prefix,
        deferrals: report.deferrals,
        sheds: report.sheds,
    })
}
