//! Discrete-event simulation of the sharded cluster: N per-shard virtual
//! clocks over one shared arrival stream.
//!
//! Each shard mirrors [`crate::simulator::des::simulate_trace_continuous`]
//! exactly — round-boundary admission, immediate retirement, a per-round
//! policy query with the live batch, and the policy feedback edge driven
//! in virtual time — but owns its **own** clock, queue, acceptance RNG
//! stream, [`SpeculationPolicy`] instance and
//! [`AdmissionController`] instance.  The global event loop interleaves
//! two event kinds in time order:
//!
//! * **arrival** — the next trace item reaches the dispatcher; the
//!   [`Router`] sees every shard's current [`ShardLoad`] (live, queued,
//!   the policy's fitted marginal cost, and the shard's deadline
//!   pressure) and picks a shard, whose queue the item joins;
//! * **round** — the shard with the earliest next round boundary runs one
//!   decode round (planning admission over its due queue first).
//!
//! An arrival is routed before any round that starts at or after its send
//! time, so a routed request is admissible at the very boundary it
//! arrived at — the same semantics as the single-worker DES.  Rounds are
//! atomic: a round spanning the arrival's send time has already completed
//! (and retired its finished rows) when the router looks, so routing
//! observes each shard at its last completed round boundary.
//!
//! Admission mirrors the real batcher per shard: the controller orders
//! the due queue, deferred requests stay queued with their counters
//! bumped, and shed requests are recorded (`RequestRecord::shed`) at the
//! boundary that shed them.  [`simulate_trace_cluster`] keeps the
//! pre-admission FIFO behaviour bit for bit.

use std::collections::VecDeque;

use crate::admission::{
    apply_plan_to_queue, predicted_finish, predicted_token_time, AdmissionController,
    AdmissionView, Candidate, Fifo,
};
use crate::kvcache::prefix::PrefixStats;
use crate::metrics::{LatencyRecorder, RequestRecord, RoundEvent, SloSummary};
use crate::policy::{RoundFeedback, SpeculationPolicy};
use crate::simulator::des::{
    emit_phase_tiles, kv_blocks_of, round_phase_split, round_phase_split_ragged, sim_bucket_for,
    SimPrefix,
};
use crate::simulator::{reshape_cost, round_cost, round_cost_ragged, SimConfig};
use crate::telemetry::attrib::Waterfall;
use crate::telemetry::{PhaseKind, Telemetry};
use crate::traffic::{Trace, TraceItem};
use crate::util::prng::{DrawBuffer, Pcg64};

use super::{marginal_cost, Router, ShardLoad};

/// Outcome of one cluster simulation: the merged latency records (each
/// tagged with its serving shard) and the per-shard round timelines.
pub struct ClusterReport {
    pub recorder: LatencyRecorder,
    /// per-shard virtual-time round timelines, indexed by shard
    pub shard_rounds: Vec<Vec<RoundEvent>>,
    pub router: String,
    /// per-shard prefix-sharing counters merged into one line (`Some`
    /// when [`SimConfig::prefix_cache`] is on; each shard owns a private
    /// cache, exactly like the threaded cluster's workers)
    pub prefix: Option<PrefixStats>,
}

impl ClusterReport {
    /// Requests served per shard (padded to the shard count, so shards
    /// that served nothing still appear).
    pub fn shard_requests(&self) -> Vec<usize> {
        let mut counts = self.recorder.per_shard_counts();
        counts.resize(self.shard_rounds.len(), 0);
        counts
    }

    /// Per-shard SLO attainment accounting (padded to the shard count),
    /// via the same `LatencyRecorder::slo_attainment` the global numbers
    /// come from — so per-shard counters always sum to the global ones.
    pub fn shard_attainment(&self) -> Vec<SloSummary> {
        let n = self.shard_rounds.len().max(1);
        let mut per_shard: Vec<LatencyRecorder> =
            (0..n).map(|_| LatencyRecorder::new()).collect();
        for r in self.recorder.records() {
            per_shard[r.shard.min(n - 1)].push(*r);
        }
        per_shard.iter().map(|rec| rec.slo_attainment()).collect()
    }
}

struct SimRow {
    id: u64,
    sent_at: f64,
    admitted_at: f64,
    plen: usize,
    /// committed tokens (prefill counts as the first one)
    generated: usize,
    batch_at_admit: usize,
    spec_at_admit: usize,
    deadline: Option<f64>,
    deferred: usize,
    /// workload class tag (drives per-class acceptance + ragged `s`)
    class: u8,
    /// virtual time the row's first token committed (end of its
    /// admission prefill — "prefill commits the first token")
    first_token_at: Option<f64>,
    /// accruing latency decomposition (see the single-worker DES twin)
    wf: Waterfall,
}

/// A queued trace item plus its admission-control state.
struct Waiting {
    item: TraceItem,
    deferred: usize,
}

struct Shard {
    /// virtual clock: the shard's next round boundary
    t: f64,
    queue: VecDeque<Waiting>,
    live: Vec<SimRow>,
    rng: Pcg64,
    rounds: Vec<RoundEvent>,
    epoch: usize,
    /// padded bucket of the shard's active epoch (0 = idle); growth past
    /// it is an epoch reshape, charged per `SimConfig::kv_layout`
    bucket: usize,
    /// round-scratch mirror of the engine's arenas: the accepted-count
    /// buffer cycles through the policy feedback by mem::take
    accepted: Vec<u32>,
    /// bulk-filled acceptance draws; leftovers are consumed before the
    /// next fill, so the per-shard stream stays exactly sequential
    draws: DrawBuffer,
    /// ragged-round scratch: per-live-row classes and draft lengths,
    /// plus the feedback's per-row vectors (cycled by mem::take)
    live_classes: Vec<u8>,
    s_choice: Vec<usize>,
    fb_s_rows: Vec<u32>,
    fb_classes: Vec<u8>,
    /// policy drift flushes already reported to the flight recorder
    drift_seen: usize,
    /// the shard's private prefix-sharing mirror (`Some` when
    /// `SimConfig::prefix_cache` is on)
    prefix: Option<SimPrefix>,
    /// prompts admitted at the current boundary, pending post-prefill
    /// registration into the prefix mirror
    admitted_ids: Vec<Vec<i32>>,
}

impl Shard {
    /// Virtual time of the shard's next round boundary, `None` when idle
    /// with nothing queued.
    fn next_round_at(&self) -> Option<f64> {
        if !self.live.is_empty() {
            Some(self.t)
        } else {
            self.queue.front().map(|w| self.t.max(w.item.send_at))
        }
    }

    /// Deadline pressure for the router: resident requests whose SLO is
    /// already lost or predicted lost at this shard's load (the DES twin
    /// of `ContinuousBatcher::slo_pressure`).
    fn slo_pressure(&self, cfg: &SimConfig, policy: &dyn SpeculationPolicy) -> usize {
        let load = self.live.len() + self.queue.len();
        let t_tok = predicted_token_time(policy, load, cfg.max_batch);
        let late = |deadline: Option<f64>, tokens_left: usize| match deadline {
            None => false,
            Some(d) => match t_tok {
                None => d < self.t,
                Some(t) => self.t + tokens_left as f64 * t > d,
            },
        };
        self.live
            .iter()
            .filter(|r| late(r.deadline, cfg.max_new_tokens.saturating_sub(r.generated)))
            .count()
            + self
                .queue
                .iter()
                .filter(|w| late(w.item.deadline, cfg.max_new_tokens))
                .count()
    }
}

/// Simulate a trace through `policies.len()` worker shards routed by
/// `router`, FIFO admission on every shard (bit-for-bit the
/// pre-admission-subsystem behaviour).
pub fn simulate_trace_cluster(
    cfg: &SimConfig,
    policies: &mut [Box<dyn SpeculationPolicy>],
    router: &mut dyn Router,
    trace: &Trace,
) -> ClusterReport {
    let mut ctrls: Vec<Box<dyn AdmissionController>> = (0..policies.len())
        .map(|_| Box::new(Fifo) as Box<dyn AdmissionController>)
        .collect();
    simulate_trace_cluster_admission(cfg, policies, &mut ctrls, router, trace)
}

/// Simulate a trace through `policies.len()` worker shards routed by
/// `router`, with one [`AdmissionController`] per shard.  Each shard gets
/// its own acceptance RNG stream derived from `cfg.seed`, so runs are
/// deterministic and two routers (or controllers) compared on the same
/// trace differ only through placement/admission.
pub fn simulate_trace_cluster_admission(
    cfg: &SimConfig,
    policies: &mut [Box<dyn SpeculationPolicy>],
    ctrls: &mut [Box<dyn AdmissionController>],
    router: &mut dyn Router,
    trace: &Trace,
) -> ClusterReport {
    simulate_trace_cluster_admission_tel(
        cfg,
        policies,
        ctrls,
        router,
        trace,
        &Telemetry::disabled(),
    )
}

/// [`simulate_trace_cluster_admission`] with an event stream on `tel`:
/// routing decisions (tagged with the chosen shard, carrying the router's
/// per-shard load scores), plus each shard's round/phase/admission/finish
/// events through a [`Telemetry::for_shard`] handle — all stamped in
/// **virtual time** under the same schema the threaded cluster emits in
/// wall time.  Emission consumes no randomness: a disabled handle
/// reproduces the plain entry point bit for bit.
pub fn simulate_trace_cluster_admission_tel(
    cfg: &SimConfig,
    policies: &mut [Box<dyn SpeculationPolicy>],
    ctrls: &mut [Box<dyn AdmissionController>],
    router: &mut dyn Router,
    trace: &Trace,
    tel: &Telemetry,
) -> ClusterReport {
    let n_shards = policies.len();
    assert!(n_shards >= 1, "cluster needs at least one shard");
    assert_eq!(ctrls.len(), n_shards, "one admission controller per shard");
    let shard_tels: Vec<Telemetry> = (0..n_shards).map(|k| tel.for_shard(k)).collect();
    let mut shards: Vec<Shard> = (0..n_shards)
        .map(|k| Shard {
            t: 0.0,
            queue: VecDeque::new(),
            live: Vec::new(),
            rng: Pcg64::with_stream(cfg.seed, 0xC1A5_7E00 + k as u64),
            rounds: Vec::new(),
            epoch: 0,
            bucket: 0,
            accepted: Vec::new(),
            draws: DrawBuffer::new(),
            live_classes: Vec::new(),
            s_choice: Vec::new(),
            fb_s_rows: Vec::new(),
            fb_classes: Vec::new(),
            drift_seen: 0,
            prefix: if cfg.prefix_cache {
                Some(SimPrefix::new(cfg.kv_block.max(1)))
            } else {
                None
            },
            admitted_ids: Vec::new(),
        })
        .collect();
    let mut recorder = LatencyRecorder::new();
    let items = &trace.items;
    let mut next = 0usize;

    loop {
        // earliest round boundary over shards with work
        let mut round_at = f64::INFINITY;
        let mut round_shard = None;
        for (k, sh) in shards.iter().enumerate() {
            if let Some(at) = sh.next_round_at() {
                if at < round_at {
                    round_at = at;
                    round_shard = Some(k);
                }
            }
        }
        let arrival_at = items.get(next).map(|i| i.send_at).unwrap_or(f64::INFINITY);
        if round_shard.is_none() && next >= items.len() {
            break;
        }
        if arrival_at <= round_at {
            // dispatch: the router sees every shard's load as of its
            // last completed round boundary
            let loads: Vec<ShardLoad> = shards
                .iter()
                .enumerate()
                .map(|(k, sh)| ShardLoad {
                    shard: k,
                    live: sh.live.len(),
                    queued: sh.queue.len(),
                    marginal_cost: marginal_cost(
                        policies[k].as_ref(),
                        sh.live.len() + sh.queue.len(),
                        cfg.max_batch,
                    ),
                    slo_pressure: sh.slo_pressure(cfg, policies[k].as_ref()),
                })
                .collect();
            let k = router.route(&loads).min(n_shards - 1);
            if tel.active() {
                // score vector: each shard's backlog as the router saw it
                // (fitted marginal cost where the policy is warm, plain
                // live+queued rows otherwise)
                let scores: Vec<f64> = loads
                    .iter()
                    .map(|l| {
                        l.marginal_cost
                            .unwrap_or((l.live + l.queued) as f64)
                    })
                    .collect();
                tel.route(items[next].send_at, items[next].id, k, &scores);
            }
            shards[k].queue.push_back(Waiting {
                item: items[next].clone(),
                deferred: 0,
            });
            next += 1;
        } else {
            let k = round_shard.expect("a shard has work");
            step_shard(
                cfg,
                &mut shards[k],
                policies[k].as_mut(),
                ctrls[k].as_mut(),
                &mut recorder,
                k,
                &shard_tels[k],
            );
        }
    }

    // drain the per-shard caches (leak-audited) and roll their counters
    // up into one cluster-level line, like the threaded cluster does
    let prefix = shards
        .iter_mut()
        .filter_map(|sh| sh.prefix.take().map(SimPrefix::finish))
        .reduce(|a, b| a.merged(&b));
    ClusterReport {
        recorder,
        shard_rounds: shards.into_iter().map(|sh| sh.rounds).collect(),
        router: router.label(),
        prefix,
    }
}

/// One round boundary on one shard: plan admission over the due queue,
/// admit/shed accordingly, run one decode round in virtual time, feed the
/// policy back, retire finished rows.  Mirrors the single-worker
/// `simulate_trace_continuous` loop body.
fn step_shard(
    cfg: &SimConfig,
    sh: &mut Shard,
    policy: &mut dyn SpeculationPolicy,
    ctrl: &mut dyn AdmissionController,
    recorder: &mut LatencyRecorder,
    shard_idx: usize,
    tel: &Telemetry,
) {
    let may_speculate = policy.wants_speculation();
    if sh.live.is_empty() {
        // idle: jump to the head arrival, opening a new epoch
        if let Some(head) = sh.queue.front() {
            if head.item.send_at > sh.t {
                sh.t = head.item.send_at;
            }
        }
        sh.epoch += 1;
        sh.bucket = 0;
    }

    // --- plan admission over the due prefix of the queue ---
    let due = sh
        .queue
        .iter()
        .take_while(|w| w.item.send_at <= sh.t)
        .count();
    let admit_n = if due > 0 {
        let candidates: Vec<Candidate> = sh
            .queue
            .iter()
            .take(due)
            .map(|w| Candidate {
                id: w.item.id,
                sent_at: w.item.send_at,
                deadline: w.item.deadline,
                prompt_len: w.item.prompt.ids.len(),
                tokens_left: cfg.max_new_tokens,
                deferred: w.deferred,
            })
            .collect();
        let view = AdmissionView {
            now: sh.t,
            live: sh.live.len(),
            max_batch: cfg.max_batch,
            policy,
        };
        let rest = sh.queue.split_off(due);
        let due_items: Vec<Waiting> = sh.queue.drain(..).collect();
        let out = apply_plan_to_queue(
            ctrl.plan(&candidates, &view),
            due_items,
            sh.live.len(),
            |w| w.deferred += 1,
        );
        for w in &out.shed {
            recorder.push(RequestRecord {
                id: w.item.id,
                sent_at: w.item.send_at,
                started_at: sh.t,
                finished_at: sh.t,
                tokens: 0,
                batch: 0,
                spec_len: 0,
                shard: shard_idx,
                deadline: w.item.deadline,
                deferred_rounds: w.deferred,
                shed: true,
                first_token_at: None,
            });
        }
        if tel.active() {
            let fin = predicted_finish(
                policy,
                sh.t,
                cfg.max_new_tokens,
                sh.live.len() + out.queue.len(),
                cfg.max_batch,
            );
            let slack = |d: Option<f64>| match (d, fin) {
                (Some(d), Some(f)) => Some(d - f),
                _ => None,
            };
            for w in &out.shed {
                tel.admission(
                    sh.t,
                    w.item.id,
                    "shed",
                    w.item.deadline,
                    slack(w.item.deadline),
                    w.deferred,
                );
                // a shed request's whole lifetime was queue wait
                let mut wf = Waterfall::default();
                wf.queue = sh.t - w.item.send_at;
                wf.deferred_rounds = w.deferred;
                wf.seal(sh.t - w.item.send_at);
                tel.finish_attrib(
                    sh.t,
                    w.item.id,
                    0,
                    true,
                    w.item.deadline.map(|d| d - sh.t),
                    Some(wf),
                );
            }
            for (i, w) in out.queue.iter().enumerate() {
                let verdict = if i < out.admit_n { "admit" } else { "defer" };
                tel.admission(
                    sh.t,
                    w.item.id,
                    verdict,
                    w.item.deadline,
                    slack(w.item.deadline),
                    w.deferred,
                );
            }
        }
        sh.queue = out.queue.into();
        sh.queue.extend(rest);
        out.admit_n
    } else {
        0
    };

    // --- admit the planned prefix, up to the live-capacity cap ---
    let mut n_admit = 0usize;
    let mut plen_sum = 0usize;
    // prompt tokens the LLM actually prefills (prefix hits shrink a
    // row's span to its unmatched suffix; == plen_sum when off)
    let mut prefill_sum = 0usize;
    let n_before = sh.live.len();
    let admit_t = sh.t;
    while n_admit < admit_n {
        if sh.live.len() >= cfg.max_batch {
            break;
        }
        let mut w = sh.queue.pop_front().expect("planned admits are queued");
        let plen = w.item.prompt.ids.len();
        let saved = match sh.prefix.as_mut() {
            Some(p) => {
                let saved = p.lookup_saved(&w.item.prompt.ids);
                sh.admitted_ids.push(std::mem::take(&mut w.item.prompt.ids));
                saved
            }
            None => 0,
        };
        let mut wf = Waterfall::default();
        wf.queue = admit_t - w.item.send_at;
        wf.deferred_rounds = w.deferred;
        sh.live.push(SimRow {
            id: w.item.id,
            sent_at: w.item.send_at,
            admitted_at: admit_t,
            plen,
            generated: 1, // prefill commits the first token
            batch_at_admit: 0,
            spec_at_admit: 0,
            deadline: w.item.deadline,
            deferred: w.deferred,
            class: w.item.class,
            first_token_at: None,
            wf,
        });
        plen_sum += plen;
        prefill_sum += plen - saved;
        n_admit += 1;
    }
    if sh.live.is_empty() {
        // the whole due queue was shed and nothing was live: no round to
        // run at this boundary
        return;
    }
    if n_admit > 0 {
        let mean_plen = (plen_sum as f64 / n_admit as f64).ceil() as usize;
        let mean_prefill = (prefill_sum as f64 / n_admit as f64).ceil() as usize;
        let t_pre = sh.t;
        sh.t += cfg.llm.t_prefill(n_admit, mean_prefill);
        if may_speculate {
            // the SSM's dense cache is private: it ingests the full
            // prompts even when the LLM mapped shared blocks
            sh.t += cfg.ssm.t_prefill(n_admit, mean_plen);
        }
        if tel.enabled() {
            tel.phase(t_pre, sh.t - t_pre, PhaseKind::Prefill);
        }
        // the newcomers' prompts are prefilled now: register them for
        // later arrivals (map-at-admit / insert-after-prefill, the
        // engine's order — batchmates never hit each other)
        if let Some(p) = sh.prefix.as_mut() {
            for ids in sh.admitted_ids.drain(..) {
                p.register(&ids);
            }
        }
        // every live row — resident rows included — sits through the
        // prefill of the newcomers
        let dpre = sh.t - t_pre;
        for row in sh.live.iter_mut() {
            row.wf.prefill += dpre;
        }
        // the newcomers' first tokens committed with this prefill
        let t_first = sh.t;
        // epoch reshape at a bucket growth, mirroring the single-worker
        // DES: carried rows re-ingest under Dense, remap under Paged
        // (bucket is monotone within an epoch, like the real batcher's)
        let want = sim_bucket_for(sh.live.len());
        if sh.bucket != 0 && want > sh.bucket && n_before > 0 {
            let carried: Vec<usize> = sh.live[..n_before]
                .iter()
                .map(|r| r.plen + r.generated)
                .collect();
            let rcst = reshape_cost(cfg, &carried, sh.live.len());
            if tel.enabled() {
                tel.phase(sh.t, rcst, PhaseKind::Reshape);
            }
            // the whole (grown) batch stalls through the re-ingest
            for row in sh.live.iter_mut() {
                row.wf.reshape += rcst;
            }
            sh.t += rcst;
        }
        sh.bucket = sh.bucket.max(want);
        let b = sh.live.len();
        let s_now = if may_speculate { policy.choose(b, 8) } else { 0 };
        for row in sh.live.iter_mut().rev().take(n_admit) {
            row.batch_at_admit = b;
            row.spec_at_admit = s_now;
            row.first_token_at = Some(t_first);
        }
    }

    // --- one decode round over the live rows ---
    let b = sh.live.len();
    debug_assert!(b >= 1, "step_shard called on an idle shard");
    let ctx = sh.live.iter().map(|r| r.plen + r.generated).sum::<usize>() / b;
    sh.live_classes.clear();
    for r in sh.live.iter() {
        sh.live_classes.push(r.class);
    }
    let classed = sh.live_classes.iter().any(|&c| c != 0);
    if may_speculate {
        policy.choose_ragged_into(&sh.live_classes, 8, &mut sh.s_choice);
    } else {
        sh.s_choice.clear();
        sh.s_choice.resize(b, 0);
    }
    let s = sh.s_choice.iter().copied().max().unwrap_or(0);
    let ragged = sh.s_choice.iter().any(|&si| si != s);
    let rc = if ragged {
        round_cost_ragged(cfg, b, &sh.s_choice, ctx)
    } else {
        round_cost(cfg, b, s, ctx)
    };
    sh.accepted.clear();
    let mut committed = 0usize;
    if s == 0 {
        for row in sh.live.iter_mut() {
            row.generated += 1;
            committed += 1;
        }
    } else {
        let need: usize = sh.s_choice.iter().sum();
        sh.draws.ensure(&mut sh.rng, need);
        let t_now = sh.t;
        for (row, &si) in sh.live.iter_mut().zip(sh.s_choice.iter()) {
            let a = cfg.class_acceptance_at(row.class, t_now).sample(si, &mut sh.draws);
            sh.accepted.push(a as u32);
            row.generated += a + 1;
            committed += a + 1;
        }
    }
    let t_round = sh.t;
    sh.t += rc;
    let accepted_total: usize = sh.accepted.iter().map(|&a| a as usize).sum();
    let drafted: usize = if s == 0 { 0 } else { sh.s_choice.iter().sum() };
    // every live row sits through this round: accrue its phase split
    let (draft, verify, accept) = if ragged {
        round_phase_split_ragged(cfg, rc, b, &sh.s_choice, ctx)
    } else {
        round_phase_split(cfg, rc, b, s, ctx)
    };
    for row in sh.live.iter_mut() {
        row.wf.add_round_split(0.0, draft, verify, accept);
    }
    sh.fb_s_rows.clear();
    if ragged {
        sh.fb_s_rows.extend(sh.s_choice.iter().map(|&si| si as u32));
    }
    sh.fb_classes.clear();
    if classed {
        sh.fb_classes.extend_from_slice(&sh.live_classes);
    }
    let fb = RoundFeedback {
        live: b,
        width: b, // continuous rounds execute at exactly the live width
        s,
        accepted: std::mem::take(&mut sh.accepted),
        committed,
        round_time: rc,
        s_rows: std::mem::take(&mut sh.fb_s_rows),
        classes: std::mem::take(&mut sh.fb_classes),
    };
    policy.observe(&fb);
    let flushes = policy.drift_flushes();
    if flushes > sh.drift_seen {
        sh.drift_seen = flushes;
        tel.drift_flush(t_round);
    }
    let kvb = kv_blocks_of(cfg, sh.live.iter().map(|r| r.plen + r.generated));
    // the shard epoch's padded bucket is the executing width
    let width = sh.bucket.max(sim_bucket_for(b));
    sh.rounds.push(RoundEvent {
        t: sh.t,
        epoch: sh.epoch,
        live: b,
        width,
        queued: sh.queue.len(),
        s,
        drafted,
        accepted: accepted_total,
        round_cost: rc,
        kv_blocks: kvb,
    });
    if tel.active() {
        tel.round(
            t_round,
            rc,
            sh.epoch,
            b,
            width,
            sh.queue.len(),
            s,
            committed,
            &fb.accepted,
            &fb.s_rows,
            kvb,
        );
        emit_phase_tiles(tel, t_round, draft, verify, accept);
        if tel.tracing() {
            tel.policy_fit(sh.t, policy.snapshot());
        }
    }
    // reclaim the feedback's per-row buffers for the shard's next round
    sh.accepted = fb.accepted;
    sh.fb_s_rows = fb.s_rows;
    sh.fb_classes = fb.classes;

    // --- retire finished rows immediately, freeing capacity ---
    let mut i = 0;
    while i < sh.live.len() {
        if sh.live[i].generated >= cfg.max_new_tokens {
            let row = sh.live.swap_remove(i);
            if tel.active() {
                let mut wf = row.wf;
                wf.seal(sh.t - row.sent_at);
                tel.finish_attrib(
                    sh.t,
                    row.id,
                    cfg.max_new_tokens,
                    false,
                    row.deadline.map(|d| d - sh.t),
                    Some(wf),
                );
            }
            recorder.push(RequestRecord {
                id: row.id,
                sent_at: row.sent_at,
                started_at: row.admitted_at,
                finished_at: sh.t,
                tokens: cfg.max_new_tokens,
                batch: row.batch_at_admit,
                spec_len: row.spec_at_admit,
                shard: shard_idx,
                deadline: row.deadline,
                deferred_rounds: row.deferred,
                shed: false,
                first_token_at: row.first_token_at,
            });
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{build_router, replicate_policies};
    use crate::config::{PolicySpec, RouterSpec};
    use crate::dataset::Prompt;
    use crate::kvcache::KvLayout;
    use crate::policy::Fixed;
    use crate::simulator::{
        simulate_trace_continuous, simulated_lut, CostModel, GpuProfile, ModelProfile,
    };
    use crate::traffic::TrafficPattern;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::paper_default(
            CostModel::new(ModelProfile::OPT_6_7B, GpuProfile::RTX3090),
            CostModel::new(ModelProfile::OPT_125M, GpuProfile::RTX3090),
        );
        c.max_new_tokens = 32; // keep tests quick
        c
    }

    fn pool() -> Vec<Prompt> {
        vec![Prompt {
            ids: vec![1; 12],
            text: String::new(),
        }]
    }

    fn fixed_policies(n: usize, s: usize) -> Vec<Box<dyn SpeculationPolicy>> {
        (0..n)
            .map(|_| Box::new(Fixed(s)) as Box<dyn SpeculationPolicy>)
            .collect()
    }

    #[test]
    fn cluster_conserves_requests_and_causality() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.1,
                cv: 1.0,
            },
            &pool(),
            200,
            13,
        );
        for spec in RouterSpec::all() {
            let mut policies = fixed_policies(4, 2);
            let mut router = build_router(spec, 5);
            let report =
                simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
            assert_eq!(report.recorder.len(), 200, "router {}", report.router);
            let mut ids: Vec<u64> =
                report.recorder.records().iter().map(|r| r.id).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..200).collect::<Vec<u64>>());
            for r in report.recorder.records() {
                assert!(r.started_at >= r.sent_at - 1e-12);
                assert!(r.finished_at > r.started_at);
                assert!(r.shard < 4);
                assert!(r.batch >= 1 && r.batch <= cfg.max_batch);
                assert!(!r.shed, "FIFO admission never sheds");
            }
            assert_eq!(report.shard_rounds.len(), 4);
            for rounds in &report.shard_rounds {
                for w in rounds.windows(2) {
                    assert!(w[1].t >= w[0].t, "shard clock went backwards");
                }
                assert!(rounds.iter().all(|e| e.live >= 1 && e.live <= cfg.max_batch));
                assert!(rounds.iter().all(|e| e.round_cost > 0.0));
            }
        }
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.2,
                cv: 1.0,
            },
            &pool(),
            120,
            3,
        );
        let mut policies = fixed_policies(3, 2);
        let mut router = build_router(RouterSpec::RoundRobin, 0);
        let report = simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
        assert_eq!(report.shard_requests(), vec![40, 40, 40]);
    }

    #[test]
    fn one_shard_cluster_matches_the_single_worker_des() {
        // with N=1 every router degenerates to the single-worker
        // continuous DES: same acceptance stream semantics, so the same
        // latency distribution shape (clocks advance identically except
        // for the RNG stream constant, so compare conservation + summary
        // against a direct run on a no-randomness policy)
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.3,
                cv: 1.0,
            },
            &pool(),
            100,
            9,
        );
        let mut single = Fixed(0);
        let (rec_single, _) = simulate_trace_continuous(&cfg, &mut single, &trace);
        let mut policies = fixed_policies(1, 0);
        let mut router = build_router(RouterSpec::JoinShortestQueue, 0);
        let report = simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
        // s = 0 rounds draw no acceptance randomness, so the two paths
        // are bit-identical
        assert_eq!(report.recorder.len(), rec_single.len());
        let mean_c = report.recorder.summary().mean;
        let mean_s = rec_single.summary().mean;
        assert!(
            (mean_c - mean_s).abs() < 1e-9,
            "1-shard cluster {mean_c} != single-worker {mean_s}"
        );
    }

    #[test]
    fn more_workers_cut_latency_under_load() {
        let cfg = cfg();
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.03,
                cv: 1.0,
            },
            &pool(),
            300,
            17,
        );
        let run = |n: usize| {
            let mut policies = fixed_policies(n, 2);
            let mut router = build_router(RouterSpec::JoinShortestQueue, 0);
            simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace)
                .recorder
                .summary()
                .mean
        };
        let one = run(1);
        let four = run(4);
        assert!(
            four < 0.7 * one,
            "4 workers ({four:.3}s) should clearly beat 1 ({one:.3}s) under load"
        );
    }

    #[test]
    fn cluster_shards_charge_dense_reshapes_but_not_paged_ones() {
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.04,
                cv: 1.0,
            },
            &pool(),
            200,
            11,
        );
        let run = |layout: KvLayout| {
            let cfg = SimConfig {
                kv_layout: layout,
                ..cfg()
            };
            let mut policies = fixed_policies(2, 2);
            let mut router = build_router(RouterSpec::JoinShortestQueue, 0);
            simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace)
        };
        let paged = run(KvLayout::Paged);
        let dense = run(KvLayout::Dense);
        assert_eq!(paged.recorder.len(), 200);
        assert_eq!(dense.recorder.len(), 200);
        let (mp, md) = (
            paged.recorder.summary().mean,
            dense.recorder.summary().mean,
        );
        assert!(
            md > mp * 1.01,
            "per-shard dense reshapes should cost latency: dense {md:.3}s vs \
             paged {mp:.3}s"
        );
        // paged timelines record per-shard block utilization
        assert!(paged
            .shard_rounds
            .iter()
            .any(|rounds| rounds.iter().any(|e| e.kv_blocks > 0)));
    }

    #[test]
    fn model_based_cluster_warms_up_and_uses_cost_aware_routing() {
        let cfg = cfg();
        let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.05,
                cv: 1.0,
            },
            &pool(),
            400,
            23,
        );
        let mut policies =
            replicate_policies(&PolicySpec::ModelBased, Some(&lut), 4).unwrap();
        let mut router = build_router(RouterSpec::CostAware, 1);
        let report = simulate_trace_cluster(&cfg, &mut policies, router.as_mut(), &trace);
        assert_eq!(report.recorder.len(), 400);
        // every shard saw traffic and its policy's fits warmed up
        for (k, p) in policies.iter().enumerate() {
            assert!(
                p.predict_token_time(2).is_some(),
                "shard {k} policy never warmed up"
            );
        }
        assert!(report.shard_requests().iter().all(|&n| n > 0));
    }

    /// Deadline-aware routing on a deadlined overload trace: requests are
    /// conserved (sheds included) and per-shard attainment sums to the
    /// global accounting.
    #[test]
    fn deadline_router_with_slo_admission_conserves_and_attains() {
        use crate::admission::replicate_controllers;
        use crate::config::AdmissionSpec;

        let cfg = cfg();
        let lut = simulated_lut(&cfg, &[1, 2, 4, 8, 16], 8, 80);
        let base = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.02,
                cv: 1.5,
            },
            &pool(),
            300,
            7,
        );
        let trace = base.with_deadlines(&crate::traffic::SloSpec::new(1.2, 2.0), 7);
        let run = |spec: RouterSpec| {
            let mut policies =
                replicate_policies(&PolicySpec::ModelBased, Some(&lut), 3).unwrap();
            let mut ctrls = replicate_controllers(AdmissionSpec::SloAware, 3);
            let mut router = build_router(spec, 5);
            simulate_trace_cluster_admission(
                &cfg,
                &mut policies,
                &mut ctrls,
                router.as_mut(),
                &trace,
            )
        };
        let report = run(RouterSpec::Deadline);
        assert_eq!(report.router, "deadline");
        assert_eq!(report.recorder.len(), 300, "every request leaves a record");
        let global = report.recorder.slo_attainment();
        assert_eq!(global.deadlined, 300);
        assert_eq!(
            global.met + global.missed + global.shed,
            300,
            "attainment counters must conserve"
        );
        let per_shard = report.shard_attainment();
        assert_eq!(per_shard.len(), 3);
        assert_eq!(per_shard.iter().map(|s| s.met).sum::<usize>(), global.met);
        assert_eq!(per_shard.iter().map(|s| s.shed).sum::<usize>(), global.shed);
        assert_eq!(
            per_shard.iter().map(|s| s.completed).sum::<usize>(),
            global.completed
        );
        // determinism: the same run replays bit-identically
        let again = run(RouterSpec::Deadline);
        let lat = |r: &ClusterReport| {
            let mut v: Vec<(u64, bool, f64)> = r
                .recorder
                .records()
                .iter()
                .map(|x| (x.id, x.shed, x.latency()))
                .collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        };
        assert_eq!(lat(&report), lat(&again));
    }
}
