//! Sharded multi-worker cluster with speculation-aware routing.
//!
//! The paper's headline — the optimal speculation length `s_opt` shrinks
//! as the batch grows — becomes a **placement** problem the moment more
//! than one worker serves traffic: how requests are routed across shards
//! determines each shard's live batch, which determines each shard's
//! `s_opt` and per-round cost (Eq. 7).  This module runs N independent
//! worker shards — each owning its own continuous batcher
//! ([`crate::batcher`]) and [`SpeculationPolicy`] instance — behind a
//! [`Router`]:
//!
//! * [`RoundRobin`] — cycle through shards in arrival order (load- and
//!   model-oblivious, the baseline);
//! * [`JoinShortestQueue`] — always pick the shard with the fewest
//!   live + queued requests;
//! * [`PowerOfTwo`] — probe two random shards, pick the lighter (the
//!   classic two-choices load balancer: most of JSQ's benefit at O(1)
//!   probe cost);
//! * [`CostAware`] — greedily pick the shard whose **fitted round-cost
//!   model** ([`ModelBased`](crate::policy::ModelBased)'s online Eq. 7
//!   fits, surfaced through
//!   [`SpeculationPolicy::predict_token_time`]) predicts the smallest
//!   marginal per-token latency increase, falling back to JSQ while any
//!   shard's fits are cold.  This is where routing and speculation
//!   synergize: a shard sitting just below a batch-bucket edge is cheap
//!   to top up, one just past it has already paid the larger `α'_b` and
//!   re-solved a smaller `s` — the router reads both off the same fits
//!   the per-shard policies learn from round feedback.
//!
//! Two drivers share the routing layer:
//!
//! * [`sim::simulate_trace_cluster`] — the DES mirror: per-shard virtual
//!   clocks over a shared arrival stream, so routing × speculation
//!   experiments are deterministic and run at paper scale in
//!   milliseconds;
//! * [`server::run_cluster_experiment`] — the real threaded path on the
//!   stub backend: one engine + batcher + policy per worker thread, a
//!   dispatcher thread owning the router, and per-shard response
//!   collectors (`ServerConfig { workers, router, .. }` selects it).

pub mod server;
pub mod sim;

use crate::config::RouterSpec;
use crate::metrics::RoundEvent;
use crate::policy::{Fixed, LutAdaptive, ModelBased, NoSpec, SpeculationPolicy};
use crate::scheduler::Lut;
use crate::util::json::Json;
use crate::util::prng::Pcg64;

use anyhow::{bail, Result};

/// What the router sees of one shard at a routing decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardLoad {
    pub shard: usize,
    /// requests live in the shard's active epoch
    pub live: usize,
    /// requests routed to the shard but not yet admitted
    pub queued: usize,
    /// predicted marginal per-token latency increase of placing one more
    /// request here (from the shard policy's fitted round-cost model;
    /// `None` while the fits are cold)
    pub marginal_cost: Option<f64>,
    /// deadline pressure: resident (live + queued) requests whose SLO is
    /// already lost or predicted lost at the shard's current load (0 when
    /// nothing carries a deadline) — the [`DeadlineAware`] router's
    /// miss-penalty signal
    pub slo_pressure: usize,
}

impl ShardLoad {
    /// Total requests the shard is responsible for.
    pub fn total(&self) -> usize {
        self.live + self.queued
    }
}

/// A request-routing strategy over shard load snapshots.
///
/// `route` is called once per arriving request with one [`ShardLoad`] per
/// shard (index `i` describes shard `i`) and returns the chosen shard
/// index.  Routers may keep state (round-robin cursor, probe RNG) but
/// must be deterministic given their construction seed.  `Send` because
/// the threaded cluster path moves the router into its dispatcher thread.
pub trait Router: Send {
    fn route(&mut self, loads: &[ShardLoad]) -> usize;
    fn label(&self) -> String;
}

/// Cycle through the shards in arrival order.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn route(&mut self, loads: &[ShardLoad]) -> usize {
        let k = self.next % loads.len();
        self.next = self.next.wrapping_add(1);
        k
    }

    fn label(&self) -> String {
        "round-robin".into()
    }
}

/// Always pick the shard with the fewest live + queued requests (ties go
/// to the lowest shard index).
#[derive(Debug, Clone, Copy, Default)]
pub struct JoinShortestQueue;

impl Router for JoinShortestQueue {
    fn route(&mut self, loads: &[ShardLoad]) -> usize {
        loads
            .iter()
            .min_by_key(|l| (l.total(), l.shard))
            .expect("route called with at least one shard")
            .shard
    }

    fn label(&self) -> String {
        "jsq".into()
    }
}

/// Probe two distinct random shards, pick the lighter (first probe wins
/// ties).  Deterministic given the construction seed.
#[derive(Debug, Clone)]
pub struct PowerOfTwo {
    rng: Pcg64,
}

impl PowerOfTwo {
    pub fn new(seed: u64) -> PowerOfTwo {
        PowerOfTwo {
            rng: Pcg64::with_stream(seed, 0x9072),
        }
    }
}

impl Router for PowerOfTwo {
    fn route(&mut self, loads: &[ShardLoad]) -> usize {
        let n = loads.len();
        if n == 1 {
            return 0;
        }
        let a = self.rng.next_below(n);
        let b = {
            let mut b = self.rng.next_below(n - 1);
            if b >= a {
                b += 1;
            }
            b
        };
        if loads[b].total() < loads[a].total() {
            b
        } else {
            a
        }
    }

    fn label(&self) -> String {
        "power-of-two".into()
    }
}

/// Greedy model-based placement: route to the shard whose fitted
/// round-cost model predicts the smallest marginal per-token latency
/// increase ([`ShardLoad::marginal_cost`]), breaking ties by load then
/// index.  While **any** shard's fits are cold the router falls back to
/// [`JoinShortestQueue`] — comparing a warm prediction against a missing
/// one would systematically dogpile whichever side is favoured.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostAware {
    jsq: JoinShortestQueue,
}

impl Router for CostAware {
    fn route(&mut self, loads: &[ShardLoad]) -> usize {
        if loads.iter().any(|l| l.marginal_cost.is_none()) {
            return self.jsq.route(loads);
        }
        loads
            .iter()
            .min_by(|x, y| {
                let kx = (x.marginal_cost.unwrap(), x.total(), x.shard);
                let ky = (y.marginal_cost.unwrap(), y.total(), y.shard);
                kx.partial_cmp(&ky).expect("marginal costs are finite")
            })
            .expect("route called with at least one shard")
            .shard
    }

    fn label(&self) -> String {
        "cost-aware".into()
    }
}

/// Deadline-aware cost routing: the [`CostAware`] marginal-latency argmin
/// with each shard's marginal penalized by its [`ShardLoad::slo_pressure`]
/// — a shard already predicted to miss deadlines is an expensive place to
/// add work even when its raw marginal looks cheap (the new request would
/// queue behind requests the shard must rush, and push them further
/// past their deadlines).  While any shard's fits are cold the fallback
/// is JSQ biased by pressure, so deadline load still spreads.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeadlineAware;

impl Router for DeadlineAware {
    fn route(&mut self, loads: &[ShardLoad]) -> usize {
        if loads.iter().any(|l| l.marginal_cost.is_none()) {
            return loads
                .iter()
                .min_by_key(|l| (l.slo_pressure, l.total(), l.shard))
                .expect("route called with at least one shard")
                .shard;
        }
        loads
            .iter()
            .min_by(|x, y| {
                let score = |l: &ShardLoad| {
                    l.marginal_cost.unwrap() * (1.0 + l.slo_pressure as f64)
                };
                (score(x), x.total(), x.shard)
                    .partial_cmp(&(score(y), y.total(), y.shard))
                    .expect("marginal costs are finite")
            })
            .expect("route called with at least one shard")
            .shard
    }

    fn label(&self) -> String {
        "deadline".into()
    }
}

/// Resolve a parsed [`RouterSpec`] into a live router.  `seed` feeds the
/// probe RNG of [`PowerOfTwo`] (the other strategies are seedless).
pub fn build_router(spec: RouterSpec, seed: u64) -> Box<dyn Router> {
    match spec {
        RouterSpec::RoundRobin => Box::new(RoundRobin::default()),
        RouterSpec::JoinShortestQueue => Box::new(JoinShortestQueue),
        RouterSpec::PowerOfTwo => Box::new(PowerOfTwo::new(seed)),
        RouterSpec::CostAware => Box::new(CostAware::default()),
        RouterSpec::Deadline => Box::new(DeadlineAware),
    }
}

/// Per-token prediction at `live`, linearly interpolated between the two
/// nearest power-of-two bucket predictions.  The policy's fits are
/// bucket-granular, but a greedy router comparing *marginal* costs needs
/// a smooth curve: on the raw stair-step, crossing a bucket edge looks
/// hugely expensive and staying inside a bucket looks free, so a burst
/// of arrivals piles onto whichever shard crossed first.
fn predict_interp(policy: &dyn SpeculationPolicy, live: usize) -> Option<f64> {
    if live <= 1 {
        return policy.predict_token_time(1);
    }
    // largest power of two <= live
    let lo = (live + 1).next_power_of_two() >> 1;
    if lo == live {
        return policy.predict_token_time(live);
    }
    let hi = lo << 1;
    let tlo = policy.predict_token_time(lo)?;
    let thi = policy.predict_token_time(hi)?;
    let w = (live - lo) as f64 / (hi - lo) as f64;
    Some(tlo + w * (thi - tlo))
}

/// Marginal per-token latency increase of adding one request to a shard
/// already carrying `load` requests, under its policy's fitted model:
/// `(load+1)·t(load+1) − load·t(load)` — adding a request slows every
/// resident down, so the marginal cost weights the per-token time shift
/// by the population bearing it.  Beyond `max_batch` the shard
/// time-shares its token throughput, so the effective per-token time
/// scales by `load / max_batch` (otherwise queue depth would stop
/// costing anything once the largest fitted bucket is full, and the
/// router would bury one shard).  `None` while the policy predicts
/// nothing (static policies, cold fits).
pub fn marginal_cost(
    policy: &dyn SpeculationPolicy,
    load: usize,
    max_batch: usize,
) -> Option<f64> {
    let max_batch = max_batch.max(1);
    let t_eff = |n: usize| -> Option<f64> {
        let t = predict_interp(policy, n.min(max_batch))?;
        Some(t * (n as f64 / max_batch as f64).max(1.0))
    };
    let after = t_eff(load + 1)?;
    if load == 0 {
        return Some(after);
    }
    let now = t_eff(load)?;
    Some(((load + 1) as f64 * after - load as f64 * now).max(0.0))
}

/// One policy instance per shard (each shard learns its own fits), all
/// resolved from the same spec.  `lut` seeds the LUT-backed policies and
/// is required for `Adaptive` / `ModelBased`.
pub fn replicate_policies(
    spec: &crate::config::PolicySpec,
    lut: Option<&Lut>,
    workers: usize,
) -> Result<Vec<Box<dyn SpeculationPolicy>>> {
    use crate::config::PolicySpec;
    (0..workers)
        .map(|_| -> Result<Box<dyn SpeculationPolicy>> {
            Ok(match spec {
                PolicySpec::None => Box::new(NoSpec),
                PolicySpec::Fixed(s) => Box::new(Fixed(*s)),
                PolicySpec::Adaptive => match lut {
                    Some(l) => Box::new(LutAdaptive(l.clone())),
                    None => bail!("adaptive policy needs an offline LUT"),
                },
                PolicySpec::ModelBased => match lut {
                    Some(l) => Box::new(ModelBased::new(l.clone())),
                    None => bail!("model-based policy needs a fallback LUT"),
                },
            })
        })
        .collect()
}

/// Per-shard slice of a cluster experiment's outcome (the breakdown
/// attached to `server::ExperimentOutcome` and printed by the CLI).
#[derive(Debug, Clone)]
pub struct ShardBreakdown {
    pub shard: usize,
    /// requests this shard served to completion
    pub requests: usize,
    /// mean end-to-end latency of those requests, seconds
    pub mean_latency: f64,
    /// the shard's own per-round (live, s) timeline
    pub rounds: Vec<RoundEvent>,
    /// fitted-model snapshot at shutdown (online policies only)
    pub policy_snapshot: Option<Json>,
    /// the shard engine's KV block accounting (paged layout only)
    pub kv_blocks: Option<crate::kvcache::KvBlockStats>,
    /// the shard engine's prefix-cache counters (paged layout with the
    /// prefix cache enabled only) — each shard keys its own trie, so
    /// cross-shard routing dilutes hit rates unless arrivals are sticky
    pub prefix: Option<crate::kvcache::prefix::PrefixStats>,
    /// this shard's SLO attainment accounting (zeroed when nothing
    /// carried a deadline)
    pub slo: crate::metrics::SloSummary,
}

impl ShardBreakdown {
    /// Mean live batch over the shard's recorded rounds.
    pub fn mean_live(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|e| e.live as f64).sum::<f64>() / self.rounds.len() as f64
    }

    /// Mean chosen speculation length over the shard's recorded rounds.
    pub fn mean_s(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|e| e.s as f64).sum::<f64>() / self.rounds.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::{AcceptanceModel, StepCostModel};
    use crate::config::PolicySpec;

    fn loads(totals: &[usize]) -> Vec<ShardLoad> {
        totals
            .iter()
            .enumerate()
            .map(|(i, &t)| ShardLoad {
                shard: i,
                live: t,
                queued: 0,
                marginal_cost: None,
                slo_pressure: 0,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::default();
        let l = loads(&[9, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&l)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_the_lightest_with_index_ties() {
        let mut r = JoinShortestQueue;
        assert_eq!(r.route(&loads(&[3, 1, 2])), 1);
        assert_eq!(r.route(&loads(&[2, 2, 2])), 0);
        let mut with_queue = loads(&[1, 1]);
        with_queue[0].queued = 5;
        assert_eq!(r.route(&with_queue), 1, "queued requests count as load");
    }

    #[test]
    fn power_of_two_is_deterministic_and_prefers_lighter_probes() {
        let l = loads(&[10, 0, 10, 10]);
        let mut a = PowerOfTwo::new(7);
        let mut b = PowerOfTwo::new(7);
        let pa: Vec<usize> = (0..64).map(|_| a.route(&l)).collect();
        let pb: Vec<usize> = (0..64).map(|_| b.route(&l)).collect();
        assert_eq!(pa, pb, "same seed, same probe sequence");
        // whenever shard 1 is probed it wins; over 64 routes with 4
        // shards that is overwhelmingly likely to have happened
        assert!(pa.contains(&1));
        // shard 1 wins far more than its uniform 1/4 share
        let hits = pa.iter().filter(|&&k| k == 1).count();
        assert!(hits * 2 > pa.len() / 2, "two-choices should favour the idle shard");
        // single shard short-circuits
        assert_eq!(PowerOfTwo::new(1).route(&loads(&[4])), 0);
    }

    #[test]
    fn cost_aware_uses_marginals_when_warm_and_jsq_when_cold() {
        let mut r = CostAware::default();
        // cold anywhere -> JSQ on totals
        let mut l = loads(&[4, 2, 3]);
        l[0].marginal_cost = Some(0.001);
        assert_eq!(r.route(&l), 1, "one cold shard forces the JSQ fallback");
        // all warm -> smallest marginal wins even against a lighter shard
        let mut warm = loads(&[6, 1, 3]);
        warm[0].marginal_cost = Some(0.0004);
        warm[1].marginal_cost = Some(0.0030);
        warm[2].marginal_cost = Some(0.0010);
        assert_eq!(r.route(&warm), 0);
        // marginal ties break by load, then index
        let mut tied = loads(&[5, 2, 2]);
        for s in tied.iter_mut() {
            s.marginal_cost = Some(0.002);
        }
        assert_eq!(r.route(&tied), 1);
    }

    #[test]
    fn deadline_aware_penalizes_pressured_shards() {
        let mut r = DeadlineAware;
        // cold anywhere -> pressure-biased JSQ: the pressured shard loses
        // even when lighter
        let mut l = loads(&[4, 2, 3]);
        l[1].slo_pressure = 3;
        assert_eq!(r.route(&l), 2, "pressure outranks raw load while cold");
        // all warm: a cheap marginal loses once pressure scales it past a
        // pricier but clean shard
        let mut warm = loads(&[6, 1, 3]);
        warm[0].marginal_cost = Some(0.0004);
        warm[1].marginal_cost = Some(0.0010);
        warm[2].marginal_cost = Some(0.0030);
        assert_eq!(r.route(&warm), 0, "no pressure: cheapest marginal wins");
        warm[0].slo_pressure = 4; // 0.0004 * 5 = 0.002 > 0.001
        assert_eq!(r.route(&warm), 1, "pressure re-prices the cheap shard");
        // equal scores tie-break by load then index
        let mut tied = loads(&[5, 2, 2]);
        for s in tied.iter_mut() {
            s.marginal_cost = Some(0.002);
        }
        assert_eq!(r.route(&tied), 1);
    }

    #[test]
    fn build_router_matches_spec_labels() {
        for spec in RouterSpec::all() {
            let r = build_router(spec, 11);
            assert_eq!(r.label(), spec.label());
        }
    }

    #[test]
    fn marginal_cost_weights_the_resident_population() {
        let acceptance = AcceptanceModel {
            c: 0.9,
            gamma: 0.548,
            r2: 1.0,
        };
        let costs = [
            StepCostModel {
                batch: 1,
                alpha: 0.0004,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
            StepCostModel {
                batch: 4,
                alpha: 0.004,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
            StepCostModel {
                batch: 16,
                alpha: 0.02,
                beta: 0.03,
                t_ssm: 0.0,
                r2: 1.0,
            },
        ];
        let lut = Lut::new([(1usize, 3usize)].into_iter().collect()).unwrap();
        let p = ModelBased::with_models(lut.clone(), acceptance, &costs);
        // an empty shard charges exactly the first request's own time
        let m0 = marginal_cost(&p, 0, 16).unwrap();
        assert!((m0 - p.predict_token_time(1).unwrap()).abs() < 1e-12);
        // moving toward the compute-bound bucket is the expensive move
        let m_light = marginal_cost(&p, 2, 16).unwrap();
        let m_heavy = marginal_cost(&p, 8, 16).unwrap();
        assert!(
            m_heavy > m_light,
            "pushing a loaded shard toward the big bucket must cost more: \
             {m_light} vs {m_heavy}"
        );
        // beyond capacity the queue keeps charging: marginals keep
        // growing instead of saturating at the largest fitted bucket
        let m_over = marginal_cost(&p, 24, 16).unwrap();
        let m_deep = marginal_cost(&p, 48, 16).unwrap();
        assert!(
            m_deep > m_over && m_over > m_heavy,
            "queue depth must keep costing: {m_heavy} -> {m_over} -> {m_deep}"
        );
        // static policies predict nothing
        assert!(marginal_cost(&NoSpec, 3, 16).is_none());
        assert!(marginal_cost(&ModelBased::new(lut), 3, 16).is_none(), "cold");
    }

    #[test]
    fn replicate_policies_builds_independent_instances() {
        let lut = Lut::new([(1usize, 4usize), (16, 1)].into_iter().collect()).unwrap();
        let ps = replicate_policies(&PolicySpec::ModelBased, Some(&lut), 3).unwrap();
        assert_eq!(ps.len(), 3);
        for p in &ps {
            assert_eq!(p.label(), "model-based");
            assert_eq!(p.choose(1, 8), 4, "cold start follows the shared LUT");
        }
        assert!(replicate_policies(&PolicySpec::Adaptive, None, 2).is_err());
        let fixed = replicate_policies(&PolicySpec::Fixed(2), None, 2).unwrap();
        assert_eq!(fixed[0].choose(9, 8), 2);
    }
}
