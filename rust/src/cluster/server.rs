//! The real threaded cluster path (stub backend): N worker threads, each
//! owning its own engine + continuous batcher + policy, behind a
//! dispatcher thread that owns the [`Router`](super::Router).
//!
//! Plumbing (all `std::sync::mpsc`, mirroring the single-worker server):
//!
//! ```text
//! client ──ServerMsg──> dispatcher ──per-shard queues──> worker 0..N-1
//!                           │  ▲                            │
//!                           │  └── ShardGauge (live/queued/marginal,
//!                           │      published at round boundaries)
//!                           │
//!   collector threads <──ServerResponse── workers
//!        └──(shard, response)──> experiment harness
//! ```
//!
//! The dispatcher keeps its own in-flight count per shard (sent minus
//! completed — an upper bound on live + queued that is exact between
//! round boundaries) and reads each worker's [`ShardGauge`] for the
//! fitted marginal cost, so [`CostAware`](super::CostAware) routing works
//! on the real path as in the DES, up to gauge staleness: the gauge only
//! refreshes at round boundaries, so the dispatcher scales the published
//! marginal by how far its in-flight count has moved past the published
//! load, keeping bursts that arrive within one round from dogpiling the
//! momentarily-cheapest shard.  Workers publish the gauge between
//! rounds; the dispatcher never blocks on a worker.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::PolicySpec;
use crate::metrics::{LatencyRecorder, RequestRecord};
use crate::server::{
    run_client, worker, Backend, ExperimentOutcome, SchedulingMode, ServerConfig,
    ServerMsg, ServerResponse,
};
use crate::testkit::stub::StubSpec;
use crate::traffic::Trace;

use super::{build_router, ShardBreakdown, ShardLoad};

/// Cold-prediction sentinel for the marginal-cost gauge slot (a real
/// marginal cost is a finite non-negative f64, whose bits never collide
/// with this).
const COLD: u64 = u64::MAX;

/// Lock-free load snapshot one cluster worker publishes for the
/// dispatcher's router: live rows, queued requests, the policy's fitted
/// marginal per-token cost of one more request (`None` while the fits
/// are cold), and the shard's deadline pressure (resident requests with
/// lost or predicted-lost SLOs).
#[derive(Debug)]
pub struct ShardGauge {
    live: AtomicUsize,
    queued: AtomicUsize,
    marginal_bits: AtomicU64,
    slo_pressure: AtomicUsize,
}

impl Default for ShardGauge {
    fn default() -> Self {
        ShardGauge {
            live: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            marginal_bits: AtomicU64::new(COLD),
            slo_pressure: AtomicUsize::new(0),
        }
    }
}

impl ShardGauge {
    pub fn publish(
        &self,
        live: usize,
        queued: usize,
        marginal: Option<f64>,
        slo_pressure: usize,
    ) {
        self.live.store(live, Ordering::Relaxed);
        self.queued.store(queued, Ordering::Relaxed);
        let bits = match marginal {
            Some(m) if m.is_finite() => m.to_bits(),
            _ => COLD,
        };
        self.marginal_bits.store(bits, Ordering::Relaxed);
        self.slo_pressure.store(slo_pressure, Ordering::Relaxed);
    }

    pub fn live(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    pub fn marginal(&self) -> Option<f64> {
        let bits = self.marginal_bits.load(Ordering::Relaxed);
        (bits != COLD).then(|| f64::from_bits(bits))
    }

    pub fn slo_pressure(&self) -> usize {
        self.slo_pressure.load(Ordering::Relaxed)
    }
}

/// Run one full client/cluster experiment on the stub backend: spawn
/// `cfg.workers` shard workers and the dispatcher, wait until every shard
/// is ready, replay the trace, collect all responses, then shut down and
/// assemble per-shard breakdowns.
pub fn run_cluster_experiment(
    spec: StubSpec,
    cfg: ServerConfig,
    policy: PolicySpec,
    lut: Option<crate::scheduler::Lut>,
    trace: &Trace,
) -> Result<ExperimentOutcome> {
    let n_shards = cfg.workers;
    if n_shards < 2 {
        bail!("run_cluster_experiment needs workers >= 2");
    }
    if cfg.mode != SchedulingMode::Continuous {
        bail!(
            "the cluster path serves continuous mode only (per-shard \
             batch-to-completion would starve the router of round boundaries)"
        );
    }
    let epoch = Instant::now();
    // align the telemetry clock (and the flight recorder's) with the
    // experiment epoch before any shard handle is cloned: every shard
    // handle shares the same inner, so all tracks rebase at once
    cfg.telemetry.rebase_to_now();

    // --- spawn the shard workers ---
    let mut shard_txs: Vec<Sender<ServerMsg>> = Vec::with_capacity(n_shards);
    let mut lut_rxs = Vec::with_capacity(n_shards);
    let mut report_rxs = Vec::with_capacity(n_shards);
    let mut worker_joins: Vec<JoinHandle<Result<()>>> = Vec::with_capacity(n_shards);
    let mut resp_rxs: Vec<Receiver<ServerResponse>> = Vec::with_capacity(n_shards);
    let gauges: Vec<Arc<ShardGauge>> = (0..n_shards)
        .map(|_| Arc::new(ShardGauge::default()))
        .collect();
    for k in 0..n_shards {
        let (req_tx, req_rx) = channel::<ServerMsg>();
        let (resp_tx, resp_rx) = channel::<ServerResponse>();
        let (lut_tx, lut_rx) = channel();
        let (report_tx, report_rx) = channel();
        let w_spec = spec.clone();
        let mut w_cfg = cfg.clone();
        // each shard's engine emits events tagged with its shard index
        w_cfg.telemetry = cfg.telemetry.for_shard(k);
        let w_policy = policy.clone();
        let w_lut = lut.clone();
        let w_gauge = Arc::clone(&gauges[k]);
        let join = std::thread::Builder::new()
            .name(format!("specbatch-shard-{k}"))
            .spawn(move || {
                worker(
                    Backend::Stub(w_spec),
                    w_cfg,
                    w_policy,
                    w_lut,
                    epoch,
                    req_rx,
                    resp_tx,
                    lut_tx,
                    report_tx,
                    Some(w_gauge),
                )
            })
            .expect("spawning shard worker thread");
        shard_txs.push(req_tx);
        lut_rxs.push(lut_rx);
        report_rxs.push(report_rx);
        worker_joins.push(join);
        resp_rxs.push(resp_rx);
    }

    // --- wait for every shard to finish startup ---
    let mut lut_used = None;
    for (k, rx) in lut_rxs.iter().enumerate() {
        let l = rx
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow!("shard {k} did not become ready"))?;
        if lut_used.is_none() {
            lut_used = l;
        }
    }

    // --- dispatcher: routes arrivals, fans shutdown out to the shards ---
    let inflight: Arc<Vec<AtomicUsize>> =
        Arc::new((0..n_shards).map(|_| AtomicUsize::new(0)).collect());
    let (dispatch_tx, dispatch_rx) = channel::<ServerMsg>();
    let dispatcher = {
        // the probe seed only matters for reproducibility in the DES;
        // the real path is wall-clock anyway
        let mut router = build_router(cfg.router, 0);
        let shard_txs = shard_txs.clone();
        let gauges: Vec<Arc<ShardGauge>> = gauges.iter().map(Arc::clone).collect();
        let inflight = Arc::clone(&inflight);
        let tel = cfg.telemetry.clone();
        std::thread::Builder::new()
            .name("specbatch-dispatcher".into())
            .spawn(move || loop {
                match dispatch_rx.recv() {
                    Ok(ServerMsg::Request(mut r)) => {
                        let loads: Vec<ShardLoad> = (0..shard_txs.len())
                            .map(|k| {
                                let live = gauges[k].live();
                                let total = inflight[k].load(Ordering::Relaxed);
                                // the gauge is frozen at the shard's last
                                // round boundary; requests routed since
                                // (total beyond the published load) must
                                // keep raising the marginal, or a burst
                                // arriving within one round would dogpile
                                // the momentarily-cheapest shard
                                let published = live + gauges[k].queued();
                                let marginal_cost = gauges[k].marginal().map(|m| {
                                    let staleness =
                                        (total + 1) as f64 / (published + 1) as f64;
                                    m * staleness.max(1.0)
                                });
                                ShardLoad {
                                    shard: k,
                                    live: live.min(total),
                                    queued: total.saturating_sub(live),
                                    marginal_cost,
                                    slo_pressure: gauges[k].slo_pressure(),
                                }
                            })
                            .collect();
                        let k = router.route(&loads).min(shard_txs.len() - 1);
                        // stamp the dispatcher hop — the slice of latency
                        // spent between client send and shard enqueue —
                        // so the shard's waterfall can split it out of
                        // the queue component
                        r.route_hop =
                            (epoch.elapsed().as_secs_f64() - r.sent_at).max(0.0);
                        if tel.active() {
                            // score vector the router saw: staleness-scaled
                            // marginal cost where warm, in-flight load else
                            let scores: Vec<f64> = loads
                                .iter()
                                .map(|l| {
                                    l.marginal_cost
                                        .unwrap_or((l.live + l.queued) as f64)
                                })
                                .collect();
                            tel.route(tel.now(), r.id, k, &scores);
                        }
                        inflight[k].fetch_add(1, Ordering::Relaxed);
                        if shard_txs[k].send(ServerMsg::Request(r)).is_err() {
                            break;
                        }
                    }
                    Ok(ServerMsg::Shutdown) | Err(_) => {
                        for tx in &shard_txs {
                            let _ = tx.send(ServerMsg::Shutdown);
                        }
                        break;
                    }
                }
            })
            .expect("spawning dispatcher thread")
    };

    // --- collectors: merge per-shard responses, settle in-flight counts ---
    let (merged_tx, merged_rx) = channel::<(usize, ServerResponse)>();
    let collectors: Vec<JoinHandle<()>> = resp_rxs
        .into_iter()
        .enumerate()
        .map(|(k, rx)| {
            let merged_tx = merged_tx.clone();
            let inflight = Arc::clone(&inflight);
            std::thread::Builder::new()
                .name(format!("specbatch-collector-{k}"))
                .spawn(move || {
                    while let Ok(resp) = rx.recv() {
                        inflight[k].fetch_sub(1, Ordering::Relaxed);
                        if merged_tx.send((k, resp)).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawning collector thread")
        })
        .collect();
    drop(merged_tx);

    // --- client: replay the trace against the dispatcher in real time ---
    let n = trace.len();
    let client_tx = dispatch_tx.clone();
    let trace_cloned = trace.clone();
    let client = std::thread::Builder::new()
        .name("specbatch-client".into())
        .spawn(move || run_client(&trace_cloned, &client_tx, epoch))
        .expect("spawning client thread");

    let mut recorder = LatencyRecorder::new();
    while recorder.len() < n {
        let (shard, resp) = merged_rx
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow!("timed out waiting for responses ({}/{n})", recorder.len()))?;
        recorder.push(RequestRecord {
            id: resp.id,
            sent_at: resp.sent_at,
            started_at: resp.started_at,
            finished_at: resp.finished_at,
            tokens: resp.tokens.len(),
            batch: resp.batch,
            spec_len: resp.spec_len,
            shard,
            deadline: resp.deadline,
            deferred_rounds: resp.deferred_rounds,
            shed: resp.shed,
            first_token_at: resp.first_token_at,
        });
    }
    client
        .join()
        .map_err(|_| anyhow!("client thread panicked"))??;

    // --- shutdown: dispatcher fans out, workers report, collectors drain ---
    let _ = dispatch_tx.send(ServerMsg::Shutdown);
    dispatcher
        .join()
        .map_err(|_| anyhow!("dispatcher thread panicked"))?;
    let mut shards = Vec::with_capacity(n_shards);
    let mut deferrals = 0usize;
    let mut sheds = 0usize;
    for (k, (join, report_rx)) in worker_joins
        .into_iter()
        .zip(report_rxs.into_iter())
        .enumerate()
    {
        match join.join() {
            Ok(r) => r?,
            Err(_) => bail!("shard {k} worker thread panicked"),
        }
        let report = report_rx.try_recv().unwrap_or_default();
        deferrals += report.deferrals;
        sheds += report.sheds;
        let mut shard_rec = LatencyRecorder::new();
        for r in recorder.records().iter().filter(|r| r.shard == k) {
            shard_rec.push(*r);
        }
        let served: Vec<&RequestRecord> = shard_rec
            .records()
            .iter()
            .filter(|r| !r.shed)
            .collect();
        let mean_latency = if served.is_empty() {
            f64::NAN
        } else {
            served.iter().map(|r| r.latency()).sum::<f64>() / served.len() as f64
        };
        shards.push(ShardBreakdown {
            shard: k,
            requests: served.len(),
            mean_latency,
            rounds: report.timeline,
            policy_snapshot: report.policy_snapshot,
            kv_blocks: report.kv_blocks,
            prefix: report.prefix,
            slo: shard_rec.slo_attainment(),
        });
    }
    for c in collectors {
        let _ = c.join();
    }

    // merge the per-shard block pools so experiment-level leak checks see
    // the whole cluster at once
    let kv_blocks = shards
        .iter()
        .filter_map(|b| b.kv_blocks)
        .reduce(|a, b| a.merged(&b));
    let prefix = shards
        .iter()
        .filter_map(|b| b.prefix)
        .reduce(|a, b| a.merged(&b));
    Ok(ExperimentOutcome {
        recorder,
        lut: lut_used,
        timeline: Vec::new(),
        policy_snapshot: None,
        shards,
        kv_blocks,
        prefix,
        deferrals,
        sheds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RouterSpec;
    use crate::dataset::Prompt;
    use crate::traffic::TrafficPattern;

    fn pool() -> Vec<Prompt> {
        (3..=8usize)
            .map(|len| Prompt {
                ids: (0..len).map(|k| 5 + (k * 3 % 40) as i32).collect(),
                text: String::new(),
            })
            .collect()
    }

    fn cluster_cfg(workers: usize, router: RouterSpec) -> ServerConfig {
        ServerConfig {
            max_batch: 4,
            max_new_tokens: 12,
            mode: SchedulingMode::Continuous,
            workers,
            router,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn threaded_cluster_serves_every_request_once() {
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.002,
                cv: 1.0,
            },
            &pool(),
            24,
            7,
        );
        let out = run_cluster_experiment(
            StubSpec::default(),
            cluster_cfg(3, RouterSpec::RoundRobin),
            PolicySpec::Fixed(2),
            None,
            &trace,
        )
        .unwrap();
        assert_eq!(out.recorder.len(), 24);
        let mut ids: Vec<u64> = out.recorder.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..24).collect::<Vec<u64>>());
        // round-robin: every shard served exactly a third of the trace
        assert_eq!(out.shards.len(), 3);
        for b in &out.shards {
            assert_eq!(b.requests, 8, "shard {} count", b.shard);
            assert!(!b.rounds.is_empty(), "shard {} recorded no rounds", b.shard);
        }
        assert_eq!(out.recorder.per_shard_counts(), vec![8, 8, 8]);
        // under the paged layout every shard pool must come back full
        if let Some(stats) = out.kv_blocks {
            assert!(stats.is_leak_free(), "cluster leaked blocks: {stats:?}");
        }
    }

    #[test]
    fn threaded_cluster_rejects_static_mode_and_single_worker() {
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.01,
                cv: 1.0,
            },
            &pool(),
            4,
            1,
        );
        let mut cfg = cluster_cfg(2, RouterSpec::RoundRobin);
        cfg.mode = SchedulingMode::Static;
        assert!(run_cluster_experiment(
            StubSpec::default(),
            cfg,
            PolicySpec::Fixed(1),
            None,
            &trace
        )
        .is_err());
        assert!(run_cluster_experiment(
            StubSpec::default(),
            cluster_cfg(1, RouterSpec::RoundRobin),
            PolicySpec::Fixed(1),
            None,
            &trace
        )
        .is_err());
    }

    #[test]
    fn threaded_cluster_cost_aware_with_model_based_policies() {
        let trace = Trace::generate(
            &TrafficPattern::Stationary {
                interval: 0.001,
                cv: 1.0,
            },
            &pool(),
            32,
            11,
        );
        let out = run_cluster_experiment(
            StubSpec::default(),
            cluster_cfg(2, RouterSpec::CostAware),
            PolicySpec::ModelBased,
            None,
            &trace,
        )
        .unwrap();
        assert_eq!(out.recorder.len(), 32);
        assert!(out.lut.is_some(), "model-based shards resolve a fallback LUT");
        // both shards took part and reported a policy snapshot
        assert_eq!(out.shards.len(), 2);
        assert!(out.shards.iter().all(|b| b.requests > 0));
        assert!(out.shards.iter().all(|b| b.policy_snapshot.is_some()));
    }
}
