//! Offline profiling stage of adaptive speculative decoding (Sec. 4).
//!
//! Measures per-token decode latency for every (batch bucket, speculation
//! length) pair on a sample of the **profile** split, then builds the
//! [`Lut`] mapping each bucket to its argmin speculation length.  The
//! search space is deliberately tiny (the paper: "the optimal speculation
//! length is usually small (less than ten)" and "we profile batch sizes
//! which are powers of two"), so profiling takes minutes and is amortized
//! over a long-running service.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::dataset::Prompt;
use crate::engine::Engine;
use crate::log_info;
use crate::policy::{Fixed, NoSpec, SpeculationPolicy};
use crate::scheduler::Lut;
use crate::util::csv::{f, Csv};

/// Profiling knobs.
#[derive(Debug, Clone)]
pub struct ProfilerConfig {
    /// buckets to profile (defaults to the artifact matrix buckets)
    pub buckets: Vec<usize>,
    /// speculation lengths to try (0 = no speculation is always tried)
    pub spec_lengths: Vec<usize>,
    /// new tokens generated per measurement batch
    pub tokens_per_run: usize,
    /// measurement batches per (b, s) point
    pub repeats: usize,
}

impl ProfilerConfig {
    pub fn from_manifest(m: &crate::runtime::Manifest) -> ProfilerConfig {
        ProfilerConfig {
            buckets: m.batch_buckets.clone(),
            spec_lengths: m.verify_lengths.clone(),
            tokens_per_run: 24,
            repeats: 2,
        }
    }

    /// Grid derived from the engine's limits (works on any backend).
    pub fn from_limits(limits: &crate::engine::EngineLimits) -> ProfilerConfig {
        ProfilerConfig {
            buckets: limits.batch_buckets.clone(),
            spec_lengths: (0..=limits.max_spec_overall()).collect(),
            tokens_per_run: 24,
            repeats: 2,
        }
    }
}

/// One measured grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub batch: usize,
    pub s: usize,
    /// seconds per generated token (decode only)
    pub per_token_latency: f64,
    /// mean accepted drafts per round (0 for s = 0)
    pub mean_accepted: f64,
}

/// Full profiling result: the grid and the derived LUT.
#[derive(Debug, Clone)]
pub struct ProfileResult {
    pub grid: Vec<GridPoint>,
    pub lut: Lut,
}

impl ProfileResult {
    /// Optimal s per bucket (the starred points of Fig. 1).
    pub fn optimal(&self) -> &BTreeMap<usize, usize> {
        self.lut.entries()
    }

    /// Grid as CSV (columns: batch, s, per_token_latency_s, mean_accepted).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&["batch", "s", "per_token_latency_s", "mean_accepted"]);
        for p in &self.grid {
            csv.row(&[
                p.batch.to_string(),
                p.s.to_string(),
                f(p.per_token_latency),
                f(p.mean_accepted),
            ]);
        }
        csv
    }
}

/// Run the profiling grid and build the LUT.
///
/// `prompts` must come from the profile split (disjoint from evaluation,
/// Sec. 5.3).  Latency is decode-only per-token wall time, matching the
/// paper's Fig. 1 metric.
pub fn profile(
    engine: &mut Engine<'_>,
    prompts: &[Prompt],
    cfg: &ProfilerConfig,
) -> Result<ProfileResult> {
    if prompts.is_empty() {
        bail!("profiler needs at least one prompt");
    }
    // precompile the grid: compilation must not contaminate measurements
    let max_bucket = cfg.buckets.iter().copied().max().unwrap_or(1);
    let max_s = cfg.spec_lengths.iter().copied().max().unwrap_or(0);
    engine.warmup(max_bucket, max_s)?;
    let limits = engine.limits().clone();
    let mut grid = Vec::new();
    let mut entries = BTreeMap::new();

    for &b in &cfg.buckets {
        if !limits.batch_buckets.contains(&b) {
            bail!(
                "bucket {b} not in the engine's bucket set {:?}",
                limits.batch_buckets
            );
        }
        let max_s = limits.max_spec_len(b);
        let mut best: Option<(usize, f64)> = None;

        for &s in &cfg.spec_lengths {
            if s > max_s {
                continue;
            }
            let mut policy: Box<dyn SpeculationPolicy> = if s == 0 {
                Box::new(NoSpec)
            } else {
                Box::new(Fixed(s))
            };
            let mut lat_sum = 0.0;
            let mut acc_sum = 0.0;
            let mut prompt_cursor = 0usize;
            for _ in 0..cfg.repeats {
                // rotate through the profile prompts deterministically
                let batch_prompts: Vec<Vec<i32>> = (0..b)
                    .map(|i| prompts[(prompt_cursor + i) % prompts.len()].ids.clone())
                    .collect();
                prompt_cursor += b;
                let out =
                    engine.generate_batch(&batch_prompts, cfg.tokens_per_run, policy.as_mut())?;
                lat_sum += out.stats.per_token_latency();
                acc_sum += out.stats.mean_accepted();
            }
            let lat = lat_sum / cfg.repeats as f64;
            let acc = acc_sum / cfg.repeats as f64;
            grid.push(GridPoint {
                batch: b,
                s,
                per_token_latency: lat,
                mean_accepted: acc,
            });
            log_info!(
                "profile b={b} s={s}: {:.3} ms/token (mean accepted {acc:.2})",
                lat * 1e3
            );
            if best.map_or(true, |(_, l)| lat < l) {
                best = Some((s, lat));
            }
        }
        let (s_opt, lat) = best.ok_or_else(|| {
            anyhow::anyhow!("no feasible speculation length for bucket {b}")
        })?;
        log_info!("profile b={b}: s_opt={s_opt} ({:.3} ms/token)", lat * 1e3);
        entries.insert(b, s_opt);
    }

    Ok(ProfileResult {
        grid,
        lut: Lut::new(entries)?,
    })
}
