//! Offline speculation-length scheduling: the paper's contribution
//! (Sec. 4).
//!
//! * [`Lut`] — the batch-size -> optimal-s look-up table built by offline
//!   profiling on power-of-two buckets, with the paper's interpolation
//!   rule ("for batch sizes that are not profiled, choose the **smaller**
//!   speculation length of the nearest two profiled batch sizes");
//! * [`profiler`] — the offline grid search that builds the LUT.
//!
//! The round-by-round policies that consume a LUT (and the online
//! model-based policy that supersedes it under drift) live in
//! [`crate::policy`].

pub mod profiler;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Batch-size -> optimal speculation length look-up table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lut {
    /// profiled (batch bucket, s_opt) pairs, keyed by bucket
    entries: BTreeMap<usize, usize>,
}

impl Lut {
    pub fn new(entries: BTreeMap<usize, usize>) -> Result<Lut> {
        if entries.is_empty() {
            bail!("LUT must have at least one profiled batch size");
        }
        Ok(Lut { entries })
    }

    pub fn entries(&self) -> &BTreeMap<usize, usize> {
        &self.entries
    }

    /// Optimal speculation length for a batch size.
    ///
    /// Exact hits use the profiled value.  Between two profiled buckets the
    /// paper picks the *smaller* of the two speculation lengths (Sec. 4) —
    /// conservative, since over-speculating at large batch actively hurts
    /// while under-speculating only forgoes some gain.  Outside the
    /// profiled range, clamp to the nearest profiled bucket.
    pub fn lookup(&self, batch: usize) -> usize {
        if let Some(&s) = self.entries.get(&batch) {
            return s;
        }
        let below = self.entries.range(..batch).next_back();
        let above = self.entries.range(batch..).next();
        match (below, above) {
            (Some((_, &lo)), Some((_, &hi))) => lo.min(hi),
            (Some((_, &lo)), None) => lo,
            (None, Some((_, &hi))) => hi,
            (None, None) => unreachable!("LUT is non-empty"),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(b, s)| (b.to_string(), Json::Num(*s as f64)))
                .collect(),
        )
    }

    pub fn from_json(json: &Json) -> Result<Lut> {
        let mut entries = BTreeMap::new();
        for (k, v) in json.as_obj()? {
            entries.insert(k.parse::<usize>()?, v.as_usize()?);
        }
        Lut::new(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lut(pairs: &[(usize, usize)]) -> Lut {
        Lut::new(pairs.iter().copied().collect()).unwrap()
    }

    #[test]
    fn exact_bucket_hits() {
        let l = lut(&[(1, 5), (2, 4), (4, 3), (8, 2), (16, 1)]);
        assert_eq!(l.lookup(1), 5);
        assert_eq!(l.lookup(8), 2);
        assert_eq!(l.lookup(16), 1);
    }

    #[test]
    fn between_buckets_takes_smaller_s() {
        // paper Sec. 4: "choose the smaller speculation length of the
        // nearest two profiled batch sizes"
        let l = lut(&[(4, 3), (8, 2)]);
        assert_eq!(l.lookup(5), 2);
        assert_eq!(l.lookup(7), 2);
        let l2 = lut(&[(4, 2), (8, 6)]);
        assert_eq!(l2.lookup(6), 2);
    }

    #[test]
    fn clamps_outside_range() {
        let l = lut(&[(2, 4), (8, 2)]);
        assert_eq!(l.lookup(1), 4);
        assert_eq!(l.lookup(32), 2);
    }

    #[test]
    fn single_entry_lut_is_constant() {
        let l = lut(&[(4, 3)]);
        assert_eq!(l.lookup(1), 3);
        assert_eq!(l.lookup(4), 3);
        assert_eq!(l.lookup(100), 3);
    }

    #[test]
    fn boundary_probes_clamp_below_and_above_the_profiled_range() {
        let l = lut(&[(2, 4), (16, 1)]);
        // below the smallest profiled bucket (including batch 0)
        assert_eq!(l.lookup(0), 4);
        assert_eq!(l.lookup(1), 4);
        // exactly on the edges
        assert_eq!(l.lookup(2), 4);
        assert_eq!(l.lookup(16), 1);
        // far above the largest profiled bucket
        assert_eq!(l.lookup(17), 1);
        assert_eq!(l.lookup(usize::MAX), 1);
    }

    #[test]
    fn between_buckets_with_equal_values_keeps_that_value() {
        let l = lut(&[(4, 3), (8, 3)]);
        assert_eq!(l.lookup(5), 3);
        assert_eq!(l.lookup(7), 3);
    }

    #[test]
    fn lut_json_roundtrip() {
        let l = lut(&[(1, 5), (16, 1)]);
        let j = l.to_json();
        assert_eq!(Lut::from_json(&j).unwrap(), l);
    }

    #[test]
    fn empty_lut_rejected() {
        assert!(Lut::new(BTreeMap::new()).is_err());
    }
}
