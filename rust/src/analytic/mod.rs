//! The paper's analytical model of batched speculative decoding
//! (Sec. 3.3, Table 1, Eq. 1-12).
//!
//! Components:
//!
//! * [`AcceptanceModel`] — `l(s) ≈ c·s^γ` (Eq. 5), fitted from measured
//!   accepted-count samples via the Eq. 4 estimator + log-log regression
//!   (Fig. 2; the paper reports `0.9·s^0.548`);
//! * [`StepCostModel`] — `t_L(b, s) ≈ α_b·s + β` (Fig. 3) and the
//!   per-draft SSM cost `t_S(b, 1)`;
//! * [`TotalTimeModel`] — Eq. 7/8 total runtime, its derivative numerator
//!   `δ` (Eq. 11), and the optimal speculation length `s_opt` (Eq. 12);
//! * monotonicity checks used by the property tests: `δ` is increasing in
//!   both `α_b` and `s`, hence `s_opt(b)` is non-increasing in `b` — the
//!   paper's key claim.

use anyhow::{bail, Result};

use crate::util::stats::{linear_fit, power_fit};

/// Eq. 4: estimate l(s) for s = 1..s_max from per-round accepted counts.
///
/// `samples[i]` is the number of drafts accepted in one speculative round
/// (an observation of min(l_i, s_used)); the estimator is
/// `l(s) ≈ mean(min(l_i, s))`.  Samples should come from rounds whose
/// speculation length was >= s_max, otherwise l(s) is clipped too early.
pub fn l_of_s_estimate(samples: &[u32], s_max: usize) -> Vec<f64> {
    assert!(s_max >= 1);
    (1..=s_max)
        .map(|s| {
            if samples.is_empty() {
                0.0
            } else {
                samples
                    .iter()
                    .map(|&l| (l as usize).min(s) as f64)
                    .sum::<f64>()
                    / samples.len() as f64
            }
        })
        .collect()
}

/// The fitted acceptance curve `l(s) = c·s^γ` (Eq. 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceptanceModel {
    pub c: f64,
    pub gamma: f64,
    /// r² of the log-log fit
    pub r2: f64,
}

impl AcceptanceModel {
    /// The paper's measured curve (Fig. 2): 0.9·s^0.548.
    pub fn paper() -> AcceptanceModel {
        AcceptanceModel {
            c: 0.9,
            gamma: 0.548,
            r2: 1.0,
        }
    }

    /// Fit from an l(s) curve (index i = l(i+1)).
    pub fn fit(l_curve: &[f64]) -> Result<AcceptanceModel> {
        if l_curve.len() < 2 {
            bail!("need l(s) at >= 2 speculation lengths to fit");
        }
        let xs: Vec<f64> = (1..=l_curve.len()).map(|s| s as f64).collect();
        let (c, gamma, r2) = power_fit(&xs, l_curve);
        Ok(AcceptanceModel { c, gamma, r2 })
    }

    /// Fit directly from accepted-count samples (Eq. 4 then Eq. 5).
    pub fn fit_samples(samples: &[u32], s_max: usize) -> Result<AcceptanceModel> {
        AcceptanceModel::fit(&l_of_s_estimate(samples, s_max))
    }

    pub fn l(&self, s: f64) -> f64 {
        self.c * s.powf(self.gamma)
    }

    /// Sub-linearity: γ < 1 (the paper's Eq. 6 argument).
    pub fn is_sublinear(&self) -> bool {
        self.gamma < 1.0
    }
}

/// `t_L(b, s) = α_b·s + β` per verify step, and `t_S(b, 1)` per draft
/// token, for one batch size (Fig. 3 linearization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCostModel {
    pub batch: usize,
    /// marginal LLM cost per speculated token (α_b), seconds
    pub alpha: f64,
    /// fixed LLM cost per step (β), seconds
    pub beta: f64,
    /// SSM cost per drafted token, t_S(b, 1), seconds
    pub t_ssm: f64,
    /// r² of the linear fit
    pub r2: f64,
}

impl StepCostModel {
    /// Fit α_b, β from measured (s, t_L) pairs for one batch size.
    pub fn fit(batch: usize, s_values: &[f64], t_l: &[f64], t_ssm: f64) -> Result<StepCostModel> {
        if s_values.len() < 2 {
            bail!("need >= 2 (s, t_L) points");
        }
        let (alpha, beta, r2) = linear_fit(s_values, t_l);
        Ok(StepCostModel {
            batch,
            alpha,
            beta,
            t_ssm,
            r2,
        })
    }

    pub fn t_llm(&self, s: f64) -> f64 {
        self.alpha * s + self.beta
    }
}

/// Eq. 7/8: expected total time per generated token and the s_opt solver.
#[derive(Debug, Clone, Copy)]
pub struct TotalTimeModel {
    pub acceptance: AcceptanceModel,
    pub cost: StepCostModel,
}

impl TotalTimeModel {
    /// Eq. 7 normalized by N: expected seconds per generated token at
    /// speculation length s (s >= 1).
    ///
    /// `(t_L(b,s) + s·t_S(b,1)) / (l(s) + 1)`
    pub fn time_per_token(&self, s: f64) -> f64 {
        (self.cost.t_llm(s) + s * self.cost.t_ssm) / (self.acceptance.l(s) + 1.0)
    }

    /// Seconds per token without speculation (one LLM step, one token).
    pub fn time_per_token_nospec(&self) -> f64 {
        self.cost.beta
    }

    /// Eq. 11: δ(s) = K·α'_b·s^γ − L·s^(γ−1) + α'_b with K = (1−γ)c,
    /// L = c·β·γ, and α'_b = α_b + t_S (the paper merges the SSM slope
    /// into α_b).  s_opt satisfies δ(s_opt) = 0; δ is increasing in s.
    pub fn delta(&self, s: f64) -> f64 {
        let a = &self.acceptance;
        let alpha = self.cost.alpha + self.cost.t_ssm;
        let k = (1.0 - a.gamma) * a.c;
        let l = a.c * self.cost.beta * a.gamma;
        k * alpha * s.powf(a.gamma) - l * s.powf(a.gamma - 1.0) + alpha
    }

    /// Continuous s_opt via bisection on δ (Eq. 12), clamped to
    /// [1, s_max].  δ increasing in s makes bisection exact.
    pub fn s_opt_continuous(&self, s_max: f64) -> f64 {
        let (mut lo, mut hi) = (1.0f64, s_max);
        if self.delta(lo) >= 0.0 {
            return lo; // already past the optimum at s=1
        }
        if self.delta(hi) <= 0.0 {
            return hi; // optimum beyond the available range
        }
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if self.delta(mid) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Discrete s_opt: argmin over s ∈ {0, 1..s_max} of expected time per
    /// token (0 = no speculation, Eq. 7 vs the plain-decode cost).
    pub fn s_opt(&self, s_max: usize) -> usize {
        let mut best = (0usize, self.time_per_token_nospec());
        for s in 1..=s_max {
            let t = self.time_per_token(s as f64);
            if t < best.1 {
                best = (s, t);
            }
        }
        best.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cost(batch: usize, alpha: f64) -> StepCostModel {
        StepCostModel {
            batch,
            alpha,
            beta: 0.03,
            t_ssm: 0.002,
            r2: 1.0,
        }
    }

    #[test]
    fn eq4_estimator() {
        // samples of l_i: 0,1,2,3 -> l(1) = mean(min(l,1)) = 1.5/4
        let samples = [0, 1, 2, 3];
        let l = l_of_s_estimate(&samples, 3);
        assert!((l[0] - 0.75).abs() < 1e-12); // min(.,1): 0,1,1,1
        assert!((l[1] - 1.25).abs() < 1e-12); // 0,1,2,2
        assert!((l[2] - 1.5).abs() < 1e-12); // 0,1,2,3
        // monotone non-decreasing in s
        assert!(l[0] <= l[1] && l[1] <= l[2]);
    }

    #[test]
    fn acceptance_fit_recovers_paper_curve() {
        let m = AcceptanceModel::paper();
        let curve: Vec<f64> = (1..=8).map(|s| m.l(s as f64)).collect();
        let fit = AcceptanceModel::fit(&curve).unwrap();
        assert!((fit.c - 0.9).abs() < 1e-9);
        assert!((fit.gamma - 0.548).abs() < 1e-9);
        assert!(fit.is_sublinear());
    }

    #[test]
    fn step_cost_fit() {
        let s = [1.0, 2.0, 4.0, 8.0];
        let t: Vec<f64> = s.iter().map(|x| 0.004 * x + 0.03).collect();
        let m = StepCostModel::fit(8, &s, &t, 0.001).unwrap();
        assert!((m.alpha - 0.004).abs() < 1e-9);
        assert!((m.beta - 0.03).abs() < 1e-9);
        assert!((m.t_llm(3.0) - 0.042).abs() < 1e-9);
    }

    #[test]
    fn speculation_beats_nospec_when_alpha_small() {
        // tiny marginal verify cost: speculation must win
        let m = TotalTimeModel {
            acceptance: AcceptanceModel::paper(),
            cost: paper_cost(1, 0.0005),
        };
        let s_opt = m.s_opt(8);
        assert!(s_opt >= 2, "s_opt={s_opt}");
        assert!(m.time_per_token(s_opt as f64) < m.time_per_token_nospec());
    }

    #[test]
    fn s_opt_is_non_increasing_in_alpha() {
        // the paper's key claim (Sec. 3.3.3): larger b (larger α_b) ->
        // smaller optimal speculation length
        let acceptance = AcceptanceModel::paper();
        let mut last = usize::MAX;
        for (i, alpha) in [0.0002, 0.001, 0.004, 0.012, 0.03].iter().enumerate() {
            let m = TotalTimeModel {
                acceptance,
                cost: paper_cost(1 << i, *alpha),
            };
            let s = m.s_opt(8);
            assert!(s <= last, "s_opt went up: {s} after {last}");
            last = s;
        }
        // extremes actually differ
        assert!(last <= 2);
    }

    #[test]
    fn delta_is_increasing_in_s_and_alpha() {
        let m = TotalTimeModel {
            acceptance: AcceptanceModel::paper(),
            cost: paper_cost(4, 0.002),
        };
        let mut prev = f64::NEG_INFINITY;
        for s in 1..=16 {
            let d = m.delta(s as f64);
            assert!(d > prev, "delta not increasing at s={s}");
            prev = d;
        }
        let m2 = TotalTimeModel {
            acceptance: AcceptanceModel::paper(),
            cost: paper_cost(4, 0.02),
        };
        for s in 1..=8 {
            assert!(m2.delta(s as f64) > m.delta(s as f64));
        }
    }

    #[test]
    fn continuous_and_discrete_sopt_agree() {
        let m = TotalTimeModel {
            acceptance: AcceptanceModel::paper(),
            cost: paper_cost(2, 0.002),
        };
        let sc = m.s_opt_continuous(8.0);
        let sd = m.s_opt(8);
        assert!(
            (sc - sd as f64).abs() <= 1.0,
            "continuous {sc} vs discrete {sd}"
        );
    }

    #[test]
    fn fit_rejects_degenerate_input() {
        assert!(AcceptanceModel::fit(&[1.0]).is_err());
        assert!(StepCostModel::fit(1, &[1.0], &[1.0], 0.0).is_err());
    }
}
