//! The batched speculative decoding engine (the paper's Sec. 3 prototype,
//! re-built as the L3 hot path).
//!
//! The engine is **reentrant at round granularity**: a [`BatchState`]
//! owns the per-row lifecycles and KV caches of one serving epoch, and
//! the step API drives it one decode round at a time:
//!
//! ```text
//! prefill_rows(prompts)            # batch prefill -> BatchState
//! loop at round boundaries:
//!   retire_finished(state)         # free slots the moment rows finish
//!   admit_rows(state, queued)      # ingest new requests into free rows
//!   decode_round(state, policy)    # s = policy.choose(LIVE batch), then
//!                                  #   s == 0 -> plain verify round
//!                                  #   s >= 1 -> speculate + verify + accept
//!                                  # finally policy.observe(feedback)
//! ```
//!
//! [`Engine::generate_batch`] (batch-to-completion, the paper's setting)
//! and the continuous batcher ([`crate::batcher`]) are both thin drivers
//! over this API, so the policy sees the *live* batch size every round —
//! the regime where the paper's adaptive LUT pays off.
//!
//! State invariants (shared with `python/compile/engine_ref.py`, asserted
//! in debug builds and by the integration tests):
//!
//! * per row: the LLM satisfies `ingested == committed.len() - 1` after
//!   every round (the last committed token is fed, not pre-ingested);
//!   the SSM sits 1..=2 behind after a speculative round (2 when every
//!   draft was accepted — its counters advance by `dlen + s - 1`, so a
//!   full acceptance leaves the last draft and the bonus un-ingested);
//! * the SSM sees a "delta" of 1..=2 committed tokens per speculation —
//!   rounds that skip the SSM (s = 0) and freshly admitted rows grow its
//!   backlog, which [`Engine::decode_round`] re-ingests via the catch-up
//!   pass before the next speculation;
//! * rows that finish stay frozen until retired: their feeds repeat the
//!   last committed token and their commits are discarded, so executables
//!   keep their static shapes; [`Engine::retire_finished`] turns frozen
//!   rows back into vacant slots (ingest counters reset to 0) that
//!   [`Engine::admit_rows`] can refill mid-epoch.
//!
//! Backends: the engine runs identically on the real PJRT executables
//! ([`Engine::new`], `--features pjrt`) and on the deterministic testkit
//! stub pair ([`Engine::stub`], always available).
//!
//! KV layouts ([`EngineConfig::kv_layout`], see [`crate::kvcache`]):
//! under `Dense` (the seed behaviour) a carried row's context is
//! re-ingested through chunked verify calls at every epoch reshape;
//! under `Paged` the engine owns per-model block pools, every slot keeps
//! a block table, and reshape admission transfers the carried chains +
//! ingest counters instead — zero token re-ingestion, so bucket growth
//! is O(1) in the carried context.  Both layouts commit bit-identical
//! tokens (`rust/tests/kv_equivalence.rs`); only the call pattern and
//! cost differ.

pub mod acceptance;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::kvcache::prefix::{PrefixCache, PrefixStats};
use crate::kvcache::{
    BlockChain, BlockManager, CarriedKv, FlatTables, KvBlockStats, KvHandle, KvLayout,
    DEFAULT_BLOCK_SIZE,
};
use crate::model::{Kv, ModelHandle};
use crate::policy::{RoundFeedback, SpeculationPolicy};
#[cfg(feature = "pjrt")]
use crate::runtime::{ExeKind, Manifest, Runtime};
use crate::telemetry::{PhaseKind, Telemetry};
use crate::testkit::stub::{StubModel, StubRole, StubSpec};
use crate::util::timer::Stopwatch;
use acceptance::accept_into;

/// `SPECBATCH_PREFIX_CACHE=on|off` — the [`EngineConfig::prefix_cache`]
/// default (anything other than `on`/`1`/`true` reads as off).
pub fn prefix_cache_from_env() -> bool {
    std::env::var("SPECBATCH_PREFIX_CACHE")
        .map(|v| matches!(v.as_str(), "on" | "1" | "true"))
        .unwrap_or(false)
}

/// Engine knobs (defaults = paper Sec. 5 methodology).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_new_tokens: usize,
    pub stop_at_eos: bool,
    pub eos_token: i32,
    pub bos_token: i32,
    pub pad_token: i32,
    /// kept for config-file compatibility; acceptance samples are always
    /// recorded for live real rows (the Fig. 2 estimator input)
    pub record_acceptance: bool,
    /// dense per-slot KV vs paged blocks with O(1) reshape remap
    /// (defaults to `SPECBATCH_KV_LAYOUT` when set, else dense)
    pub kv_layout: KvLayout,
    /// prefix-sharing KV cache over the paged block pool: admissions
    /// whose prompt hits a cached prefix map those blocks read-only and
    /// prefill only the suffix (see [`crate::kvcache::prefix`]).
    /// Defaults to `SPECBATCH_PREFIX_CACHE` (`on`/`off`) when set, else
    /// off.  Requires the `Paged` layout — ignored under `Dense`, so
    /// env-driven CI matrices stay valid on every leg.
    pub prefix_cache: bool,
    /// minimum wall-clock seconds per decode round (0 = as fast as the
    /// backend runs).  The stub pair decodes in microseconds, which makes
    /// wall-clock SLO experiments pure scheduler-jitter noise; a small
    /// throttle (e.g. 2 ms) pins the service rate so deadline timing is
    /// reproducible on any machine.  No effect on virtual-time paths.
    pub min_round_seconds: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_new_tokens: 128,
            stop_at_eos: true,
            eos_token: 2,
            bos_token: 1,
            pad_token: 0,
            record_acceptance: false,
            kv_layout: KvLayout::default_layout(),
            prefix_cache: prefix_cache_from_env(),
            min_round_seconds: 0.0,
        }
    }
}

/// One decode round's wall time split into its execution phases
/// (derived from the stopwatch sections the round body already times).
/// `accept` is the remainder after catch-up/draft/verify, so the four
/// fields tile the round's wall time exactly — the attribution
/// invariant `rust/tests/attribution.rs` pins.  Computed every round
/// (two map reads per field), whether or not telemetry is on, so the
/// batcher can build per-request waterfalls at `--telemetry off`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundPhases {
    pub catch_up: f64,
    pub draft: f64,
    pub verify: f64,
    pub accept: f64,
}

impl RoundPhases {
    pub fn total(&self) -> f64 {
        self.catch_up + self.draft + self.verify + self.accept
    }
}

/// One decode round as seen by the policy: the live batch size it was
/// queried with, the speculation length it chose, what the round
/// committed/accepted, and how long it took (the raw material of the
/// policy feedback edge).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundInfo {
    pub live: usize,
    /// executing width (the padded bucket): `width - live` lanes are
    /// padding slack in the round's waste accounting
    pub width: usize,
    /// executed speculation length — the widest per-row choice on a
    /// ragged round (the verify call pads every lane to this span)
    pub s: usize,
    pub committed: usize,
    /// draft tokens requested over the live rows (`Σ s_i`; equals
    /// `live * s` on uniform rounds, 0 on plain rounds)
    pub drafted: usize,
    /// drafts accepted over the live real rows (0 for plain rounds)
    pub accepted: usize,
    /// wall seconds the round took, including any SSM catch-up pass (the
    /// policy feedback instead carries the catch-up-free time, which is
    /// the clean per-s cost signal)
    pub round_time: f64,
    /// the round's phase split (tiles `round_time` exactly)
    pub phases: RoundPhases,
}

/// Statistics of one serving epoch (a `generate_batch` call or a
/// continuous-batching epoch).
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    /// decode rounds after prefill (each = <=1 SSM call + 1 LLM call)
    pub rounds: usize,
    pub llm_calls: usize,
    pub ssm_calls: usize,
    /// total draft tokens proposed / accepted (live rows only)
    pub drafted: usize,
    pub accepted: usize,
    /// tokens returned to callers (sum over real rows)
    pub useful_tokens: usize,
    /// wall time of the whole call including prefill
    pub wall: Duration,
    /// wall time spent after prefill (per-token latency uses this)
    pub decode_wall: Duration,
    /// accepted-count samples (one per live row per speculative round)
    pub accept_samples: Vec<u32>,
    /// speculation length used each round
    pub spec_lens: Vec<usize>,
    /// per-round (live batch, s, committed) timeline
    pub per_round: Vec<RoundInfo>,
    /// context tokens re-fed through chunked verify calls for carried
    /// rows (dense-layout epoch reshapes; 0 under the paged layout)
    pub reingested_tokens: usize,
    /// KV entries transferred by block-table remap instead of
    /// re-ingestion (paged-layout epoch reshapes)
    pub remapped_tokens: usize,
    /// admission-control defer events charged to this epoch (one per
    /// candidate per round boundary it was held back at — the batcher's
    /// `AdmissionController` fills these; 0 under FIFO)
    pub deferrals: usize,
    /// requests shed by admission control while this epoch was active
    pub sheds: usize,
}

impl GenStats {
    /// Per-token decode latency in seconds (the paper's Fig. 1/4 metric).
    pub fn per_token_latency(&self) -> f64 {
        if self.useful_tokens == 0 {
            return f64::NAN;
        }
        self.decode_wall.as_secs_f64() / self.useful_tokens as f64
    }

    /// Mean accepted drafts per speculative round (the l̄ of Sec. 3.3).
    pub fn mean_accepted(&self) -> f64 {
        if self.accept_samples.is_empty() {
            return 0.0;
        }
        self.accept_samples.iter().map(|&a| a as f64).sum::<f64>()
            / self.accept_samples.len() as f64
    }
}

/// Output of one batch generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    /// generated tokens per input prompt (prompt excluded), truncated at
    /// `max_new_tokens` / first `<eos>`
    pub tokens: Vec<Vec<i32>>,
    pub stats: GenStats,
}

/// Batch limits the engine schedules against: bucket set, per-bucket
/// speculation/verify spans, prompt and KV capacity.  Derived from the
/// artifact [`Manifest`] on the PJRT backend and from [`StubSpec`] on the
/// stub backend.
#[derive(Debug, Clone)]
pub struct EngineLimits {
    /// compiled batch buckets, sorted ascending
    pub batch_buckets: Vec<usize>,
    pub max_prompt: usize,
    pub max_seq: usize,
    max_spec: BTreeMap<usize, usize>,
    max_verify: BTreeMap<usize, usize>,
}

impl EngineLimits {
    #[cfg(feature = "pjrt")]
    pub fn from_manifest(m: &Manifest) -> Result<EngineLimits> {
        let spec = &m
            .models
            .get("llm")
            .ok_or_else(|| anyhow::anyhow!("manifest lacks the llm model"))?
            .spec;
        let mut buckets = m.batch_buckets.clone();
        buckets.sort_unstable();
        buckets.dedup();
        let mut max_spec = BTreeMap::new();
        let mut max_verify = BTreeMap::new();
        for &b in &buckets {
            max_spec.insert(b, m.max_spec_len(b));
            let v = (1..=16)
                .take_while(|&s| m.has_exe("llm", ExeKind::Verify, b, s))
                .last()
                .unwrap_or(0);
            max_verify.insert(b, v);
        }
        Ok(EngineLimits {
            batch_buckets: buckets,
            max_prompt: spec.max_prompt,
            max_seq: spec.max_seq,
            max_spec,
            max_verify,
        })
    }

    pub fn from_stub(spec: &StubSpec) -> EngineLimits {
        let mut buckets = spec.batch_buckets.clone();
        buckets.sort_unstable();
        buckets.dedup();
        let max_spec: BTreeMap<usize, usize> =
            buckets.iter().map(|&b| (b, spec.max_spec)).collect();
        let max_verify = max_spec.clone();
        EngineLimits {
            batch_buckets: buckets,
            max_prompt: spec.max_prompt,
            max_seq: spec.max_seq,
            max_spec,
            max_verify,
        }
    }

    /// Smallest bucket that can hold `n` rows.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "batch of {n} exceeds the largest compiled bucket {:?}",
                    self.batch_buckets.last()
                )
            })
    }

    /// Like [`EngineLimits::bucket_for`], but saturates at the largest
    /// bucket instead of failing (the batcher caps admissions itself).
    pub fn bucket_for_clamped(&self, n: usize) -> usize {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.batch_buckets.last().copied().unwrap_or(1))
    }

    /// Largest speculation length with both verify and speculate support
    /// at this bucket.
    pub fn max_spec_len(&self, bucket: usize) -> usize {
        self.max_spec.get(&bucket).copied().unwrap_or(0)
    }

    /// Largest verify span at this bucket (the admission ingest chunk).
    pub fn max_verify_len(&self, bucket: usize) -> usize {
        self.max_verify.get(&bucket).copied().unwrap_or(0)
    }

    /// Largest speculation length over all buckets.
    pub fn max_spec_overall(&self) -> usize {
        self.max_spec.values().copied().max().unwrap_or(0)
    }
}

/// Per-slot row lifecycles in structure-of-arrays layout.  A slot is
/// either vacant (`real == false`: bucket padding / retired), live, or
/// frozen (`finished == true`: awaiting retirement).
///
/// Token storage is one flat arena of `bucket * stride` i32s — slot `i`'s
/// committed stream lives at `tokens[i*stride..][..len[i]]` — so the
/// decode hot loop walks parallel flat vectors instead of chasing
/// per-row `Vec`s, and committing a token is a bounds-checked store,
/// never an allocation.  `stride = max_seq + 2` covers the longest
/// committed stream any round can produce: the pre-verify capacity check
/// caps ingest at `max_seq`, so `committed <= max_seq + 1` always holds.
#[derive(Debug, Clone)]
struct RowSoa {
    stride: usize,
    tokens: Vec<i32>,
    /// committed length per slot (>= 1: prompts are non-empty, vacant
    /// slots hold a lone `<bos>`)
    len: Vec<u32>,
    prompt_len: Vec<u32>,
    max_new: Vec<u32>,
    /// real request (false = vacant padding slot)
    real: Vec<bool>,
    /// frozen rows keep shapes static but stop committing
    finished: Vec<bool>,
    /// workload class tag (0 = default) — the ragged policies' per-row
    /// acceptance-regime key; pure metadata to the execution path
    class: Vec<u8>,
}

impl RowSoa {
    fn new(bucket: usize, stride: usize, bos: i32) -> RowSoa {
        assert!(stride > 0, "RowSoa stride must be positive");
        let mut rows = RowSoa {
            stride,
            tokens: vec![0; bucket * stride],
            len: vec![0; bucket],
            prompt_len: vec![0; bucket],
            max_new: vec![0; bucket],
            real: vec![false; bucket],
            finished: vec![true; bucket],
            class: vec![0; bucket],
        };
        for i in 0..bucket {
            rows.set_vacant(i, bos);
        }
        rows
    }

    fn n(&self) -> usize {
        self.len.len()
    }

    /// Slot `i`'s full committed stream (prompt + generated).
    fn committed(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.stride..][..self.len[i] as usize]
    }

    /// Slot `i`'s generated suffix.
    fn gen_tokens(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.stride..][self.prompt_len[i] as usize..self.len[i] as usize]
    }

    fn generated(&self, i: usize) -> usize {
        (self.len[i] - self.prompt_len[i]) as usize
    }

    fn last(&self, i: usize) -> i32 {
        self.tokens[i * self.stride + self.len[i] as usize - 1]
    }

    fn push(&mut self, i: usize, t: i32) {
        let n = self.len[i] as usize;
        self.tokens[i * self.stride + n] = t;
        self.len[i] = (n + 1) as u32;
    }

    fn extend(&mut self, i: usize, ts: &[i32]) {
        let n = self.len[i] as usize;
        self.tokens[i * self.stride + n..][..ts.len()].copy_from_slice(ts);
        self.len[i] = (n + ts.len()) as u32;
    }

    fn install(&mut self, i: usize, context: &[i32], prompt_len: usize, max_new: usize) {
        self.tokens[i * self.stride..][..context.len()].copy_from_slice(context);
        self.len[i] = context.len() as u32;
        self.prompt_len[i] = prompt_len as u32;
        self.max_new[i] = max_new as u32;
        self.real[i] = true;
        self.finished[i] = false;
    }

    fn set_vacant(&mut self, i: usize, bos: i32) {
        self.tokens[i * self.stride] = bos;
        self.len[i] = 1;
        self.prompt_len[i] = 1;
        self.max_new[i] = 0;
        self.real[i] = false;
        self.finished[i] = true;
        self.class[i] = 0;
    }

    fn is_live(&self, i: usize) -> bool {
        self.real[i] && !self.finished[i]
    }

    fn committed_total(&self) -> usize {
        (0..self.n())
            .filter(|&i| self.real[i])
            .map(|i| self.generated(i))
            .sum()
    }
}

/// Per-slot block tables of a paged-layout epoch, one per model (flat
/// fixed-stride [`FlatTables`]; empty row = vacant or dense).  The block
/// ids reference the engine-owned pools ([`Engine`] is the allocator; the
/// state is only the table holder, so carried chains can outlive the
/// epoch).
struct SlotTables {
    llm: FlatTables,
    ssm: FlatTables,
}

/// The state of one serving epoch: row lifecycles + KV caches, driven by
/// the engine's step API one round at a time.
pub struct BatchState {
    bucket: usize,
    may_speculate: bool,
    rows: RowSoa,
    llm_kv: Kv,
    ssm_kv: Option<Kv>,
    /// the SSM's KV is behind (plain rounds / fresh admissions); the next
    /// speculative round runs the catch-up pass first
    ssm_backlog: bool,
    /// paged-layout block tables (None under the dense layout)
    tables: Option<SlotTables>,
    pub stats: GenStats,
}

impl BatchState {
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    pub fn live_rows(&self) -> usize {
        (0..self.rows.n()).filter(|&i| self.rows.is_live(i)).count()
    }

    pub fn has_live(&self) -> bool {
        (0..self.rows.n()).any(|i| self.rows.is_live(i))
    }

    pub fn occupied_slots(&self) -> usize {
        self.rows.real.iter().filter(|&&r| r).count()
    }

    pub fn free_slots(&self) -> usize {
        self.bucket - self.occupied_slots()
    }

    /// KV blocks this epoch currently holds across both model pools
    /// (0 under the dense layout) — the per-round utilization counter
    /// recorded into `metrics::RoundEvent`.
    pub fn kv_blocks_in_use(&self) -> usize {
        self.tables
            .as_ref()
            .map_or(0, |t| t.llm.total_blocks() + t.ssm.total_blocks())
    }

    /// Tag a slot with a workload class (0 = default).  The per-row key
    /// ragged policies choose speculation lengths by; no effect on the
    /// execution path itself.
    pub fn set_class(&mut self, slot: usize, class: u8) {
        if slot < self.rows.n() {
            self.rows.class[slot] = class;
        }
    }

    /// A slot's workload class tag.
    pub fn class_of(&self, slot: usize) -> u8 {
        self.rows.class.get(slot).copied().unwrap_or(0)
    }

    /// Generated tokens of a slot so far (None when the slot is vacant).
    pub fn generated_tokens(&self, slot: usize) -> Option<&[i32]> {
        if slot < self.rows.n() && self.rows.real[slot] {
            Some(self.rows.gen_tokens(slot))
        } else {
            None
        }
    }

    /// Test hook for the KV state-machine invariants (DESIGN.md): per
    /// slot, `(committed length, LLM ingested, SSM ingested)`.  After any
    /// speculative round the LLM counter equals `committed - 1` and the
    /// SSM counter sits within the 1..=2 delta window; after plain
    /// rounds or admissions the SSM may lag further (its catch-up
    /// backlog).
    pub fn ingest_state(&self) -> Vec<(usize, u32, Option<u32>)> {
        let llm = self.llm_kv.ingested();
        let ssm: Option<&[u32]> = self.ssm_kv.as_ref().map(|kv| kv.ingested());
        (0..self.rows.n())
            .map(|i| (self.rows.len[i] as usize, llm[i], ssm.map(|v| v[i])))
            .collect()
    }
}

/// A request handed to [`Engine::admit_rows`] at a round boundary.
///
/// Not `Clone`: a paged-layout request owns ref-counted KV block chains
/// ([`CarriedKv::Blocks`]) whose refcounts a naive clone would not copy.
#[derive(Debug)]
pub struct AdmitRequest {
    /// full committed context: the prompt, plus any previously generated
    /// tokens when re-admitting a carried-over row (epoch reshape)
    pub context: Vec<i32>,
    /// length of the original prompt prefix inside `context`
    pub prompt_len: usize,
    /// generation budget, counted from `prompt_len`
    pub max_new: usize,
    /// carried-row KV transfer: `None` for fresh admissions,
    /// `Some(Reingest)` for dense-layout carries (context re-fed),
    /// `Some(Blocks(..))` for paged-layout carries (block-table remap)
    pub carried_kv: Option<CarriedKv>,
    /// workload class tag (0 = default) — rides into the slot so ragged
    /// policies can key per-row speculation on it
    pub class: u8,
}

impl AdmitRequest {
    /// A fresh (never-served) admission.
    pub fn fresh(context: Vec<i32>, prompt_len: usize, max_new: usize) -> AdmitRequest {
        AdmitRequest {
            context,
            prompt_len,
            max_new,
            carried_kv: None,
            class: 0,
        }
    }

    /// Same admission tagged with a workload class.
    pub fn with_class(mut self, class: u8) -> AdmitRequest {
        self.class = class;
        self
    }
}

/// A finished row returned by [`Engine::retire_finished`].
#[derive(Debug, Clone)]
pub struct RetiredRow {
    pub slot: usize,
    /// generated tokens, truncated at `max_new` / first `<eos>`
    pub tokens: Vec<i32>,
}

/// The engine's per-model KV block pools (paged layout only).  The
/// engine is the allocator — pools outlive any single [`BatchState`], so
/// carried block chains survive an epoch reshape by refcount alone.
struct KvPools {
    llm: BlockManager,
    ssm: BlockManager,
}

fn build_pools(limits: &EngineLimits, layout: KvLayout) -> Option<KvPools> {
    if layout != KvLayout::Paged {
        return None;
    }
    let max_bucket = limits.batch_buckets.last().copied().unwrap_or(1).max(1);
    let per_row = limits.max_seq.div_ceil(DEFAULT_BLOCK_SIZE).max(1);
    // x4 headroom: carried chains briefly coexist with the reshaped
    // epoch's fresh tables, and tests drive several states per engine
    let capacity = max_bucket * per_row * 4;
    Some(KvPools {
        llm: BlockManager::new(capacity, DEFAULT_BLOCK_SIZE),
        ssm: BlockManager::new(capacity, DEFAULT_BLOCK_SIZE),
    })
}

/// Reusable hot-path buffers owned by the engine: every per-round vector
/// the decode loop needs, grown once to its high-water mark and reused
/// across rounds and epochs, so steady-state `decode_round` performs
/// zero heap allocations (pinned by `rust/tests/zero_alloc.rs` with a
/// counting global allocator).
#[derive(Debug, Default)]
struct RoundScratch {
    /// verify feed `[B, s+1]` (also the admission ingest feed)
    feed: Vec<i32>,
    /// SSM delta tokens `[B, 2]` + per-row delta lengths
    delta: Vec<i32>,
    dlens: Vec<i32>,
    /// per-row clamp targets (`committed - 1`)
    clamp: Vec<u32>,
    /// LLM predictions / SSM drafts
    pred: Vec<i32>,
    draft: Vec<i32>,
    /// flat acceptance output: commit tokens `[B, s+1]` + per-row lengths
    commit: Vec<i32>,
    commit_len: Vec<u32>,
    /// per-real-row accepted counts of the current round; telemetry and
    /// the policy feedback share it (`mem::take` round-trip, no clone)
    accepted: Vec<u32>,
    /// admission ingest: post-call clamp targets + ingest-counter snapshot
    desired: Vec<u32>,
    ing: Vec<u32>,
    /// ragged-round arenas: live-row classes in slot order (the policy's
    /// ragged view, lent to the feedback when non-trivial), the per-live-
    /// row choice, its per-slot expansion (frozen/vacant lanes ride at
    /// the executed s), and the u32 copy telemetry/feedback carry on
    /// non-uniform rounds (empty = uniform)
    classes: Vec<u8>,
    s_choice: Vec<usize>,
    s_slot: Vec<usize>,
    s_rows: Vec<u32>,
}

/// The batched speculative decoding engine.
pub struct Engine<'rt> {
    pub cfg: EngineConfig,
    limits: EngineLimits,
    llm: ModelHandle<'rt>,
    ssm: ModelHandle<'rt>,
    /// per-section timing for the §Perf pass
    pub stopwatch: Stopwatch,
    /// round-scratch arenas (see [`RoundScratch`])
    scratch: RoundScratch,
    /// observability handle (disabled by default: every emit below is a
    /// single `Option` branch, keeping the hot path allocation-free)
    tel: Telemetry,
    /// (epoch, queued) the serving loop reports for telemetry round
    /// spans — two plain stores per round, nothing when disabled
    round_ctx: (usize, usize),
    /// policy drift flushes already reported to the flight recorder
    drift_seen: usize,
    /// paged-layout block pools (None under the dense layout)
    pools: Option<KvPools>,
    /// prefix-sharing index over the LLM block pool (None unless
    /// `cfg.prefix_cache` under the paged layout)
    prefix: Option<PrefixCache>,
    #[cfg(feature = "pjrt")]
    rt: Option<&'rt Runtime>,
}

impl<'rt> Engine<'rt> {
    /// Engine over the real PJRT runtime (requires `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Result<Engine<'rt>> {
        if cfg.kv_layout == KvLayout::Paged {
            bail!(
                "the paged KV layout is stub-only for now: PJRT KV caches \
                 are dense per-row device buffers, so a block-table remap \
                 would transfer counters without the cached keys/values \
                 (run with --kv-layout dense, or the stub backend)"
            );
        }
        Ok(Engine {
            cfg,
            limits: EngineLimits::from_manifest(&rt.manifest)?,
            llm: ModelHandle::Pjrt(crate::model::Model::new(rt, "llm")?),
            ssm: ModelHandle::Pjrt(crate::model::Model::new(rt, "ssm")?),
            stopwatch: Stopwatch::new(),
            scratch: RoundScratch::default(),
            tel: Telemetry::disabled(),
            round_ctx: (0, 0),
            drift_seen: 0,
            pools: None,
            prefix: None,
            rt: Some(rt),
        })
    }

    /// Engine over the deterministic stub model pair — no artifacts, no
    /// PJRT; used by the default test/CI path and the stub server mode.
    pub fn stub(spec: StubSpec, cfg: EngineConfig) -> Result<Engine<'static>> {
        if spec.vocab <= 4 {
            bail!("stub vocab must exceed the 4 reserved specials");
        }
        if spec.batch_buckets.is_empty() {
            bail!("stub needs at least one batch bucket");
        }
        if spec.max_prompt == 0 || spec.max_seq <= spec.max_prompt {
            bail!("stub needs 0 < max_prompt < max_seq");
        }
        let limits = EngineLimits::from_stub(&spec);
        let pools = build_pools(&limits, cfg.kv_layout);
        // the prefix index shares blocks through the LLM pool, so it
        // exists only where the pool does (paged layout)
        let prefix = (cfg.prefix_cache && pools.is_some())
            .then(|| PrefixCache::new(DEFAULT_BLOCK_SIZE));
        Ok(Engine {
            cfg,
            limits,
            llm: ModelHandle::stub(StubModel::new(spec.clone(), StubRole::Llm)),
            ssm: ModelHandle::stub(StubModel::new(spec, StubRole::Ssm)),
            stopwatch: Stopwatch::new(),
            scratch: RoundScratch::default(),
            tel: Telemetry::disabled(),
            round_ctx: (0, 0),
            drift_seen: 0,
            pools,
            prefix,
            #[cfg(feature = "pjrt")]
            rt: None,
        })
    }

    pub fn limits(&self) -> &EngineLimits {
        &self.limits
    }

    /// Install an observability handle (see [`crate::telemetry`]).  The
    /// default is the disabled handle, under which every emission in the
    /// decode loop is a single branch.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.tel = tel;
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Report the (epoch, queued) context telemetry round spans carry.
    /// Called by the serving loop driving this engine; two plain `usize`
    /// stores, free whether or not telemetry is on.
    pub fn set_round_context(&mut self, epoch: usize, queued: usize) {
        self.round_ctx = (epoch, queued);
    }

    /// The KV layout this engine runs (see [`crate::kvcache`]).
    pub fn kv_layout(&self) -> KvLayout {
        self.cfg.kv_layout
    }

    /// Block-pool accounting snapshot, LLM + SSM pools merged (None under
    /// the dense layout).  At a clean shutdown `is_leak_free()` holds —
    /// the invariant the leak tests pin.
    pub fn kv_block_stats(&self) -> Option<KvBlockStats> {
        self.pools
            .as_ref()
            .map(|p| p.llm.stats().merged(&p.ssm.stats()))
    }

    /// Cumulative prefix-sharing counters (None when the prefix cache is
    /// off or the layout is dense).
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|c| c.stats())
    }

    /// True when admissions consult the prefix index.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Return every cached prefix chain to the pool (shutdown / leak
    /// audit: after this plus releasing all batch states, the free list
    /// is back at capacity).  No-op when the cache is off.
    pub fn clear_prefix_cache(&mut self) {
        if let (Some(cache), Some(pools)) = (self.prefix.as_mut(), self.pools.as_mut()) {
            cache.evict_all(&mut pools.llm);
        }
    }

    /// Longest cached prefix of `tokens`: a retained block chain ready
    /// to install read-only into a slot's table, with a partially filled
    /// shared tail already replaced copy-on-write (the caller's suffix
    /// ingest writes into that block immediately).  None on a miss or
    /// when the cache is off.
    fn map_prefix(&mut self, tokens: &[i32]) -> Result<Option<(Vec<u32>, usize)>> {
        let (Some(cache), Some(pools)) = (self.prefix.as_mut(), self.pools.as_mut()) else {
            return Ok(None);
        };
        if tokens.is_empty() {
            return Ok(None);
        }
        let Some(mut m) = cache.lookup(tokens, &mut pools.llm) else {
            return Ok(None);
        };
        if m.tokens % DEFAULT_BLOCK_SIZE != 0 {
            let tail = *m.blocks.last().expect("a partial tail implies a block");
            match cache.cow_tail(&mut pools.llm, tail) {
                Ok(fresh) => {
                    *m.blocks.last_mut().expect("a partial tail implies a block") = fresh;
                }
                Err(e) => {
                    for &b in &m.blocks {
                        pools.llm.release(b);
                    }
                    return Err(e);
                }
            }
        }
        Ok(Some((m.blocks, m.tokens)))
    }

    /// Register freshly ingested prompt spans into the prefix index: for
    /// each slot, the prompt prefix its KV actually covers (the ingest
    /// counter, capped at the prompt length).  Spans already cached are
    /// deduplicated inside the trie.  No-op when the cache is off.
    fn insert_prefixes(&mut self, st: &BatchState, slots: &[usize]) {
        let (Some(cache), Some(pools), Some(tables)) = (
            self.prefix.as_mut(),
            self.pools.as_mut(),
            st.tables.as_ref(),
        ) else {
            return;
        };
        let ing = st.llm_kv.ingested();
        for &i in slots {
            let span = (st.rows.prompt_len[i] as usize).min(ing[i] as usize);
            if span == 0 {
                continue;
            }
            cache.insert(
                &st.rows.committed(i)[..span],
                tables.llm.row(i),
                &mut pools.llm,
            );
        }
    }

    /// Precompile the executable matrix up to (`max_bucket`, `max_s`).
    /// No-op (0 executables) on the stub backend.
    pub fn warmup(&mut self, max_bucket: usize, max_s: usize) -> Result<usize> {
        #[cfg(feature = "pjrt")]
        if let Some(rt) = self.rt {
            return rt.warmup(max_bucket, max_s);
        }
        let _ = (max_bucket, max_s);
        Ok(0)
    }

    /// Generate up to `max_new` tokens for every prompt, as one
    /// batch-to-completion epoch (the paper's static-batching setting).
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        policy: &mut dyn SpeculationPolicy,
    ) -> Result<GenOutput> {
        let t_start = Instant::now();
        let n = prompts.len();
        if n == 0 {
            bail!("generate_batch: empty prompt list");
        }
        let bucket = self.limits.bucket_for(n)?;
        let may_speculate = policy.wants_speculation();
        let mut st = self.prefill_rows(prompts, bucket, may_speculate, max_new)?;

        let decode_start = Instant::now();
        while st.has_live() {
            self.decode_round(&mut st, policy)?;
            // hard safety net: a stuck batch must not loop forever
            if st.stats.rounds > 4 * (max_new + 2) {
                bail!("decode loop exceeded round budget — state machine bug");
            }
        }
        st.stats.decode_wall = decode_start.elapsed();
        // the epoch is over: return its blocks to the pools
        self.release_state(&mut st);

        // --- collect outputs ---
        let mut tokens = Vec::with_capacity(n);
        for i in 0..n {
            let gen = st.rows.gen_tokens(i);
            let mut out: Vec<i32> = Vec::with_capacity(max_new.min(gen.len()));
            for &t in gen.iter().take(max_new) {
                out.push(t);
                if self.cfg.stop_at_eos && t == self.cfg.eos_token {
                    break;
                }
            }
            st.stats.useful_tokens += out.len();
            tokens.push(out);
        }
        st.stats.wall = t_start.elapsed();
        Ok(GenOutput {
            tokens,
            stats: st.stats,
        })
    }

    /// Batch-prefill `prompts` into a fresh [`BatchState`] at `bucket`
    /// (prompts occupy slots `0..prompts.len()`, the rest start vacant).
    /// Commits each row's first generated token.
    pub fn prefill_rows(
        &mut self,
        prompts: &[Vec<i32>],
        bucket: usize,
        may_speculate: bool,
        max_new: usize,
    ) -> Result<BatchState> {
        if prompts.is_empty() {
            bail!("prefill_rows: empty prompt list");
        }
        if !self.limits.batch_buckets.contains(&bucket) {
            bail!(
                "prefill_rows: {bucket} is not a compiled batch bucket ({:?})",
                self.limits.batch_buckets
            );
        }
        if prompts.len() > bucket {
            bail!(
                "prefill_rows: {} prompts exceed bucket {bucket}",
                prompts.len()
            );
        }
        let max_prompt = self.limits.max_prompt;
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() > max_prompt {
                bail!(
                    "prompt {i} length {} out of range 1..={max_prompt}",
                    p.len()
                );
            }
        }
        let may_speculate = may_speculate && self.limits.max_spec_len(bucket) > 0;

        // --- assemble rows (real + vacant padding), SoA layout ---
        // stride covers committed <= max_seq + 1 (see RowSoa docs)
        let stride = self.limits.max_seq + 2;
        let mut rows = RowSoa::new(bucket, stride, self.cfg.bos_token);
        for (i, p) in prompts.iter().enumerate() {
            rows.install(i, p, p.len(), max_new);
        }

        // --- prefix-cache map (paged + cache on): the longest cached
        // prefix of each prompt rides in as a read-only block chain and
        // the prefill below feeds only the suffix.  The lookup is capped
        // at plen-1 so at least one token remains to feed (its last-token
        // prediction is the row's first committed token either way).
        let mut mapped: Vec<usize> = vec![0; bucket];
        let mut chains: Vec<Option<Vec<u32>>> = (0..bucket).map(|_| None).collect();
        if self.prefix.is_some() {
            for (i, p) in prompts.iter().enumerate() {
                if p.len() < 2 {
                    continue;
                }
                if let Some((chain, m)) = self.map_prefix(&p[..p.len() - 1])? {
                    mapped[i] = m;
                    chains[i] = Some(chain);
                }
            }
        }

        // --- padded prefill over both models (mapped rows: suffix only) ---
        let mut tokens = vec![self.cfg.pad_token; bucket * max_prompt];
        let mut plens = vec![0i32; bucket];
        for i in 0..bucket {
            let plen = rows.prompt_len[i] as usize;
            let skip = mapped[i];
            let feed_len = plen - skip;
            tokens[i * max_prompt..i * max_prompt + feed_len]
                .copy_from_slice(&rows.committed(i)[skip..plen]);
            plens[i] = feed_len as i32;
        }
        let tel_mark = self
            .tel
            .enabled()
            .then(|| self.tel.now());
        let mut llm_kv = self.llm.new_kv(bucket)?;
        let first = self.stopwatch.time("prefill_llm", || {
            self.llm.prefill(&tokens, &plens, bucket, &mut llm_kv)
        })?;
        let mut ssm_kv = if may_speculate {
            let mut kv = self.ssm.new_kv(bucket)?;
            // the SSM's own first prediction is discarded — it only needs KV
            let _ = self.stopwatch.time("prefill_ssm", || {
                self.ssm.prefill(&tokens, &plens, bucket, &mut kv)
            })?;
            Some(kv)
        } else {
            None
        };

        if let Some(t0) = tel_mark {
            self.tel.phase(t0, self.tel.now() - t0, PhaseKind::Prefill);
        }
        // commit the prefill token
        for (i, &t) in first.iter().enumerate() {
            rows.push(i, t);
        }
        // mapped rows: the suffix prefill left the LLM counter at the
        // suffix length — the mapped chain covers the rest, so the full
        // prompt is ingested.  No draft-side blocks are cached: rewind
        // the SSM to zero and let the catch-up pass rebuild it.
        let mut any_mapped = false;
        for (i, &m) in mapped.iter().enumerate() {
            if m == 0 {
                continue;
            }
            any_mapped = true;
            llm_kv.set_row_ingested(i, rows.prompt_len[i]);
            if let Some(kv) = ssm_kv.as_mut() {
                kv.set_row_ingested(i, 0);
            }
        }
        let table_stride = self.limits.max_seq.div_ceil(DEFAULT_BLOCK_SIZE).max(1);
        let mut tables = self.pools.as_ref().map(|_| SlotTables {
            llm: FlatTables::new(bucket, table_stride),
            ssm: FlatTables::new(bucket, table_stride),
        });
        // install the mapped chains before the sync below grows each
        // table to its counter (the chains transfer their references)
        if let Some(t) = tables.as_mut() {
            for (i, chain) in chains.iter().enumerate() {
                if let Some(chain) = chain {
                    t.llm.set_row(i, chain);
                }
            }
        }
        let mut stats = GenStats::default();
        // pre-size the per-epoch sample vectors to the decode loop's
        // round budget so steady-state pushes never reallocate (the
        // zero-alloc invariant); continuous-batching epochs outliving
        // the budget fall back to amortized growth
        let round_budget = 4 * (max_new + 2) + 1;
        stats.spec_lens.reserve(round_budget);
        stats.per_round.reserve(round_budget);
        stats.accept_samples.reserve(round_budget * bucket);
        let mut st = BatchState {
            bucket,
            may_speculate,
            rows,
            llm_kv,
            ssm_kv,
            // mapped rows rewound their SSM counters: catch up lazily
            ssm_backlog: any_mapped,
            tables,
            stats,
        };
        self.check_eos_and_limits(&mut st.rows);
        self.sync_blocks(&mut st)?;
        if self.prefix.is_some() {
            let fresh: Vec<usize> = (0..prompts.len()).collect();
            self.insert_prefixes(&st, &fresh);
        }
        Ok(st)
    }

    /// Run ONE decode round: query the policy with the live rows' class
    /// tags (per-row ragged choice; uniform policies broadcast), then a
    /// plain verify round (all s_i = 0) or a speculate/verify/accept
    /// round executed at the widest choice `s = max s_i` — rows with a
    /// smaller s_i commit a truncated prefix (padded verify).  Freezes
    /// rows that hit `<eos>` / their budget and feeds the round's
    /// outcome back to the policy ([`SpeculationPolicy::observe`]).
    pub fn decode_round(
        &mut self,
        st: &mut BatchState,
        policy: &mut dyn SpeculationPolicy,
    ) -> Result<RoundInfo> {
        let live = st.live_rows();
        if live == 0 {
            bail!("decode_round: no live rows in the batch");
        }
        let max_s = self.limits.max_spec_len(st.bucket);
        // gather the live rows' class tags in slot order — the policy's
        // per-row view.  Uniform policies broadcast their scalar choice
        // over it (the default `choose_ragged_into`), so this round is
        // bit-identical to the scalar path for them.
        self.scratch.classes.clear();
        for i in 0..st.rows.n() {
            if st.rows.is_live(i) {
                self.scratch.classes.push(st.rows.class[i]);
            }
        }
        let s = if st.may_speculate {
            let RoundScratch {
                classes, s_choice, ..
            } = &mut self.scratch;
            policy.choose_ragged_into(classes, max_s, s_choice);
            debug_assert_eq!(s_choice.len(), live);
            s_choice.iter().copied().max().unwrap_or(0)
        } else {
            self.scratch.s_choice.clear();
            self.scratch.s_choice.resize(live, 0);
            0
        };
        // the round executes at the widest per-row choice (the verify
        // call pads every lane to s); rows that asked for less commit a
        // truncated prefix — their surplus lanes are intra-row padding
        let ragged = s > 0 && self.scratch.s_choice.iter().any(|&si| si != s);
        self.scratch.s_slot.clear();
        self.scratch.s_slot.resize(st.rows.n(), s);
        self.scratch.s_rows.clear();
        if ragged {
            let RoundScratch {
                s_choice,
                s_slot,
                s_rows,
                ..
            } = &mut self.scratch;
            let mut li = 0usize;
            for i in 0..st.rows.n() {
                if st.rows.is_live(i) {
                    s_slot[i] = s_choice[li];
                    li += 1;
                }
            }
            s_rows.extend(s_choice.iter().map(|&si| si as u32));
        }
        let drafted: usize = if s == 0 {
            0
        } else {
            self.scratch.s_choice.iter().sum()
        };
        let before = st.rows.committed_total();
        self.scratch.accepted.clear();
        st.stats.spec_lens.push(s);
        st.stats.rounds += 1;

        // the phase breakdown is *derived* from the stopwatch sections
        // the round body already times (no double-timing): the section
        // totals captured here, diffed after the round, are this
        // round's catch-up/draft/verify shares.  Captured every round
        // (read-only map lookups) so `RoundInfo::phases` feeds request
        // waterfalls even with telemetry off; the event timestamp is
        // only taken when some sink is attached ([`Telemetry::active`]
        // covers the always-on flight recorder too).
        let tel_mark = self.tel.active().then(|| self.tel.now());
        let catch0 = self.stopwatch.total("ssm_catch_up");
        let draft0 = self.stopwatch.total("speculate");
        let verify0 = self.stopwatch.total("verify");
        // two clocks: `wall_start` covers the whole round (the timeline's
        // accounting truth), `fit_start` begins AFTER the SSM catch-up
        // pass — backlog drain is bookkeeping for earlier plain rounds /
        // admissions, and billing it to this (s, time) point would bias
        // the policy's per-s round-cost fit
        let wall_start = Instant::now();
        let fit_start: Instant;
        {
            let BatchState {
                bucket,
                rows,
                llm_kv,
                ssm_kv,
                ssm_backlog,
                stats,
                ..
            } = st;
            if s == 0 {
                fit_start = wall_start;
                self.round_plain(rows, *bucket, llm_kv, stats)?;
                *ssm_backlog = true;
            } else {
                let ssm_kv = ssm_kv.as_mut().expect("speculating epoch owns an SSM KV");
                if *ssm_backlog {
                    self.ssm_catch_up(rows, *bucket, ssm_kv, stats)?;
                    *ssm_backlog = false;
                }
                fit_start = Instant::now();
                self.round_speculative(rows, *bucket, s, llm_kv, ssm_kv, stats)?;
            }
        }
        // wall-clock throttle: pin the service rate for reproducible
        // deadline experiments on the µs-fast stub (no-op by default)
        if self.cfg.min_round_seconds > 0.0 {
            let spent = wall_start.elapsed().as_secs_f64();
            if spent < self.cfg.min_round_seconds {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    self.cfg.min_round_seconds - spent,
                ));
            }
        }
        let fit_time = fit_start.elapsed().as_secs_f64();
        let wall_time = wall_start.elapsed().as_secs_f64();
        self.check_eos_and_limits(&mut st.rows);
        self.sync_blocks(st)?;
        let committed = st.rows.committed_total() - before;
        let catch = (self.stopwatch.total("ssm_catch_up") - catch0).as_secs_f64();
        let draft = (self.stopwatch.total("speculate") - draft0).as_secs_f64();
        let verify = (self.stopwatch.total("verify") - verify0).as_secs_f64();
        // the host-side accept/commit share is the round's remainder,
        // so the four phases exactly tile the round's wall time
        let phases = RoundPhases {
            catch_up: catch,
            draft,
            verify,
            accept: (wall_time - (catch + draft + verify)).max(0.0),
        };
        if let Some(t0) = tel_mark {
            self.tel.round(
                t0,
                wall_time,
                self.round_ctx.0,
                live,
                st.bucket,
                self.round_ctx.1,
                s,
                committed,
                &self.scratch.accepted,
                &self.scratch.s_rows,
                st.kv_blocks_in_use(),
            );
            // phases laid out back-to-back in execution order
            let mut t = t0;
            for (dur, phase) in [
                (catch, PhaseKind::CatchUp),
                (draft, PhaseKind::Draft),
                (verify, PhaseKind::Verify),
            ] {
                if dur > 0.0 {
                    self.tel.phase(t, dur, phase);
                    t += dur;
                }
            }
            self.tel.phase(t, phases.accept, PhaseKind::Accept);
            if let Some(kv) = self.kv_block_stats() {
                let ps = self.prefix_stats().unwrap_or_default();
                self.tel.kv_pool_prefix(
                    t0 + wall_time,
                    kv.in_use,
                    kv.capacity,
                    kv.mean_internal_frag,
                    ps.prefix_hits,
                    ps.prefill_tokens_saved,
                );
            }
        }
        let info = RoundInfo {
            live,
            width: st.bucket,
            s,
            committed,
            drafted,
            accepted: self.scratch.accepted.iter().map(|&a| a as usize).sum(),
            round_time: wall_time,
            phases,
        };
        st.stats.per_round.push(info);
        // lend the accepted/s_rows/classes buffers to the feedback (no
        // clone), then take them back so the next round reuses their
        // capacity.  `classes` travels only when some live row is tagged
        // — a classless round observes exactly as it did before.
        let classed = self.scratch.classes.iter().any(|&c| c != 0);
        let fb = RoundFeedback {
            live,
            // the round executed at the padded bucket width, which is
            // what its cost scales with
            width: st.bucket,
            s,
            accepted: std::mem::take(&mut self.scratch.accepted),
            s_rows: std::mem::take(&mut self.scratch.s_rows),
            classes: if classed {
                std::mem::take(&mut self.scratch.classes)
            } else {
                Vec::new()
            },
            committed,
            round_time: fit_time,
        };
        policy.observe(&fb);
        self.scratch.accepted = fb.accepted;
        self.scratch.s_rows = fb.s_rows;
        if classed {
            self.scratch.classes = fb.classes;
        }
        // a CUSUM flush is exactly the moment the operator wants the
        // surrounding rounds for — arm a flight dump (plain compare
        // when the policy has no detector)
        let flushes = policy.drift_flushes();
        if flushes > self.drift_seen {
            self.drift_seen = flushes;
            self.tel.drift_flush(self.tel.now());
        }
        Ok(info)
    }

    /// Admit queued requests into vacant slots at a round boundary.
    ///
    /// Fresh and dense-carried contexts are ingested into the LLM KV via
    /// chunked verify calls (frozen/live rows re-feed their last token
    /// and are clamped back); the SSM catches up lazily before the next
    /// speculative round.  Paged-carried rows ([`CarriedKv::Blocks`])
    /// skip ingestion entirely: their block chains are installed into the
    /// slot's tables and the ingest counters transferred — the reshape-
    /// as-remap path.  Returns the slot indices, in request order.
    pub fn admit_rows(
        &mut self,
        st: &mut BatchState,
        reqs: Vec<AdmitRequest>,
    ) -> Result<Vec<usize>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let vacant: Vec<usize> = (0..st.rows.n()).filter(|&i| !st.rows.real[i]).collect();
        if reqs.len() > vacant.len() {
            bail!(
                "admit_rows: {} requests for {} free slots",
                reqs.len(),
                vacant.len()
            );
        }
        let mut slots = Vec::with_capacity(reqs.len());
        // fresh admissions that should register their prompt span in the
        // prefix index once their context is ingested (cache on only)
        let mut fresh: Vec<usize> = Vec::new();
        for (req, &slot) in reqs.into_iter().zip(vacant.iter()) {
            if req.context.is_empty() {
                bail!("admit_rows: empty context");
            }
            if req.prompt_len == 0 || req.prompt_len > req.context.len() {
                bail!(
                    "admit_rows: prompt_len {} out of range for a context of {}",
                    req.prompt_len,
                    req.context.len()
                );
            }
            if req.context.len() + 1 > self.limits.max_seq {
                bail!(
                    "admit_rows: context of {} tokens exceeds the KV capacity {}",
                    req.context.len(),
                    self.limits.max_seq
                );
            }
            let ctx_len = req.context.len();
            st.rows.install(slot, &req.context, req.prompt_len, req.max_new);
            st.rows.class[slot] = req.class;
            match req.carried_kv {
                Some(CarriedKv::Blocks(handle)) => {
                    self.remap_slot(st, slot, ctx_len, handle)?;
                }
                Some(CarriedKv::Reingest) => {
                    // dense carry: the whole generated-so-far context goes
                    // back through verify calls (the reshape wall the
                    // paged layout removes)
                    st.stats.reingested_tokens += ctx_len - 1;
                    st.llm_kv.reset_row(slot);
                    if let Some(kv) = &mut st.ssm_kv {
                        kv.reset_row(slot);
                    }
                }
                None => {
                    st.llm_kv.reset_row(slot);
                    if let Some(kv) = &mut st.ssm_kv {
                        kv.reset_row(slot);
                    }
                    if self.prefix.is_some() {
                        // prefix lookup at admit time: the longest cached
                        // prefix of the prompt installs as a read-only
                        // chain + counter transfer, and the chunked
                        // ingest below feeds only the suffix (capped at
                        // ctx-1 so one token is always left to feed)
                        let cap = req.prompt_len.min(ctx_len - 1);
                        if cap > 0 {
                            if let Some((chain, m)) = self.map_prefix(&req.context[..cap])? {
                                let tables = st
                                    .tables
                                    .as_mut()
                                    .expect("prefix cache implies paged tables");
                                tables.llm.set_row(slot, &chain);
                                st.llm_kv.set_row_ingested(slot, m as u32);
                            }
                        }
                        fresh.push(slot);
                    }
                }
            }
            slots.push(slot);
        }
        let tel_mark = self.tel.enabled().then(|| self.tel.now());
        self.ingest_admitted(st)?;
        if let Some(t0) = tel_mark {
            // admission-time context ingest (fresh prompts + any dense
            // carry re-ingest) — the cost the paged remap avoids
            self.tel.phase(t0, self.tel.now() - t0, PhaseKind::Reshape);
        }
        // freshly admitted rows put the SSM behind by a whole context
        // (remapped rows keep their counters; the catch-up pass no-ops
        // for any row that is already within the delta invariant)
        st.ssm_backlog = true;
        // a re-admitted context may already contain <eos> past the prompt
        self.check_eos_and_limits(&mut st.rows);
        self.sync_blocks(st)?;
        // fresh prompts now have their KV in place: register their spans
        // so later admissions can share them
        self.insert_prefixes(st, &fresh);
        Ok(slots)
    }

    /// Install a carried row's block chains + ingest counters into `slot`
    /// — the O(1) reshape remap.  Consumes the handle's block references.
    fn remap_slot(
        &mut self,
        st: &mut BatchState,
        slot: usize,
        ctx_len: usize,
        handle: KvHandle,
    ) -> Result<()> {
        let (Some(pools), Some(tables)) = (self.pools.as_mut(), st.tables.as_mut()) else {
            bail!("admit_rows: a block-table handle reached a dense-layout engine");
        };
        if handle.llm.ingested as usize != ctx_len - 1 {
            bail!(
                "admit_rows: carried KV covers {} tokens for a context of {ctx_len}",
                handle.llm.ingested
            );
        }
        // swap the chains in, releasing whatever the vacant slot held —
        // a span rewrite in the flat tables, no per-slot Vec churn
        for &id in tables.llm.row(slot) {
            pools.llm.release(id);
        }
        tables.llm.set_row(slot, &handle.llm.blocks);
        st.llm_kv.set_row_ingested(slot, handle.llm.ingested);
        st.stats.remapped_tokens += handle.llm.ingested as usize;
        for &id in tables.ssm.row(slot) {
            pools.ssm.release(id);
        }
        tables.ssm.set_row(slot, &[]);
        match (st.ssm_kv.as_mut(), handle.ssm) {
            (Some(kv), Some(chain)) => {
                tables.ssm.set_row(slot, &chain.blocks);
                kv.set_row_ingested(slot, chain.ingested);
            }
            (Some(kv), None) => kv.set_row_ingested(slot, 0),
            (None, Some(chain)) => {
                // epoch without an SSM: drop the carried draft-side chain
                for id in chain.blocks {
                    pools.ssm.release(id);
                }
            }
            (None, None) => {}
        }
        Ok(())
    }

    /// Collect finished rows and turn their slots vacant (KV counters
    /// reset) so the batcher can refill them.  Returns the retired rows'
    /// generated tokens.
    pub fn retire_finished(&mut self, st: &mut BatchState) -> Vec<RetiredRow> {
        let mut retired = Vec::new();
        for i in 0..st.rows.n() {
            if !(st.rows.real[i] && st.rows.finished[i]) {
                continue;
            }
            let gen = st.rows.gen_tokens(i);
            let max_new = st.rows.max_new[i] as usize;
            let mut tokens: Vec<i32> = Vec::with_capacity(max_new.min(gen.len()));
            for &t in gen.iter().take(max_new) {
                tokens.push(t);
                if self.cfg.stop_at_eos && t == self.cfg.eos_token {
                    break;
                }
            }
            st.stats.useful_tokens += tokens.len();
            retired.push(RetiredRow { slot: i, tokens });
            st.rows.set_vacant(i, self.cfg.bos_token);
            st.llm_kv.reset_row(i);
            if let Some(kv) = &mut st.ssm_kv {
                kv.reset_row(i);
            }
        }
        // retirement only rolls counters to zero, so the sync can only
        // shrink tables (return blocks) — allocation cannot fail here
        self.sync_blocks(st)
            .expect("retirement only returns blocks to the pool");
        retired
    }

    /// Export the unfinished rows of an epoch as re-admittable requests
    /// (used by the batcher to reshape an epoch into a larger bucket).
    ///
    /// Under the dense layout the requests carry [`CarriedKv::Reingest`]:
    /// re-admission feeds each context back through chunked verify calls.
    /// Under the paged layout they carry [`CarriedKv::Blocks`] — cloned,
    /// ref-retained block chains plus the ingest counters — so
    /// re-admission is a block-table remap with zero token re-ingestion.
    /// Call [`Engine::release_state`] on the old state afterwards; the
    /// retained references keep the carried chains alive in between.
    ///
    /// Writes into `out` (cleared first) so a reshaping caller can reuse
    /// one buffer across epochs instead of receiving a fresh `Vec` each
    /// time; the caller drains it.  The requests themselves own their
    /// contexts/chains — that is the carried state, not churn.
    pub fn export_rows(&mut self, st: &BatchState, out: &mut Vec<(usize, AdmitRequest)>) {
        out.clear();
        let llm_ing = st.llm_kv.ingested();
        for i in 0..st.rows.n() {
            if !st.rows.is_live(i) {
                continue;
            }
            let carried_kv = match (self.pools.as_mut(), st.tables.as_ref()) {
                (Some(pools), Some(tables)) => {
                    let llm = BlockChain {
                        blocks: tables.llm.row(i).to_vec(),
                        ingested: llm_ing[i],
                    };
                    for &id in &llm.blocks {
                        pools.llm.retain(id);
                    }
                    let ssm = st.ssm_kv.as_ref().map(|kv| {
                        let chain = BlockChain {
                            blocks: tables.ssm.row(i).to_vec(),
                            ingested: kv.ingested()[i],
                        };
                        for &id in &chain.blocks {
                            pools.ssm.retain(id);
                        }
                        chain
                    });
                    CarriedKv::Blocks(KvHandle { llm, ssm })
                }
                _ => CarriedKv::Reingest,
            };
            out.push((
                i,
                AdmitRequest {
                    context: st.rows.committed(i).to_vec(),
                    prompt_len: st.rows.prompt_len[i] as usize,
                    max_new: st.rows.max_new[i] as usize,
                    carried_kv: Some(carried_kv),
                    class: st.rows.class[i],
                },
            ));
        }
    }

    /// Return every block a state still holds to the pools (end of the
    /// epoch's life: reshape hand-off, drained batcher epoch, or the end
    /// of a `generate_batch` call).  No-op under the dense layout.
    pub fn release_state(&mut self, st: &mut BatchState) {
        let (Some(pools), Some(tables)) = (self.pools.as_mut(), st.tables.as_mut()) else {
            return;
        };
        pools.llm.release_flat(&mut tables.llm);
        pools.ssm.release_flat(&mut tables.ssm);
    }

    /// Bring every slot's block tables in line with its KV ingest
    /// counters (grow = alloc, shrink = release).  The paged layout's
    /// single accounting point, called after every state-mutating step.
    fn sync_blocks(&mut self, st: &mut BatchState) -> Result<()> {
        let (Some(pools), Some(tables)) = (self.pools.as_mut(), st.tables.as_mut()) else {
            return Ok(());
        };
        // LLM pool pressure is the one reclamation trigger for cached
        // prefix chains: evict LRU entries and retry (sync_flat commits
        // partial growth before erroring, so the retry is exact)
        loop {
            match pools.llm.sync_flat(&mut tables.llm, st.llm_kv.ingested()) {
                Ok(()) => break,
                Err(e) => {
                    let evicted = self
                        .prefix
                        .as_mut()
                        .is_some_and(|c| c.evict_lru(&mut pools.llm));
                    if !evicted {
                        return Err(e);
                    }
                }
            }
        }
        if let Some(kv) = &st.ssm_kv {
            pools.ssm.sync_flat(&mut tables.ssm, kv.ingested())?;
        }
        Ok(())
    }

    /// Chunked LLM ingestion of admitted rows' contexts: repeated verify
    /// calls where pending rows feed their next context chunk and every
    /// other row re-feeds its last token (and is clamped back).
    fn ingest_admitted(&mut self, st: &mut BatchState) -> Result<()> {
        let bucket = st.bucket;
        let max_chunk = self.limits.max_verify_len(bucket) + 1;
        let cap = self.limits.max_seq;
        let pad = self.cfg.pad_token;
        let Engine {
            llm,
            stopwatch,
            scratch,
            ..
        } = self;
        let RoundScratch {
            feed,
            desired,
            ing,
            pred,
            ..
        } = scratch;
        let rows = &st.rows;
        loop {
            ing.clear();
            ing.extend_from_slice(st.llm_kv.ingested());
            let is_pending =
                |i: usize| rows.is_live(i) && (ing[i] as usize) < rows.len[i] as usize - 1;
            if !(0..bucket).any(is_pending) {
                return Ok(());
            }
            // the verify capacity check uses the max counter over ALL rows
            // (non-pending counters are clamped straight back, but only
            // after the call), so shrink the chunk when any row sits near
            // the KV capacity — verify spans 1..=max_chunk are all
            // compiled, shorter chunks just cost extra passes
            let max_ing = ing.iter().copied().max().unwrap_or(0) as usize;
            if max_ing + 1 > cap {
                bail!(
                    "admit_rows: KV capacity {cap} exhausted (a row has \
                     ingested {max_ing}) — cannot ingest new contexts"
                );
            }
            let chunk = max_chunk.min(cap - max_ing);
            feed.clear();
            feed.resize(bucket * chunk, pad);
            desired.clear();
            desired.resize(bucket, 0);
            for i in 0..bucket {
                let start = ing[i] as usize;
                if is_pending(i) {
                    let take = chunk.min(rows.len[i] as usize - 1 - start);
                    let piece = &rows.committed(i)[start..start + take];
                    for (j, slot) in feed[i * chunk..(i + 1) * chunk].iter_mut().enumerate() {
                        // pad the tail by repeating the last real token
                        *slot = piece[j.min(take - 1)];
                    }
                    desired[i] = (start + take) as u32;
                } else {
                    let last = rows.last(i);
                    for slot in feed[i * chunk..(i + 1) * chunk].iter_mut() {
                        *slot = last;
                    }
                    desired[i] = rows.len[i] - 1;
                }
            }
            let s = chunk - 1;
            stopwatch.time("ingest", || {
                llm.verify_into(feed, s, bucket, &mut st.llm_kv, pred)
            })?;
            st.stats.llm_calls += 1;
            st.llm_kv.clamp_to(desired);
        }
    }

    /// One plain decode round (s = 0): feed the last committed token.
    fn round_plain(
        &mut self,
        rows: &mut RowSoa,
        bucket: usize,
        llm_kv: &mut Kv,
        stats: &mut GenStats,
    ) -> Result<()> {
        let Engine {
            llm,
            stopwatch,
            scratch,
            ..
        } = self;
        let RoundScratch {
            feed, pred, clamp, ..
        } = scratch;
        feed.clear();
        feed.extend((0..bucket).map(|i| rows.last(i)));
        stopwatch.time("verify", || llm.verify_into(feed, 0, bucket, llm_kv, pred))?;
        stats.llm_calls += 1;
        for i in 0..bucket {
            if !rows.finished[i] {
                rows.push(i, pred[i]);
            }
        }
        clamp.clear();
        clamp.extend((0..bucket).map(|i| rows.len[i] - 1));
        llm_kv.clamp_to(clamp);
        Ok(())
    }

    /// One speculative round: SSM drafts s tokens, LLM verifies, host
    /// accepts (Algorithm 1).
    fn round_speculative(
        &mut self,
        rows: &mut RowSoa,
        bucket: usize,
        s: usize,
        llm_kv: &mut Kv,
        ssm_kv: &mut Kv,
        stats: &mut GenStats,
    ) -> Result<()> {
        let pad = self.cfg.pad_token;
        let Engine {
            llm,
            ssm,
            stopwatch,
            scratch,
            ..
        } = self;
        let RoundScratch {
            feed,
            delta,
            dlens,
            clamp,
            pred,
            draft,
            commit,
            commit_len,
            accepted,
            s_slot,
            ..
        } = scratch;

        // --- SSM: delta ingest + draft ---
        build_delta_into(pad, rows, ssm_kv, delta, dlens)?;
        stopwatch.time("speculate", || {
            ssm.speculate_into(delta, dlens, s, bucket, ssm_kv, draft)
        })?;
        stats.ssm_calls += 1;

        // --- LLM: verify ---
        feed.clear();
        feed.resize(bucket * (s + 1), 0);
        for i in 0..bucket {
            feed[i * (s + 1)] = rows.last(i);
            feed[i * (s + 1) + 1..(i + 1) * (s + 1)].copy_from_slice(&draft[i * s..(i + 1) * s]);
        }
        stopwatch.time("verify", || llm.verify_into(feed, s, bucket, llm_kv, pred))?;
        stats.llm_calls += 1;

        // --- host: acceptance + commit ---
        accept_into(draft, pred, bucket, s, commit, commit_len);
        for i in 0..bucket {
            if rows.finished[i] {
                continue;
            }
            // ragged truncation: a row that asked for s_i < s commits at
            // most its own s_i accepted drafts (+1 bonus/correction);
            // whatever the padded verify proved beyond that is intra-row
            // padding, never committed.  Uniform rounds have
            // s_slot[i] == s, so n == commit_len[i] — the old behaviour.
            let n = (commit_len[i] as usize).min(s_slot[i] + 1);
            rows.extend(i, &commit[i * (s + 1)..][..n]);
            stats.drafted += s_slot[i];
            stats.accepted += n - 1;
            if rows.real[i] {
                stats.accept_samples.push((n - 1) as u32);
                accepted.push((n - 1) as u32);
            }
        }

        // --- clamp both caches to committed-1 ---
        clamp.clear();
        clamp.extend((0..bucket).map(|i| rows.len[i] - 1));
        llm_kv.clamp_to(clamp);
        ssm_kv.clamp_to(clamp);
        Ok(())
    }

    /// Re-ingest the SSM's backlog (plain-decode rounds / freshly admitted
    /// rows) so the delta invariant holds again.  Each pass ingests up to
    /// 2 tokens per row via a throwaway `speculate(s=1)` call, then clamps
    /// the counters.
    fn ssm_catch_up(
        &mut self,
        rows: &RowSoa,
        bucket: usize,
        ssm_kv: &mut Kv,
        stats: &mut GenStats,
    ) -> Result<()> {
        let pad = self.cfg.pad_token;
        let Engine {
            ssm,
            stopwatch,
            scratch,
            ..
        } = self;
        let RoundScratch {
            delta,
            dlens,
            clamp,
            draft,
            ..
        } = scratch;
        loop {
            let ingested = ssm_kv.ingested();
            let max_missing = (0..bucket)
                .map(|i| rows.len[i] as usize - ingested[i] as usize)
                .max()
                .unwrap_or(0);
            if max_missing <= 2 {
                return Ok(());
            }
            delta.clear();
            delta.resize(bucket * 2, pad);
            dlens.clear();
            dlens.resize(bucket, 0);
            for i in 0..bucket {
                let ing = ingested[i] as usize;
                // leave at least one committed token un-ingested
                let take = (rows.len[i] as usize - 1 - ing).clamp(1, 2);
                for (j, &t) in rows.committed(i)[ing..ing + take].iter().enumerate() {
                    delta[i * 2 + j] = t;
                }
                dlens[i] = take as i32;
            }
            stopwatch.time("ssm_catch_up", || {
                ssm.speculate_into(delta, dlens, 1, bucket, ssm_kv, draft)
            })?;
            stats.ssm_calls += 1;
            clamp.clear();
            clamp.extend((0..bucket).map(|i| rows.len[i] - 1));
            ssm_kv.clamp_to(clamp);
        }
    }

    /// Freeze rows that hit their budget or emitted `<eos>`.
    fn check_eos_and_limits(&self, rows: &mut RowSoa) {
        for i in 0..rows.n() {
            if rows.finished[i] {
                continue;
            }
            if rows.generated(i) >= rows.max_new[i] as usize {
                rows.finished[i] = true;
                continue;
            }
            if self.cfg.stop_at_eos && rows.gen_tokens(i).contains(&self.cfg.eos_token) {
                rows.finished[i] = true;
            }
        }
    }
}

/// Build the SSM delta (the 1..=2 committed tokens it has not seen) into
/// caller-owned scratch.
fn build_delta_into(
    pad: i32,
    rows: &RowSoa,
    ssm_kv: &Kv,
    delta: &mut Vec<i32>,
    dlens: &mut Vec<i32>,
) -> Result<()> {
    let bucket = rows.n();
    let ingested = ssm_kv.ingested();
    delta.clear();
    delta.resize(bucket * 2, pad);
    dlens.clear();
    dlens.resize(bucket, 0);
    for i in 0..bucket {
        let ing = ingested[i] as usize;
        let committed = rows.len[i] as usize;
        let missing = committed - ing;
        if !(1..=2).contains(&missing) {
            bail!("SSM delta invariant violated on row {i}: committed {committed} ingested {ing}");
        }
        for (j, &t) in rows.committed(i)[ing..].iter().enumerate() {
            delta[i * 2 + j] = t;
        }
        dlens[i] = missing as i32;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{Fixed, LutAdaptive, NoSpec};
    use crate::testkit::stub::StubModel;

    fn stub_engine() -> Engine<'static> {
        Engine::stub(StubSpec::default(), EngineConfig::default()).unwrap()
    }

    /// The greedy reference chain of the stub LLM.
    fn chain(start: i32, n: usize) -> Vec<i32> {
        let m = StubModel::new(StubSpec::default(), StubRole::Llm);
        let mut out = Vec::with_capacity(n);
        let mut cur = start;
        for _ in 0..n {
            cur = m.llm_next(cur);
            out.push(cur);
        }
        out
    }

    #[test]
    fn stub_generation_is_lossless_across_policies() {
        let mut e = stub_engine();
        let prompts = vec![vec![5, 9, 12], vec![7], vec![30, 31]];
        let expect: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| chain(*p.last().unwrap(), 20))
            .collect();
        let mut policies: Vec<Box<dyn SpeculationPolicy>> = vec![
            Box::new(NoSpec),
            Box::new(Fixed(1)),
            Box::new(Fixed(4)),
            Box::new(LutAdaptive(
                crate::scheduler::Lut::new(
                    [(1usize, 5usize), (4, 3), (16, 1)].into_iter().collect(),
                )
                .unwrap(),
            )),
        ];
        for policy in policies.iter_mut() {
            let label = policy.label();
            let out = e.generate_batch(&prompts, 20, policy.as_mut()).unwrap();
            assert_eq!(out.tokens, expect, "policy {label}");
            assert!(out.stats.rounds > 0);
        }
    }

    #[test]
    fn step_api_matches_generate_batch() {
        let prompts = vec![vec![5, 9], vec![7, 8, 11]];
        let reference = stub_engine()
            .generate_batch(&prompts, 16, &mut Fixed(3))
            .unwrap();

        let mut e = stub_engine();
        let mut policy = Fixed(3);
        let bucket = e.limits().bucket_for(prompts.len()).unwrap();
        let mut st = e.prefill_rows(&prompts, bucket, true, 16).unwrap();
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        for (i, expect) in reference.tokens.iter().enumerate() {
            let got = st.generated_tokens(i).unwrap();
            assert_eq!(&got[..expect.len().min(got.len())], &expect[..]);
        }
    }

    #[test]
    fn per_round_timeline_records_live_s_and_cost() {
        let mut e = stub_engine();
        let out = e
            .generate_batch(&[vec![5], vec![9]], 12, &mut Fixed(2))
            .unwrap();
        assert_eq!(out.stats.per_round.len(), out.stats.rounds);
        for r in &out.stats.per_round {
            assert!(r.live >= 1 && r.live <= 2);
            assert!(r.s <= 2);
            assert!(r.committed >= 1);
            assert!(r.accepted <= r.s * r.live);
            assert!(r.round_time >= 0.0);
        }
        // the per-round accepted counts reconcile with the epoch totals
        let total: usize = out.stats.per_round.iter().map(|r| r.accepted).sum();
        assert_eq!(
            total,
            out.stats.accept_samples.iter().map(|&a| a as usize).sum::<usize>()
        );
    }

    /// The engine drives the policy's feedback edge: one observe call per
    /// round, with the same (live, s) the round ran with.
    #[test]
    fn decode_round_feeds_the_policy_back() {
        use crate::policy::RoundFeedback;

        struct Recorder {
            inner: Fixed,
            seen: Vec<(usize, usize, usize)>,
        }
        impl SpeculationPolicy for Recorder {
            fn choose(&self, live: usize, max_s: usize) -> usize {
                self.inner.choose(live, max_s)
            }
            fn observe(&mut self, fb: &RoundFeedback) {
                if fb.s == 0 {
                    assert!(fb.accepted.is_empty(), "plain rounds carry no samples");
                }
                self.seen.push((fb.live, fb.s, fb.committed));
            }
            fn label(&self) -> String {
                "recorder".into()
            }
        }

        let mut e = stub_engine();
        let mut policy = Recorder {
            inner: Fixed(3),
            seen: Vec::new(),
        };
        let out = e.generate_batch(&[vec![5], vec![9]], 10, &mut policy).unwrap();
        assert_eq!(policy.seen.len(), out.stats.rounds);
        for ((live, s, committed), info) in policy.seen.iter().zip(&out.stats.per_round) {
            assert_eq!(*live, info.live);
            assert_eq!(*s, info.s);
            assert_eq!(*committed, info.committed);
        }
    }

    #[test]
    fn admission_mid_epoch_is_lossless() {
        let mut policy = Fixed(3);
        let p0 = vec![5, 9, 12];
        let p1 = vec![7];
        let p2 = vec![40, 41];
        let expect = |p: &Vec<i32>| chain(*p.last().unwrap(), 10);

        let mut e = stub_engine();
        let mut st = e.prefill_rows(&[p0.clone()], 4, true, 10).unwrap();
        // run a few rounds with only row 0 live
        for _ in 0..3 {
            if st.has_live() {
                e.decode_round(&mut st, &mut policy).unwrap();
            }
        }
        // admit two more requests into free slots mid-epoch
        let reqs: Vec<AdmitRequest> = [&p1, &p2]
            .iter()
            .map(|p| AdmitRequest::fresh((*p).clone(), p.len(), 10))
            .collect();
        let slots = e.admit_rows(&mut st, reqs).unwrap();
        assert_eq!(slots.len(), 2);
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        let retired = e.retire_finished(&mut st);
        assert_eq!(retired.len(), 3);
        let by_slot = |slot: usize| {
            retired
                .iter()
                .find(|r| r.slot == slot)
                .map(|r| r.tokens.clone())
                .unwrap()
        };
        assert_eq!(by_slot(0), expect(&p0));
        assert_eq!(by_slot(slots[0]), expect(&p1));
        assert_eq!(by_slot(slots[1]), expect(&p2));
        // all slots are free again
        assert_eq!(st.free_slots(), 4);
        assert!(!st.has_live());
    }

    #[test]
    fn retire_frees_slots_for_reuse() {
        let mut policy = Fixed(2);
        let mut e = stub_engine();
        let mut st = e.prefill_rows(&[vec![5]], 2, true, 4).unwrap();
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        let first = e.retire_finished(&mut st);
        assert_eq!(first.len(), 1);
        assert_eq!(st.free_slots(), 2);
        // admit a new request into the recycled slot and finish it
        let slots = e
            .admit_rows(&mut st, vec![AdmitRequest::fresh(vec![9, 10], 2, 6)])
            .unwrap();
        assert_eq!(slots.len(), 1);
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        let second = e.retire_finished(&mut st);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].tokens, chain(10, 6));
    }

    #[test]
    fn odd_batch_pads_to_bucket_and_rejects_oversizes() {
        let mut e = stub_engine();
        let out = e
            .generate_batch(
                &[vec![5], vec![6], vec![7]],
                6,
                &mut Fixed(2),
            )
            .unwrap();
        assert_eq!(out.tokens.len(), 3);

        let too_long = vec![vec![4i32; e.limits().max_prompt + 1]];
        assert!(e.generate_batch(&too_long, 4, &mut NoSpec).is_err());
        assert!(e.generate_batch(&[], 4, &mut NoSpec).is_err());
        let max_bucket = *e.limits().batch_buckets.last().unwrap();
        let too_many = vec![vec![5i32, 6]; max_bucket + 1];
        assert!(e.generate_batch(&too_many, 4, &mut NoSpec).is_err());
    }

    #[test]
    fn kv_capacity_overflow_is_detected() {
        let spec = StubSpec {
            max_seq: 24,
            ..StubSpec::default()
        };
        let mut e = Engine::stub(spec, EngineConfig::default()).unwrap();
        let err = e
            .generate_batch(&[vec![5, 6, 7]], 64, &mut Fixed(2))
            .unwrap_err()
            .to_string();
        assert!(err.contains("overflow"), "{err}");
    }

    #[test]
    fn admission_near_kv_capacity_shrinks_the_ingest_chunk() {
        // a frozen row sitting near max_seq must not make admission fail:
        // the ingest chunk shrinks to what the capacity check allows
        let spec = StubSpec {
            max_seq: 40,
            ..StubSpec::default()
        };
        let mut policy = Fixed(2);
        let mut e = Engine::stub(spec, EngineConfig::default()).unwrap();
        let mut st = e.prefill_rows(&[vec![5, 6, 7, 8]], 2, true, 30).unwrap();
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        // do NOT retire: the frozen row keeps its high ingest counter
        let slots = e
            .admit_rows(&mut st, vec![AdmitRequest::fresh(vec![9; 14], 14, 2)])
            .unwrap();
        while st.has_live() {
            e.decode_round(&mut st, &mut policy).unwrap();
        }
        let retired = e.retire_finished(&mut st);
        let new_row = retired.iter().find(|r| r.slot == slots[0]).unwrap();
        assert_eq!(new_row.tokens, chain(9, 2));
    }

    fn layout_engine(layout: KvLayout) -> Engine<'static> {
        Engine::stub(
            StubSpec::default(),
            EngineConfig {
                kv_layout: layout,
                ..EngineConfig::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn paged_generation_matches_dense_and_releases_every_block() {
        let prompts = vec![vec![5, 9, 12], vec![7]];
        let dense = layout_engine(KvLayout::Dense)
            .generate_batch(&prompts, 16, &mut Fixed(3))
            .unwrap();
        let mut e = layout_engine(KvLayout::Paged);
        let paged = e.generate_batch(&prompts, 16, &mut Fixed(3)).unwrap();
        assert_eq!(dense.tokens, paged.tokens, "layouts must not change tokens");
        e.clear_prefix_cache(); // cached prefix blocks are not leaks
        let stats = e.kv_block_stats().expect("paged engine reports block stats");
        assert!(stats.is_leak_free(), "blocks leaked: {stats:?}");
        assert!(stats.peak_in_use > 0, "the epoch never held a block");
        assert!(layout_engine(KvLayout::Dense).kv_block_stats().is_none());
    }

    #[test]
    fn spec_len_respects_bucket_cap() {
        let spec = StubSpec {
            max_spec: 3,
            ..StubSpec::default()
        };
        let mut e = Engine::stub(spec, EngineConfig::default()).unwrap();
        let out = e
            .generate_batch(&[vec![5]], 10, &mut Fixed(8))
            .unwrap();
        assert!(out.stats.spec_lens.iter().all(|&s| s <= 3));
        assert_eq!(out.tokens[0], chain(5, 10));
    }
}
