//! The batched speculative decoding engine (the paper's Sec. 3 prototype,
//! re-built as the L3 hot path).
//!
//! One [`Engine::generate_batch`] call serves one batch to completion:
//!
//! ```text
//! prefill(LLM) ─ prefill(SSM, if the policy may speculate)
//! loop:
//!   s = policy(live batch size)
//!   s == 0 ->  verify_s0(LLM)                      # plain batched decode
//!   s >= 1 ->  speculate(SSM, s) -> verify(LLM, s) # Algorithm 1, batched
//!   host: first-mismatch acceptance, commit, clamp both KV ingest counters
//! until every live row hit max_new_tokens (or <eos>)
//! ```
//!
//! State invariants (shared with `python/compile/engine_ref.py`, asserted
//! in debug builds and by the integration tests):
//!
//! * per row: `ingested == committed.len() - 1` after every round for both
//!   models (the last committed token is fed, not pre-ingested);
//! * the SSM sees a "delta" of 1..=2 committed tokens per speculation —
//!   rounds that skip the SSM (s = 0) grow its backlog, which
//!   [`Engine::ssm_catch_up`] re-ingests before the next speculation;
//! * rows that finish stay in the batch but frozen: their feeds repeat the
//!   last committed token and their commits are discarded, so executables
//!   keep their static shapes (the paper's prototype masks finished rows
//!   the same way).

pub mod acceptance;

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::{KvCache, Model};
use crate::runtime::Runtime;
use crate::scheduler::SpecPolicy;
use crate::util::timer::Stopwatch;
use acceptance::accept_batch;

/// Engine knobs (defaults = paper Sec. 5 methodology).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_new_tokens: usize,
    pub stop_at_eos: bool,
    pub eos_token: i32,
    pub bos_token: i32,
    pub pad_token: i32,
    /// record per-round accepted counts (Fig. 2 estimator input)
    pub record_acceptance: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_new_tokens: 128,
            stop_at_eos: true,
            eos_token: 2,
            bos_token: 1,
            pad_token: 0,
            record_acceptance: false,
        }
    }
}

/// Statistics of one `generate_batch` call.
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    /// decode rounds after prefill (each = <=1 SSM call + 1 LLM call)
    pub rounds: usize,
    pub llm_calls: usize,
    pub ssm_calls: usize,
    /// total draft tokens proposed / accepted (live rows only)
    pub drafted: usize,
    pub accepted: usize,
    /// tokens returned to callers (sum over real rows)
    pub useful_tokens: usize,
    /// wall time of the whole call including prefill
    pub wall: Duration,
    /// wall time spent after prefill (per-token latency uses this)
    pub decode_wall: Duration,
    /// accepted-count samples (one per live row per speculative round)
    pub accept_samples: Vec<u32>,
    /// speculation length used each round
    pub spec_lens: Vec<usize>,
}

impl GenStats {
    /// Per-token decode latency in seconds (the paper's Fig. 1/4 metric).
    pub fn per_token_latency(&self) -> f64 {
        if self.useful_tokens == 0 {
            return f64::NAN;
        }
        self.decode_wall.as_secs_f64() / self.useful_tokens as f64
    }

    /// Mean accepted drafts per speculative round (the l̄ of Sec. 3.3).
    pub fn mean_accepted(&self) -> f64 {
        if self.accept_samples.is_empty() {
            return 0.0;
        }
        self.accept_samples.iter().map(|&a| a as f64).sum::<f64>()
            / self.accept_samples.len() as f64
    }
}

/// Output of one batch generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    /// generated tokens per input prompt (prompt excluded), truncated at
    /// `max_new_tokens` / first `<eos>`
    pub tokens: Vec<Vec<i32>>,
    pub stats: GenStats,
}

/// Per-row state during a batch generation.
struct Row {
    committed: Vec<i32>,
    prompt_len: usize,
    /// real request (false = bucket padding row)
    real: bool,
    /// frozen rows keep shapes static but stop committing
    finished: bool,
}

impl Row {
    fn generated(&self) -> usize {
        self.committed.len() - self.prompt_len
    }

    fn last(&self) -> i32 {
        *self.committed.last().expect("committed never empty")
    }
}

/// The batched speculative decoding engine.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: EngineConfig,
    llm: Model<'rt>,
    ssm: Model<'rt>,
    /// per-section timing for the §Perf pass
    pub stopwatch: Stopwatch,
    /// stash for the prefill prediction between prefill() and its commit
    last_prefill: Option<Vec<i32>>,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Result<Engine<'rt>> {
        Ok(Engine {
            rt,
            cfg,
            llm: Model::new(rt, "llm")?,
            ssm: Model::new(rt, "ssm")?,
            stopwatch: Stopwatch::new(),
            last_prefill: None,
        })
    }

    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Generate up to `max_new` tokens for every prompt, as one batch.
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        policy: &SpecPolicy,
    ) -> Result<GenOutput> {
        let t_start = Instant::now();
        let n = prompts.len();
        if n == 0 {
            bail!("generate_batch: empty prompt list");
        }
        let max_prompt = self.llm.spec.max_prompt;
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() > max_prompt {
                bail!(
                    "prompt {i} length {} out of range 1..={max_prompt}",
                    p.len()
                );
            }
        }
        let bucket = self.rt.manifest.bucket_for(n)?;
        let max_s = self.rt.manifest.max_spec_len(bucket);
        let may_speculate = !matches!(policy, SpecPolicy::NoSpec) && max_s > 0;

        // --- assemble rows (real + bucket padding) ---
        let mut rows: Vec<Row> = Vec::with_capacity(bucket);
        for p in prompts {
            rows.push(Row {
                committed: p.clone(),
                prompt_len: p.len(),
                real: true,
                finished: false,
            });
        }
        for _ in n..bucket {
            rows.push(Row {
                committed: vec![self.cfg.bos_token],
                prompt_len: 1,
                real: false,
                finished: true, // padding rows are frozen from the start
            });
        }

        // --- prefill ---
        let (mut llm_kv, mut ssm_kv, _prefill_dur) =
            self.prefill(&rows, bucket, may_speculate)?;

        let mut stats = GenStats::default();
        let mut ssm_backlog_possible = false;

        // commit the prefill token
        // (prefill() stashed it in self.last_prefill)
        let first = self.last_prefill.take().expect("prefill token set");
        for (row, &t) in rows.iter_mut().zip(&first) {
            row.committed.push(t);
        }
        self.check_eos_and_limits(&mut rows, max_new);

        let decode_start = Instant::now();

        // --- decode loop ---
        while rows.iter().any(|r| r.real && !r.finished) {
            let live = rows.iter().filter(|r| r.real && !r.finished).count();
            let s = policy.spec_len(live, max_s);
            stats.spec_lens.push(s);
            stats.rounds += 1;

            if s == 0 || !may_speculate {
                self.round_plain(&mut rows, bucket, &mut llm_kv, &mut stats)?;
                ssm_backlog_possible = true;
            } else {
                let ssm_kv = ssm_kv.as_mut().expect("ssm kv exists");
                if ssm_backlog_possible {
                    self.ssm_catch_up(&rows, bucket, ssm_kv, &mut stats)?;
                    ssm_backlog_possible = false;
                }
                self.round_speculative(&mut rows, bucket, s, &mut llm_kv, ssm_kv, &mut stats)?;
            }
            self.check_eos_and_limits(&mut rows, max_new);

            // hard safety net: a stuck batch must not loop forever
            if stats.rounds > 4 * (max_new + 2) {
                bail!("decode loop exceeded round budget — state machine bug");
            }
        }
        stats.decode_wall = decode_start.elapsed();
        stats.wall = t_start.elapsed();

        // --- collect outputs ---
        let mut tokens = Vec::with_capacity(n);
        for row in rows.iter().take(n) {
            let gen = &row.committed[row.prompt_len..];
            let mut out: Vec<i32> = Vec::with_capacity(max_new.min(gen.len()));
            for &t in gen.iter().take(max_new) {
                out.push(t);
                if self.cfg.stop_at_eos && t == self.cfg.eos_token {
                    break;
                }
            }
            stats.useful_tokens += out.len();
            tokens.push(out);
        }
        Ok(GenOutput { tokens, stats })
    }

    /// LLM (+ optional SSM) prefill over the padded prompts.
    fn prefill(
        &mut self,
        rows: &[Row],
        bucket: usize,
        with_ssm: bool,
    ) -> Result<(KvCache, Option<KvCache>, Duration)> {
        let t0 = Instant::now();
        let p = self.llm.spec.max_prompt;
        let mut tokens = vec![self.cfg.pad_token; bucket * p];
        let mut plens = vec![0i32; bucket];
        for (i, row) in rows.iter().enumerate() {
            tokens[i * p..i * p + row.prompt_len]
                .copy_from_slice(&row.committed[..row.prompt_len]);
            plens[i] = row.prompt_len as i32;
        }
        let mut llm_kv = self.llm.new_kv(bucket)?;
        let first = self.stopwatch.time("prefill_llm", || {
            self.llm.prefill(&tokens, &plens, bucket, &mut llm_kv)
        })?;
        self.last_prefill = Some(first);

        let ssm_kv = if with_ssm {
            let mut kv = self.ssm.new_kv(bucket)?;
            // the SSM's own first prediction is discarded — it only needs KV
            let _ = self.stopwatch.time("prefill_ssm", || {
                self.ssm.prefill(&tokens, &plens, bucket, &mut kv)
            })?;
            Some(kv)
        } else {
            None
        };
        Ok((llm_kv, ssm_kv, t0.elapsed()))
    }

    /// One plain decode round (s = 0): feed the last committed token.
    fn round_plain(
        &mut self,
        rows: &mut [Row],
        bucket: usize,
        llm_kv: &mut KvCache,
        stats: &mut GenStats,
    ) -> Result<()> {
        let feed: Vec<i32> = rows.iter().map(|r| r.last()).collect();
        let pred = self
            .stopwatch
            .time("verify", || self.llm.verify(&feed, 0, bucket, llm_kv))?;
        stats.llm_calls += 1;
        for (row, &t) in rows.iter_mut().zip(&pred) {
            if !row.finished {
                row.committed.push(t);
            }
        }
        let clamp: Vec<u32> = rows.iter().map(|r| r.committed.len() as u32 - 1).collect();
        llm_kv.clamp_to(&clamp);
        Ok(())
    }

    /// One speculative round: SSM drafts s tokens, LLM verifies, host
    /// accepts (Algorithm 1).
    fn round_speculative(
        &mut self,
        rows: &mut [Row],
        bucket: usize,
        s: usize,
        llm_kv: &mut KvCache,
        ssm_kv: &mut KvCache,
        stats: &mut GenStats,
    ) -> Result<()> {
        // --- SSM: delta ingest + draft ---
        let (delta, dlens) = self.build_delta(rows, ssm_kv)?;
        let draft = self.stopwatch.time("speculate", || {
            self.ssm.speculate(&delta, &dlens, s, bucket, ssm_kv)
        })?;
        stats.ssm_calls += 1;

        // --- LLM: verify ---
        let mut feed = vec![0i32; bucket * (s + 1)];
        for (i, row) in rows.iter().enumerate() {
            feed[i * (s + 1)] = row.last();
            feed[i * (s + 1) + 1..(i + 1) * (s + 1)]
                .copy_from_slice(&draft[i * s..(i + 1) * s]);
        }
        let pred = self
            .stopwatch
            .time("verify", || self.llm.verify(&feed, s, bucket, llm_kv))?;
        stats.llm_calls += 1;

        // --- host: acceptance + commit ---
        let results = accept_batch(&draft, &pred, bucket, s);
        for (row, acc) in rows.iter_mut().zip(&results) {
            if row.finished {
                continue;
            }
            row.committed.extend_from_slice(&acc.commit);
            stats.drafted += s;
            stats.accepted += acc.accepted;
            if self.cfg.record_acceptance && row.real {
                stats.accept_samples.push(acc.accepted as u32);
            }
        }
        if !self.cfg.record_acceptance {
            // still track live-row acceptance for mean_accepted()
            for (row, acc) in rows.iter().zip(&results) {
                if !row.finished && row.real {
                    stats.accept_samples.push(acc.accepted as u32);
                }
            }
        }

        // --- clamp both caches to committed-1 ---
        let clamp: Vec<u32> = rows.iter().map(|r| r.committed.len() as u32 - 1).collect();
        llm_kv.clamp_to(&clamp);
        ssm_kv.clamp_to(&clamp);
        Ok(())
    }

    /// Build the SSM delta (the 1..=2 committed tokens it has not seen).
    fn build_delta(&self, rows: &[Row], ssm_kv: &KvCache) -> Result<(Vec<i32>, Vec<i32>)> {
        let bucket = rows.len();
        let mut delta = vec![self.cfg.pad_token; bucket * 2];
        let mut dlens = vec![0i32; bucket];
        for (i, row) in rows.iter().enumerate() {
            let ing = ssm_kv.ingested[i] as usize;
            let missing = row.committed.len() - ing;
            if !(1..=2).contains(&missing) {
                bail!(
                    "SSM delta invariant violated on row {i}: committed {} ingested {ing}",
                    row.committed.len()
                );
            }
            for (j, &t) in row.committed[ing..].iter().enumerate() {
                delta[i * 2 + j] = t;
            }
            dlens[i] = missing as i32;
        }
        Ok((delta, dlens))
    }

    /// Re-ingest the SSM's backlog after plain-decode rounds so the delta
    /// invariant holds again.  Each pass ingests up to 2 tokens per row
    /// via a throwaway `speculate(s=1)` call, then clamps the counters.
    fn ssm_catch_up(
        &mut self,
        rows: &[Row],
        bucket: usize,
        ssm_kv: &mut KvCache,
        stats: &mut GenStats,
    ) -> Result<()> {
        loop {
            let max_missing = rows
                .iter()
                .enumerate()
                .map(|(i, r)| r.committed.len() - ssm_kv.ingested[i] as usize)
                .max()
                .unwrap_or(0);
            if max_missing <= 2 {
                return Ok(());
            }
            let mut delta = vec![self.cfg.pad_token; bucket * 2];
            let mut dlens = vec![0i32; bucket];
            for (i, row) in rows.iter().enumerate() {
                let ing = ssm_kv.ingested[i] as usize;
                // leave at least one committed token un-ingested
                let take = (row.committed.len() - 1 - ing).clamp(1, 2);
                for (j, &t) in row.committed[ing..ing + take].iter().enumerate() {
                    delta[i * 2 + j] = t;
                }
                dlens[i] = take as i32;
            }
            let _ = self.stopwatch.time("ssm_catch_up", || {
                self.ssm.speculate(&delta, &dlens, 1, bucket, ssm_kv)
            })?;
            stats.ssm_calls += 1;
            let clamp: Vec<u32> =
                rows.iter().map(|r| r.committed.len() as u32 - 1).collect();
            ssm_kv.clamp_to(&clamp);
        }
    }

    /// Freeze rows that hit their budget or emitted `<eos>`.
    fn check_eos_and_limits(&self, rows: &mut [Row], max_new: usize) {
        for row in rows.iter_mut() {
            if row.finished {
                continue;
            }
            if row.generated() >= max_new {
                row.finished = true;
                continue;
            }
            if self.cfg.stop_at_eos {
                let gen = &row.committed[row.prompt_len..];
                if gen.contains(&self.cfg.eos_token) {
                    row.finished = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Engine logic that does not need a Runtime is covered in
    // acceptance.rs; end-to-end behaviour (including losslessness vs the
    // Python goldens) lives in rust/tests/engine_integration.rs.
}
