//! The acceptance rule of speculative decoding (Algorithm 1, greedy).
//!
//! Given the SSM's draft tokens and the LLM's argmax predictions at every
//! in-flight position, compute how many drafts are accepted and which
//! tokens get committed.  Pure host-side logic, exhaustively unit- and
//! property-tested (testkit) because *losslessness* — speculative output
//! must equal plain greedy output — hinges on this function.

/// Result of verifying one row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowAcceptance {
    /// number of draft tokens accepted (0..=s)
    pub accepted: usize,
    /// tokens to append to the committed sequence: the accepted drafts
    /// plus the LLM's bonus/correction token (always non-empty)
    pub commit: Vec<i32>,
}

/// Greedy first-mismatch acceptance for one row.
///
/// `draft` is `d_1..d_s` from the SSM; `pred` is `argmax(o_0)..argmax(o_s)`
/// from the LLM, where `pred[j]` is the LLM's choice for the token *after*
/// position j of the feed `[last_committed, d_1..d_s]`.
///
/// `d_{j+1}` is accepted iff it equals `pred[j]` **and** all earlier drafts
/// were accepted (the paper: "the correctness of one speculated token
/// relies on the correctness of its previous tokens").  The committed
/// tokens are the accepted prefix plus `pred[a]` — a bonus token when all
/// drafts pass, a correction otherwise.  The LLM thus always contributes
/// exactly one token, which guarantees termination even with a useless
/// draft model.
pub fn accept_row(draft: &[i32], pred: &[i32]) -> RowAcceptance {
    debug_assert_eq!(pred.len(), draft.len() + 1);
    let mut accepted = 0;
    while accepted < draft.len() && draft[accepted] == pred[accepted] {
        accepted += 1;
    }
    let mut commit = Vec::with_capacity(accepted + 1);
    commit.extend_from_slice(&draft[..accepted]);
    commit.push(pred[accepted]);
    RowAcceptance { accepted, commit }
}

/// Batched acceptance over flattened `[B, s]` drafts / `[B, s+1]` preds.
pub fn accept_batch(draft: &[i32], pred: &[i32], batch: usize, s: usize) -> Vec<RowAcceptance> {
    assert_eq!(draft.len(), batch * s);
    assert_eq!(pred.len(), batch * (s + 1));
    (0..batch)
        .map(|i| accept_row(&draft[i * s..(i + 1) * s], &pred[i * (s + 1)..(i + 1) * (s + 1)]))
        .collect()
}

/// Allocation-free batched acceptance into caller-owned scratch: row `i`'s
/// committed tokens land at `commit[i*(s+1)..][..commit_len[i]]`
/// (`commit_len[i]` = accepted + 1, matching [`accept_row`]'s commit).
/// The hot-path twin of [`accept_batch`] — same decisions, flat output.
pub fn accept_into(
    draft: &[i32],
    pred: &[i32],
    batch: usize,
    s: usize,
    commit: &mut Vec<i32>,
    commit_len: &mut Vec<u32>,
) {
    assert_eq!(draft.len(), batch * s);
    assert_eq!(pred.len(), batch * (s + 1));
    commit.clear();
    commit.resize(batch * (s + 1), 0);
    commit_len.clear();
    commit_len.resize(batch, 0);
    for i in 0..batch {
        let d = &draft[i * s..(i + 1) * s];
        let p = &pred[i * (s + 1)..(i + 1) * (s + 1)];
        let mut accepted = 0;
        while accepted < s && d[accepted] == p[accepted] {
            accepted += 1;
        }
        let out = &mut commit[i * (s + 1)..][..accepted + 1];
        out[..accepted].copy_from_slice(&d[..accepted]);
        out[accepted] = p[accepted];
        commit_len[i] = (accepted + 1) as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_accepted_gets_bonus() {
        let r = accept_row(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(r.accepted, 3);
        assert_eq!(r.commit, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_truncates() {
        let r = accept_row(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(r.accepted, 1);
        assert_eq!(r.commit, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_still_commits_one() {
        let r = accept_row(&[5, 6], &[1, 2, 3]);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.commit, vec![1]);
    }

    #[test]
    fn later_coincidences_do_not_resurrect() {
        // draft[1] "matches" pred[1] but draft[0] failed, so it must not
        // count — correctness is prefix-dependent
        let r = accept_row(&[5, 6], &[9, 6, 7]);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.commit, vec![9]);
    }

    #[test]
    fn zero_length_draft_is_plain_decode() {
        let r = accept_row(&[], &[42]);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.commit, vec![42]);
    }

    #[test]
    fn batch_layout() {
        let draft = [1, 2, /* row 1 */ 3, 4];
        let pred = [1, 2, 9, /* row 1 */ 7, 4, 5];
        let rows = accept_batch(&draft, &pred, 2, 2);
        assert_eq!(rows[0].commit, vec![1, 2, 9]);
        assert_eq!(rows[1].commit, vec![7]);
    }

    #[test]
    fn single_token_draft_accept_and_reject() {
        let hit = accept_row(&[5], &[5, 8]);
        assert_eq!(hit.accepted, 1);
        assert_eq!(hit.commit, vec![5, 8]);
        let miss = accept_row(&[5], &[6, 8]);
        assert_eq!(miss.accepted, 0);
        assert_eq!(miss.commit, vec![6]);
    }

    #[test]
    fn batch_where_every_row_rejects_still_commits_one_each() {
        let draft = [1, 2, 3, 4, 5, 6];
        let pred = [9, 1, 2, 8, 3, 4, 7, 5, 6]; // first prediction differs per row
        let rows = accept_batch(&draft, &pred, 3, 2);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.accepted, 0, "row {i}");
            assert_eq!(r.commit.len(), 1, "row {i}");
            assert_eq!(r.commit[0], pred[i * 3], "row {i}");
        }
    }

    #[test]
    fn commit_structure_invariant_holds() {
        // commit = accepted prefix of the draft + exactly one LLM token
        let draft = [5, 6, 7, 8];
        let pred = [5, 6, 9, 1, 2];
        let r = accept_row(&draft, &pred);
        assert_eq!(r.commit.len(), r.accepted + 1);
        assert_eq!(&r.commit[..r.accepted], &draft[..r.accepted]);
        assert_eq!(r.commit[r.accepted], pred[r.accepted]);
    }

    #[test]
    fn accept_into_matches_accept_batch() {
        // exhaustive-ish cross-check on a mixed batch: full accept,
        // partial, immediate reject, and a later coincidence
        let draft = [5, 6, /* r1 */ 5, 9, /* r2 */ 1, 2, /* r3 */ 4, 6];
        let pred = [5, 6, 7, /* r1 */ 5, 8, 9, /* r2 */ 9, 2, 3, /* r3 */ 3, 6, 1];
        let rows = accept_batch(&draft, &pred, 4, 2);
        let (mut commit, mut commit_len) = (Vec::new(), Vec::new());
        accept_into(&draft, &pred, 4, 2, &mut commit, &mut commit_len);
        for (i, r) in rows.iter().enumerate() {
            let n = commit_len[i] as usize;
            assert_eq!(n, r.accepted + 1, "row {i} length");
            assert_eq!(&commit[i * 3..][..n], r.commit.as_slice(), "row {i}");
        }
        // scratch reuse across calls must not leak stale state
        accept_into(&draft[..2], &pred[..3], 1, 2, &mut commit, &mut commit_len);
        assert_eq!(commit_len.len(), 1);
        assert_eq!(&commit[..commit_len[0] as usize], rows[0].commit.as_slice());
    }

    #[test]
    fn commit_always_advances() {
        // termination property: every row commits >= 1 token
        for draft in [&[][..], &[1][..], &[1, 2, 3][..]] {
            let pred: Vec<i32> = (10..10 + draft.len() as i32 + 1).collect();
            assert!(!accept_row(draft, &pred).commit.is_empty());
        }
    }
}
