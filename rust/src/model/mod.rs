//! Model handles, backend-polymorphic.
//!
//! The engine drives its models through [`ModelHandle`] / [`Kv`], which
//! dispatch to one of two backends:
//!
//! * **PJRT** ([`Model`] / [`KvCache`], `--features pjrt`): the real AOT
//!   executables loaded through the PJRT C API;
//! * **stub** ([`crate::testkit::stub::StubModel`], always available):
//!   a deterministic hash-chain model pair honouring the identical
//!   calling convention, so the engine, batcher and server are fully
//!   testable without Python-built artifacts.
//!
//! Both backends share the KV contract: `ingested[b]` counts the cache
//! entries of row `b` holding committed-token state; entries above are
//! stale and never attended; the caller clamps counters back to
//! `committed - 1` after every acceptance round.

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{KvCache, Model};

use std::marker::PhantomData;

#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::Result;

use crate::testkit::stub::{StubKv, StubModel};

/// A KV cache of either backend.
pub enum Kv {
    #[cfg(feature = "pjrt")]
    Pjrt(KvCache),
    Stub(StubKv),
}

impl Kv {
    pub fn batch(&self) -> usize {
        match self {
            #[cfg(feature = "pjrt")]
            Kv::Pjrt(kv) => kv.batch,
            Kv::Stub(kv) => kv.batch,
        }
    }

    pub fn ingested(&self) -> &[u32] {
        match self {
            #[cfg(feature = "pjrt")]
            Kv::Pjrt(kv) => &kv.ingested,
            Kv::Stub(kv) => &kv.ingested,
        }
    }

    /// Roll ingest counters back to `committed_len - 1` per row.
    pub fn clamp_to(&mut self, committed_minus_one: &[u32]) {
        match self {
            #[cfg(feature = "pjrt")]
            Kv::Pjrt(kv) => kv.clamp_to(committed_minus_one),
            Kv::Stub(kv) => kv.clamp_to(committed_minus_one),
        }
    }

    /// Forget a row entirely (continuous batching re-admits into it).
    pub fn reset_row(&mut self, row: usize) {
        match self {
            #[cfg(feature = "pjrt")]
            Kv::Pjrt(kv) => kv.reset_row(row),
            Kv::Stub(kv) => kv.reset_row(row),
        }
    }

    /// Set a row's ingest counter directly — the paged-layout block-table
    /// remap: the carried row's cache entries already exist (indexed by
    /// its block chain), so admission transfers the counter instead of
    /// re-ingesting the context.
    pub fn set_row_ingested(&mut self, row: usize, ingested: u32) {
        match self {
            #[cfg(feature = "pjrt")]
            Kv::Pjrt(kv) => kv.ingested[row] = ingested,
            Kv::Stub(kv) => kv.ingested[row] = ingested,
        }
    }
}

/// A model of either backend, exposing the three-step calling convention.
pub enum ModelHandle<'rt> {
    #[cfg(feature = "pjrt")]
    Pjrt(Model<'rt>),
    Stub(StubModel, PhantomData<&'rt ()>),
}

impl<'rt> ModelHandle<'rt> {
    pub fn stub(model: StubModel) -> ModelHandle<'rt> {
        ModelHandle::Stub(model, PhantomData)
    }

    pub fn max_prompt(&self) -> usize {
        match self {
            #[cfg(feature = "pjrt")]
            ModelHandle::Pjrt(m) => m.spec.max_prompt,
            ModelHandle::Stub(m, _) => m.spec.max_prompt,
        }
    }

    pub fn max_seq(&self) -> usize {
        match self {
            #[cfg(feature = "pjrt")]
            ModelHandle::Pjrt(m) => m.spec.max_seq,
            ModelHandle::Stub(m, _) => m.spec.max_seq,
        }
    }

    pub fn new_kv(&self, batch: usize) -> Result<Kv> {
        Ok(match self {
            #[cfg(feature = "pjrt")]
            ModelHandle::Pjrt(m) => Kv::Pjrt(m.new_kv(batch)?),
            ModelHandle::Stub(m, _) => Kv::Stub(m.new_kv(batch)),
        })
    }

    pub fn prefill(
        &self,
        tokens: &[i32],
        plens: &[i32],
        batch: usize,
        kv: &mut Kv,
    ) -> Result<Vec<i32>> {
        match (self, kv) {
            #[cfg(feature = "pjrt")]
            (ModelHandle::Pjrt(m), Kv::Pjrt(kv)) => m.prefill(tokens, plens, batch, kv),
            (ModelHandle::Stub(m, _), Kv::Stub(kv)) => m.prefill(tokens, plens, batch, kv),
            #[cfg(feature = "pjrt")]
            _ => bail!("model/KV backend mismatch"),
        }
    }

    pub fn verify(&self, feed: &[i32], s: usize, batch: usize, kv: &mut Kv) -> Result<Vec<i32>> {
        match (self, kv) {
            #[cfg(feature = "pjrt")]
            (ModelHandle::Pjrt(m), Kv::Pjrt(kv)) => m.verify(feed, s, batch, kv),
            (ModelHandle::Stub(m, _), Kv::Stub(kv)) => m.verify(feed, s, batch, kv),
            #[cfg(feature = "pjrt")]
            _ => bail!("model/KV backend mismatch"),
        }
    }

    pub fn speculate(
        &self,
        delta: &[i32],
        dlens: &[i32],
        s: usize,
        batch: usize,
        kv: &mut Kv,
    ) -> Result<Vec<i32>> {
        match (self, kv) {
            #[cfg(feature = "pjrt")]
            (ModelHandle::Pjrt(m), Kv::Pjrt(kv)) => m.speculate(delta, dlens, s, batch, kv),
            (ModelHandle::Stub(m, _), Kv::Stub(kv)) => m.speculate(delta, dlens, s, batch, kv),
            #[cfg(feature = "pjrt")]
            _ => bail!("model/KV backend mismatch"),
        }
    }

    /// [`ModelHandle::verify`] into a caller-owned buffer.  The stub
    /// backend is allocation-free; the PJRT backend stages through its
    /// device transfer either way, so it routes via the owning call.
    pub fn verify_into(
        &self,
        feed: &[i32],
        s: usize,
        batch: usize,
        kv: &mut Kv,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        match (self, kv) {
            #[cfg(feature = "pjrt")]
            (ModelHandle::Pjrt(m), Kv::Pjrt(kv)) => {
                let pred = m.verify(feed, s, batch, kv)?;
                out.clear();
                out.extend_from_slice(&pred);
                Ok(())
            }
            (ModelHandle::Stub(m, _), Kv::Stub(kv)) => m.verify_into(feed, s, batch, kv, out),
            #[cfg(feature = "pjrt")]
            _ => bail!("model/KV backend mismatch"),
        }
    }

    /// [`ModelHandle::speculate`] into a caller-owned buffer (see
    /// [`ModelHandle::verify_into`] for the backend split).
    pub fn speculate_into(
        &self,
        delta: &[i32],
        dlens: &[i32],
        s: usize,
        batch: usize,
        kv: &mut Kv,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        match (self, kv) {
            #[cfg(feature = "pjrt")]
            (ModelHandle::Pjrt(m), Kv::Pjrt(kv)) => {
                let draft = m.speculate(delta, dlens, s, batch, kv)?;
                out.clear();
                out.extend_from_slice(&draft);
                Ok(())
            }
            (ModelHandle::Stub(m, _), Kv::Stub(kv)) => {
                m.speculate_into(delta, dlens, s, batch, kv, out)
            }
            #[cfg(feature = "pjrt")]
            _ => bail!("model/KV backend mismatch"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::stub::{StubRole, StubSpec};

    #[test]
    fn kv_clamp_and_reset_through_the_handle() {
        let m = ModelHandle::stub(StubModel::new(StubSpec::default(), StubRole::Llm));
        let mut kv = m.new_kv(3).unwrap();
        match &mut kv {
            Kv::Stub(inner) => inner.ingested = vec![10, 12, 9],
            #[cfg(feature = "pjrt")]
            _ => unreachable!("stub handle yields stub KV"),
        }
        kv.clamp_to(&[9, 12, 9]);
        assert_eq!(kv.ingested(), &[9, 12, 9]);
        kv.reset_row(1);
        assert_eq!(kv.ingested(), &[9, 0, 9]);
        assert_eq!(kv.batch(), 3);
    }
}
