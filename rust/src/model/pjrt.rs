//! PJRT-backed model handles: typed wrappers over the AOT executables of
//! one model (compiled only with `--features pjrt`).
//!
//! A [`Model`] binds a model name ("llm"/"ssm") to the [`Runtime`] and
//! exposes the three entry points of the calling convention
//! (`prefill` / `verify` / `speculate`) with host-side shape checking.
//! The KV cache lives in a [`KvCache`]: a device buffer chained from call
//! to call (never copied through the host on the hot path) plus the
//! per-row *ingested* counters that drive the attention masks.

use anyhow::{bail, Result};

use crate::runtime::{ExeKind, ModelSpec, Runtime};

/// Device-resident KV cache for one batch, plus per-row ingest counters.
///
/// Invariant (see `python/compile/model.py`): `ingested[b]` cache entries
/// of row `b` hold the K/V of the first `ingested[b]` committed tokens;
/// entries above may be stale (rejected speculations) — they are never
/// attended and are overwritten by the next ingest at the same offsets.
pub struct KvCache {
    pub buf: xla::PjRtBuffer,
    pub batch: usize,
    pub ingested: Vec<u32>,
}

impl KvCache {
    /// Roll ingest counters back to `committed_len - 1` per row after a
    /// verification round rejected some drafts.
    pub fn clamp_to(&mut self, committed_minus_one: &[u32]) {
        assert_eq!(committed_minus_one.len(), self.batch);
        for (ing, &c) in self.ingested.iter_mut().zip(committed_minus_one) {
            *ing = (*ing).min(c);
        }
    }

    /// Forget a row entirely: continuous batching re-admits a new request
    /// into the slot and re-ingests its context from position 0 (stale
    /// device entries above `ingested` are never attended).
    pub fn reset_row(&mut self, row: usize) {
        self.ingested[row] = 0;
    }
}

/// One model (LLM or SSM) bound to the runtime.
pub struct Model<'rt> {
    rt: &'rt Runtime,
    pub name: String,
    pub spec: ModelSpec,
}

impl<'rt> Model<'rt> {
    pub fn new(rt: &'rt Runtime, name: &str) -> Result<Model<'rt>> {
        let spec = rt.model_spec(name)?.clone();
        Ok(Model {
            rt,
            name: name.to_string(),
            spec,
        })
    }

    /// Fresh zeroed KV cache for a batch bucket.
    pub fn new_kv(&self, batch: usize) -> Result<KvCache> {
        let buf = self.rt.f32_zeros(&self.spec.kv_dims(batch))?;
        Ok(KvCache {
            buf,
            batch,
            ingested: vec![0; batch],
        })
    }

    fn run_step(
        &self,
        kind: ExeKind,
        batch: usize,
        s: usize,
        i32_inputs: &[(&[i32], &[usize])],
        kv: &mut KvCache,
    ) -> Result<Vec<i32>> {
        if kv.batch != batch {
            bail!(
                "{}: KV cache batch {} != executable batch {batch}",
                self.name,
                kv.batch
            );
        }
        let exe = self.rt.executable(&self.name, kind, batch, s)?;
        let staged: Vec<xla::PjRtBuffer> = i32_inputs
            .iter()
            .map(|(data, dims)| self.rt.i32_buffer(data, dims))
            .collect::<Result<_>>()?;
        let weights = self.rt.weights(&self.name)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(staged.len() + 1 + weights.len());
        args.extend(staged.iter());
        args.push(&kv.buf);
        args.extend(weights.iter());
        let mut out = self.rt.run(&exe, &args, 2)?;
        // outputs: (pred i32, kv' f32) — keep kv' on device, read pred
        let new_kv = out.pop().unwrap();
        let pred = self.rt.read_i32(&out.pop().unwrap())?;
        kv.buf = new_kv;
        Ok(pred)
    }

    /// Prefill the (padded) prompts; returns the argmax prediction at each
    /// row's last real prompt token (i.e. the first generated token).
    /// Marks all `P` slots ingested=plens afterwards via the caller.
    pub fn prefill(
        &self,
        tokens: &[i32],
        plens: &[i32],
        batch: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<i32>> {
        let p = self.spec.max_prompt;
        if tokens.len() != batch * p {
            bail!(
                "{} prefill: tokens len {} != batch {batch} x max_prompt {p}",
                self.name,
                tokens.len()
            );
        }
        if plens.len() != batch {
            bail!("{} prefill: plens len mismatch", self.name);
        }
        if plens.iter().any(|&l| l <= 0 || l as usize > p) {
            bail!("{} prefill: prompt length out of range 1..={p}", self.name);
        }
        if kv.ingested.iter().any(|&i| i != 0) {
            bail!("{} prefill: KV cache already used", self.name);
        }
        let last = self.run_step(
            ExeKind::Prefill,
            batch,
            0,
            &[(tokens, &[batch, p]), (plens, &[batch])],
            kv,
        )?;
        for (ing, &l) in kv.ingested.iter_mut().zip(plens) {
            *ing = l as u32;
        }
        Ok(last)
    }

    /// LLM verification step: feed `[last_committed, d_1..d_s]` per row,
    /// get the argmax prediction at every position (flattened `[B, s+1]`).
    /// `s == 0` is the plain decode step.  Ingest counters advance by
    /// `s + 1`; the caller clamps them back per accepted counts.
    pub fn verify(
        &self,
        feed: &[i32],
        s: usize,
        batch: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<i32>> {
        let t = s + 1;
        if feed.len() != batch * t {
            bail!(
                "{} verify(s={s}): feed len {} != batch {batch} x {t}",
                self.name,
                feed.len()
            );
        }
        self.check_capacity(kv, t)?;
        let lens: Vec<i32> = kv.ingested.iter().map(|&x| x as i32).collect();
        let pred = self.run_step(
            ExeKind::Verify,
            batch,
            s,
            &[(feed, &[batch, t]), (&lens, &[batch])],
            kv,
        )?;
        for ing in kv.ingested.iter_mut() {
            *ing += t as u32;
        }
        Ok(pred)
    }

    /// SSM speculation step: ingest the 1..=2 token committed delta, then
    /// draft `s` tokens (flattened `[B, s]`).  Ingest counters advance by
    /// `dlens + s - 1` per row (the final draft is predicted, not fed).
    pub fn speculate(
        &self,
        delta: &[i32],
        dlens: &[i32],
        s: usize,
        batch: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<i32>> {
        if s == 0 {
            bail!("{} speculate: s must be >= 1", self.name);
        }
        if delta.len() != batch * 2 || dlens.len() != batch {
            bail!("{} speculate: delta/dlens shape mismatch", self.name);
        }
        if dlens.iter().any(|&d| !(1..=2).contains(&d)) {
            bail!(
                "{} speculate: delta invariant violated (dlens must be 1..=2, got {dlens:?})",
                self.name
            );
        }
        self.check_capacity(kv, 2 + s)?;
        let lens: Vec<i32> = kv.ingested.iter().map(|&x| x as i32).collect();
        let draft = self.run_step(
            ExeKind::Speculate,
            batch,
            s,
            &[(delta, &[batch, 2]), (dlens, &[batch]), (&lens, &[batch])],
            kv,
        )?;
        for (ing, &d) in kv.ingested.iter_mut().zip(dlens) {
            *ing += d as u32 + (s as u32 - 1);
        }
        Ok(draft)
    }

    fn check_capacity(&self, kv: &KvCache, t: usize) -> Result<()> {
        let cap = self.spec.max_seq;
        if let Some(&max_ing) = kv.ingested.iter().max() {
            if max_ing as usize + t > cap {
                bail!(
                    "{}: KV cache overflow (ingested {max_ing} + {t} > capacity {cap}) — \
                     lower max_new_tokens or rebuild artifacts with a larger max_seq",
                    self.name
                );
            }
        }
        Ok(())
    }
}
